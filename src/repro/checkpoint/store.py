"""Sharded checkpointing with atomic commit + elastic restore.

Layout (one directory per step):

    <dir>/step_000123.tmp/            # written first
        meta.json                     # tree structure, shapes, dtypes
        shard_<host>.npz              # this host's param/opt shards
    <dir>/step_000123/                # atomic rename on success
    <dir>/LATEST                      # pointer file, written last

Fault-tolerance properties:
  * a crash mid-write leaves only a .tmp dir — restore ignores it;
  * restore reshards to ANY mesh topology (elastic): arrays are saved
    unsharded per leaf (host gathers its addressable shards; single-host
    saves the full array) and re-placed under the target sharding on load;
  * ``CheckpointManager`` installs a SIGTERM hook so preemptions flush a
    final checkpoint (the "node failure" path), and prunes old steps.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import signal

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, *,
                    host_index: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    meta = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        meta["leaves"][key] = {"shape": list(arr.shape),
                               "dtype": str(arr.dtype)}
        if not (np.issubdtype(arr.dtype, np.floating)
                or np.issubdtype(arr.dtype, np.integer)
                or arr.dtype == np.bool_):
            # ml_dtypes (bfloat16, fp8) don't survive npz roundtrips: store
            # as f32 (exact for bf16); logical dtype restored from meta.
            arr = arr.astype(np.float32)
        arrays[key.replace("/", "__")] = arr
    np.savez(os.path.join(tmp, f"shard_{host_index}.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    ptr = os.path.join(directory, "LATEST")
    if os.path.exists(ptr):
        with open(ptr) as f:
            m = re.match(r"step_(\d+)", f.read().strip())
            if m and os.path.isdir(os.path.join(directory, m.group(0))):
                return int(m.group(1))
    # Fallback: scan for committed dirs (LATEST lost).
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))] \
        if os.path.isdir(directory) else []
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings_tree=None, host_index: int = 0):
    """Restore into the structure of ``like_tree``; reshard to
    ``shardings_tree`` (elastic: target mesh may differ from save mesh)."""
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, f"shard_{host_index}.npz"))
    flat_like = _flatten_with_paths(like_tree)
    flat_sh = (_flatten_with_paths(shardings_tree)
               if shardings_tree is not None else {})
    out = {}
    for key, leaf in flat_like.items():
        arr = data[key.replace("/", "__")]
        want_dtype = (leaf.dtype if hasattr(leaf, "dtype")
                      else np.asarray(leaf).dtype)
        a = jnp.asarray(arr, dtype=want_dtype)
        if key in flat_sh:
            a = jax.device_put(a, flat_sh[key])
        out[key] = a
    # Rebuild the tree.
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)
    treedef = leaves_paths[1]
    rebuilt = []
    for pathk, _ in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        rebuilt.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, rebuilt)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    save_every: int = 50

    def __post_init__(self):
        self._preempted = False
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass  # not on main thread

    def _on_sigterm(self, *_):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.save_every == 0 or self._preempted:
            save_checkpoint(self.directory, step, tree)
            self._prune()
            return True
        return False

    def _prune(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree, *, shardings_tree=None):
        step = latest_step(self.directory)
        if step is None:
            return None, 0
        return restore_checkpoint(self.directory, step, like_tree,
                                  shardings_tree=shardings_tree), step
