"""Per-query scheme + algorithm planning (the paper's §3.2/§4 machinery,
promoted from hand-set benchmark knobs to an online decision per query).

For every admitted join the planner prices, through ``SeriesCostModel``:

  * SHJ under each co-processing scheme (CPU_ONLY / GPU_ONLY / OL / DD /
    PL), build and probe series separately — Eqs. 1–5 with the δ-sweep
    optimizers choosing the per-step ratios;
  * PHJ: planner-chosen radix schedule priced per pass (the
    ``PassPlanner`` knee model), plus a post-partition join phase whose
    random accesses are cache-resident (the paper's locality argument for
    partitioning in the first place).

SHJ's probe-side random accesses degrade once the hash table outgrows the
cache (working set ≈ 32 B/tuple of CSR arrays); that is priced as a
multiplicative penalty per doubling past ``cache_bytes`` — the same knee
idiom the pass planner uses for scatter fanout.  Small inputs therefore
plan to SHJ (partitioning is pure overhead) and large ones to PHJ,
reproducing the paper's regime split.

Two signals close the loop as traffic flows:

  * ``OnlineUnitCosts`` (calibrate.py) — measured phase times fold back
    into per-phase unit-cost scales, so estimates track this host;
  * cache awareness — a query whose build table is already resident is
    priced with zero build cost, which is what makes the engine prefer
    probe-only SHJ on hot tables over re-partitioning.
"""
from __future__ import annotations

import dataclasses
import math
import threading

import numpy as np

from repro.core.calibrate import APU_CPU, APU_GPU, OnlineUnitCosts
from repro.core.cost_model import DeviceSpec, LinkSpec, SeriesCostModel
from repro.core.hash_table import default_num_buckets
from repro.core.pass_planner import PassPlanner, default_planner
from repro.core.phj import default_shj_bits
from repro.core.shj import BUILD_SERIES, PROBE_SERIES

SCHEMES = ("CPU_ONLY", "GPU_ONLY", "OL", "DD", "PL")
# What CoProcessor.build_table/probe_table actually realize: one quantized
# cut per phase (ratios[0]).  Per-step OL/PL vectors are priced by the
# model but only run_map_series executes them, and the engine does not use
# that path yet — so by default the planner only offers schemes whose
# estimate matches what will execute.  Pass allowed_schemes=SCHEMES to
# price the full catalog (model studies, paper figures).
EXECUTABLE_SCHEMES = ("CPU_ONLY", "GPU_ONLY", "DD")

# What a PL boundary exchange actually costs between host device groups: a
# device_get + concat + device_put round trip (~ms), not a zero-copy
# alias.  The analytic ZEROCOPY_LINK underprices that by orders of
# magnitude, which would make the planner pick PL ratio boundaries that
# measure slower than DD; this spec is calibrated to the observed host
# shuffle cost.  Pass an explicit link (ICI_LINK etc.) for pod-scale
# planning.
HOST_SHUFFLE_LINK = LinkSpec("host_shuffle", 1e-3, 1e9)

# CSR hash-table working set per build tuple (7 dense int32 columns plus
# bucket headers at the default load factor).
TABLE_BYTES_PER_TUPLE = 32


@dataclasses.dataclass
class QueryPlan:
    """Everything the executor needs, plus the estimates behind the choice."""

    algorithm: str                  # "shj" | "phj" | "groupby"
    scheme: str                     # one of SCHEMES
    build_ratios: tuple             # len-4 per-step CPU shares
    probe_ratios: tuple
    num_buckets: int
    max_out: int
    table_mode: str = "shared"
    cached: bool = False            # probe-only against a resident table
    est_s: float = 0.0
    est_build_s: float = 0.0        # phj: partition-phase estimate
    est_probe_s: float = 0.0        # phj: join-phase estimate
    # phj-only knobs (planner-chosen); groupby reuses schedule +
    # partition_ratio and carries its aggregate-phase split in join_ratio.
    schedule: tuple | None = None
    shj_bits: int = 0
    partition_ratio: float = 0.5
    join_ratio: float = 0.5
    # Join-variant semantics ("inner" | "semi" | "anti" | "left_outer"):
    # semi/anti probes skip the p4 payload gather, so they are priced on
    # the p1–p3 series only.
    kind: str = "inner"

    @property
    def c_share(self) -> float:
        """Mean CPU-side ratio — drives load-aware admission."""
        if self.algorithm in ("phj", "groupby"):
            return 0.5 * (self.partition_ratio + self.join_ratio)
        rs = list(self.probe_ratios) + ([] if self.cached
                                        else list(self.build_ratios))
        return float(np.mean(rs)) if rs else 0.5


def _unit_parts(device: DeviceSpec, cost) -> tuple[float, float]:
    """(non-random, random-access) components of seconds/item (Eq. 3)."""
    non_rand = (cost.ops_per_item / device.ops_per_s
                + cost.seq_bytes_per_item / device.seq_bw_bytes_per_s)
    rand = cost.rand_accesses_per_item / device.rand_access_per_s
    return non_rand, rand


class QueryPlanner:
    """Chooses algorithm, scheme, and ratios for one join query."""

    def __init__(self, device_c: DeviceSpec = APU_CPU,
                 device_g: DeviceSpec = APU_GPU,
                 link: LinkSpec = HOST_SHUFFLE_LINK, *,
                 discrete: bool = False,
                 delta: float = 0.05,
                 allowed_schemes: tuple[str, ...] = EXECUTABLE_SCHEMES,
                 allow_phj: bool = True,
                 cache_bytes: int = 4 << 20, rand_penalty: float = 0.35,
                 reuse_discount: float = 0.5,
                 phj_overhead_s: float = 2e-3,
                 coproc_margin: float = 1.1,
                 min_feedback_items: int = 2048,
                 replan_margin: float = 0.8,
                 handoff_latency_s: float = 2e-4,
                 handoff_bw_bytes_per_s: float = 2e9,
                 u_overrides: dict | None = None,
                 pass_planner: PassPlanner | None = None,
                 partition_device_g: DeviceSpec | None = None,
                 online: OnlineUnitCosts | None = None):
        self.device_c = device_c
        self.device_g = device_g
        self.link = link
        self.discrete = discrete
        self.delta = float(delta)
        self.allowed_schemes = tuple(allowed_schemes)
        self.allow_phj = allow_phj
        self.cache_bytes = int(cache_bytes)
        self.rand_penalty = float(rand_penalty)
        self.reuse_discount = float(reuse_discount)
        # Fixed per-query cost of PHJ's partition-ownership exchange (host
        # gather/scatter of both relations between the groups) — it is what
        # makes PHJ a losing plan for small queries even before the online
        # scales converge.
        self.phj_overhead_s = float(phj_overhead_s)
        # Handicap on mixed-ratio schemes (OL/DD/PL): splitting a step
        # series across groups carries coordination overhead the series
        # model does not price, so co-processing must promise at least
        # this factor of improvement over the best single-group plan.
        self.coproc_margin = float(coproc_margin)
        # Feedback floor: a query this small measures dispatch overhead,
        # not per-item cost — one such sample can swing the online scales
        # by orders of magnitude, and every material move invalidates all
        # sticky plans (recompile churn).  The query pipeline's post-filter
        # stages routinely run a few hundred tuples; they must not
        # calibrate the model.
        self.min_feedback_items = int(min_feedback_items)
        # Replan hysteresis: when a calibration tick re-prices a sticky
        # signature, the challenger must beat the incumbent's re-priced
        # estimate by this factor to displace it.  Near-tie flips would
        # trade compiled executables for a fresh XLA compile each time the
        # scales wiggle — far more expensive than any near-tie gain.
        self.replan_margin = float(replan_margin)
        # Host hand-off pricing: what one D2H gather + H2D re-upload of a
        # stage intermediate costs (latency + bytes/bandwidth).  Measured
        # on the real devices by ``calibrated``; the analytic defaults are
        # host-platform ballparks.  The join-order optimizer adds this
        # term per host-materialized stage hand-off and ~0 for the fused
        # device-resident hand-off, which is what lets it prefer orders
        # keeping the large intermediate resident.
        self.handoff_latency_s = float(handoff_latency_s)
        self.handoff_bw_bytes_per_s = float(handoff_bw_bytes_per_s)
        self.u_overrides = dict(u_overrides or {})
        self.pass_planner = pass_planner or default_planner(device_c)
        # None -> the G-group mirrors the planner's (calibrated) C costs;
        # a DeviceSpec prices it analytically.  Analytic planners default
        # to the G device spec.
        self.partition_device_g = (partition_device_g if pass_planner
                                   is not None else
                                   (partition_device_g or device_g))
        self.online = online or OnlineUnitCosts()
        self.plan_counts: dict[tuple[str, str], int] = {}
        self._sweep_cache: dict = {}
        self._plan_cache: dict = {}
        self._replan_flags = 0
        self._lock = threading.Lock()

    # -- measured construction (paper §4.2, once at service start) ---------
    @classmethod
    def calibrated(cls, cp, *, n: int = 32768, reps: int = 2, **kw
                   ) -> "QueryPlanner":
        """Measure per-step unit costs on ``cp``'s real device groups."""
        from repro.core import build_hash_table, uniform_relation
        from repro.core.calibrate import calibrated_overrides
        from repro.core.pass_planner import calibrate_partition_unit_costs
        rel = uniform_relation(n, seed=0)
        probe = uniform_relation(n, key_range=n, seed=1)
        nb = default_num_buckets(n)
        items_b = {"rid": rel.rid, "key": rel.key}
        u = calibrated_overrides(BUILD_SERIES, {"num_buckets": nb}, items_b,
                                 cp.c, cp.g, reps=reps)
        table = build_hash_table(rel, nb)
        u.update(calibrated_overrides(
            PROBE_SERIES, {"table": table, "max_out": 4 * n,
                           "num_buckets": nb},
            {"rid": probe.rid, "key": probe.key}, cp.c, cp.g, reps=reps))
        part_u = calibrate_partition_unit_costs(cp.c, n, reps=reps)
        lat, bw = cls._measure_handoff(cp)
        kw.setdefault("handoff_latency_s", lat)
        kw.setdefault("handoff_bw_bytes_per_s", bw)
        return cls(u_overrides=u,
                   pass_planner=PassPlanner.from_measurements(part_u),
                   partition_device_g=None, **kw)

    @staticmethod
    def _measure_handoff(cp, reps: int = 3) -> tuple[float, float]:
        """Measured H2D/D2H unit cost of a host stage hand-off.

        Times a device_put + device_get round trip at two sizes: the small
        buffer isolates the per-transfer latency, the large one the
        bandwidth (both directions count — a host hand-off pays a gather
        down and an upload back).
        """
        import time as _time

        import jax as _jax
        import numpy as _np

        def round_trip(n):
            buf = _np.zeros(n, _np.int32)
            ts = []
            for _ in range(reps + 1):   # first rep warms allocation paths
                t0 = _time.perf_counter()
                dev = _jax.device_put(buf, cp.g.devices[0])
                _jax.block_until_ready(dev)
                _np.asarray(_jax.device_get(dev))
                ts.append(_time.perf_counter() - t0)
            return float(_np.median(ts[1:]))

        small, large = 256, 1 << 18                    # 1 KiB vs 1 MiB
        t_small = round_trip(small)
        t_large = round_trip(large)
        lat = max(1e-6, t_small)
        bw = (2 * 4 * (large - small)) / max(t_large - t_small, 1e-9)
        return lat, max(bw, 1e8)

    def host_handoff_s(self, nbytes: int) -> float:
        """Cost of one host-materialized stage hand-off of ``nbytes``."""
        if nbytes <= 0:
            return 0.0
        return self.handoff_latency_s + nbytes / self.handoff_bw_bytes_per_s

    # -- model construction --------------------------------------------------
    def table_rand_scale(self, build_n: int) -> float:
        """Random-access penalty once the table outgrows the cache."""
        ws = max(1, build_n * TABLE_BYTES_PER_TUPLE)
        excess = max(0.0, math.log2(ws / self.cache_bytes))
        return 1.0 + self.rand_penalty * excess

    def _series_model(self, series, x, *, rand_scale: float = 1.0
                      ) -> SeriesCostModel:
        names, u_c, u_g, outb = [], [], [], []
        for s in series:
            nc, rc = _unit_parts(self.device_c, s.cost)
            ng, rg = _unit_parts(self.device_g, s.cost)
            if s.name in self.u_overrides:
                # Measured u; the rand share of the *analytic* split decides
                # how much of it the table-size penalty inflates.
                mc, mg = self.u_overrides[s.name]
                fc = rc / max(nc + rc, 1e-30)
                fg = rg / max(ng + rg, 1e-30)
                uc = mc * (1.0 + fc * (rand_scale - 1.0))
                ug = mg * (1.0 + fg * (rand_scale - 1.0))
            else:
                uc = nc + rc * rand_scale
                ug = ng + rg * rand_scale
            names.append(s.name)
            u_c.append(uc)
            u_g.append(ug)
            outb.append(s.cost.out_bytes_per_item)
        return SeriesCostModel(names, u_c, u_g, np.asarray(x, np.float64),
                               np.asarray(outb, np.float64), self.link,
                               discrete=self.discrete)

    def _sweep(self, key, series, x, *, rand_scale: float):
        """Memoized scheme sweep (hot-table traffic re-plans same shapes).

        The sweep prices the *unscaled* model, so the chosen ratios — and
        therefore the compiled slice shapes — are stable; online scales
        adjust candidate totals afterwards, per scheme.
        """
        cache_key = (key, tuple(x), round(rand_scale, 4), self.delta)
        with self._lock:
            hit = self._sweep_cache.get(cache_key)
        if hit is not None:
            return hit
        m = self._series_model(series, x, rand_scale=rand_scale)
        out = m.scheme_sweep(delta=self.delta, schemes=self.allowed_schemes)
        with self._lock:
            if len(self._sweep_cache) > 512:
                self._sweep_cache.clear()
            self._sweep_cache[cache_key] = out
        return out

    # -- candidate estimates -------------------------------------------------
    def _shj_candidates(self, build_n: int, probe_n: int, cached: bool,
                        kind: str = "inner"):
        rs = self.table_rand_scale(build_n)
        # Semi/anti emit match flags instead of expanding matches: the p4
        # payload gather (2 random accesses/tuple) drops out of the series,
        # which is what makes those probes cheaper than inner at equal
        # sizes.  Left-outer keeps the full expansion (plus the unmatched
        # emission riding the same scan).
        probe_steps = (PROBE_SERIES.steps[:3] if kind in ("semi", "anti")
                       else PROBE_SERIES.steps)
        probe_tag = ("shj_probe" if kind == "inner"
                     else f"shj_probe[{kind}]")
        probe = self._sweep(probe_tag, probe_steps,
                            [probe_n] * len(probe_steps), rand_scale=rs)
        if cached:
            build = None
        else:
            build = self._sweep("shj_build", BUILD_SERIES.steps,
                                [build_n] * 4, rand_scale=rs)
        for scheme in self.allowed_schemes:
            rp, tp = probe[scheme]
            rb, tb = build[scheme] if build else (rp, 0.0)
            # Per-scheme online scales: a PL plan's boundary shuffles and a
            # DD plan's flat split calibrate independently.
            tp = tp * self.online.scale_for(f"{probe_tag}:{scheme}")
            tb = tb * self.online.scale_for(f"shj_build:{scheme}")
            yield QueryPlan(
                algorithm="shj", scheme=scheme,
                build_ratios=tuple(float(r) for r in rb),
                probe_ratios=tuple(float(r) for r in rp),
                num_buckets=default_num_buckets(build_n), max_out=0,
                cached=cached, est_s=tb + tp, est_build_s=tb,
                est_probe_s=tp, kind=kind)

    def _phj_candidate(self, build_n: int, probe_n: int) -> QueryPlan | None:
        plan = self.pass_planner.plan(build_n)
        total_bits = plan.total_bits
        part_scale = self.online.scale_for("phj_partition")
        est_part, part_ratio = 0.0, 0.5
        for i, bits in enumerate(plan.schedule):
            m = self.pass_planner.pass_model(
                build_n, bits, device_g=self.partition_device_g,
                link=self.link)
            r, t_r = m.optimize_dd(delta=self.delta)
            m_s = self.pass_planner.pass_model(
                probe_n, bits, device_g=self.partition_device_g,
                link=self.link)
            _, t_s = m_s.optimize_dd(delta=self.delta)
            est_part += (t_r + t_s) * part_scale
            if i == 0:
                part_ratio = float(r)
        # Post-partition join: one ownership ratio across both sub-phases;
        # random accesses are partition-local, hence cache-resident
        # (rand_scale=1) — the whole point of paying for partitioning.
        steps = list(BUILD_SERIES.steps) + list(PROBE_SERIES.steps)
        m_join = self._series_model(steps, [build_n] * 4 + [probe_n] * 4,
                                    rand_scale=1.0)
        join_ratio, est_join = m_join.optimize_dd(delta=self.delta)
        est_join = est_join * self.online.scale_for("phj_join")
        return QueryPlan(
            algorithm="phj", scheme="DD",
            build_ratios=(part_ratio,) * 4, probe_ratios=(join_ratio,) * 4,
            num_buckets=default_num_buckets(build_n), max_out=0,
            est_s=est_part + est_join + self.phj_overhead_s,
            est_build_s=est_part,
            est_probe_s=est_join, schedule=plan.schedule,
            shj_bits=default_shj_bits(build_n, total_bits),
            partition_ratio=part_ratio, join_ratio=float(join_ratio))

    # -- the decision --------------------------------------------------------
    def choose(self, build_n: int, probe_n: int, *, max_out: int,
               cached: bool = False, expect_reuse: bool = False,
               c_load: float = 0.0, g_load: float = 0.0,
               kind: str = "inner", record: bool = True) -> QueryPlan:
        """Plan one query.

        ``kind``         — join-variant semantics; non-inner kinds run over
                           the SHJ probe path only (PHJ's partition-pair
                           ownership split has no variant emission), with
                           semi/anti priced without the p4 payload gather.
        ``cached``       — the build table is resident: probe-only SHJ.
        ``expect_reuse`` — this fingerprint has been seen before, so an SHJ
                           build is an investment the cache will amortize
                           (its cost is discounted by ``reuse_discount``).
        ``c_load``/``g_load`` — outstanding estimated seconds already
        admitted per group; added to each candidate in proportion to the
        share of that group it would use, so near-ties break toward the
        idler group and work from different queries overlaps.

        Plans are *sticky*: once a signature has been planned, the same
        plan (and therefore its compiled executables) is reused until the
        online calibration moves materially (``OnlineUnitCosts.version``).
        Load bias applies at (re)planning moments, not on every repeat of
        a hot signature.
        """
        sig = (build_n, probe_n, cached, expect_reuse,
               self._load_bucket(c_load, g_load), kind)

        def make_candidates():
            # A resident table does not *force* probe-only: at sizes
            # where the un-partitioned table is cache-hostile, re-running
            # PHJ can beat probing it — the sweep arbitrates (plan.cached
            # marks the winner).
            cands = list(self._shj_candidates(build_n, probe_n, cached,
                                              kind))
            if self.allow_phj and kind == "inner":
                phj = self._phj_candidate(build_n, probe_n)
                if phj is not None:
                    cands.append(phj)
            return cands

        def effective(p: QueryPlan) -> float:
            est = p.est_s
            if (expect_reuse and not cached and p.algorithm == "shj"):
                est = p.est_build_s * self.reuse_discount + p.est_probe_s
            if p.algorithm == "shj" and p.scheme not in ("CPU_ONLY",
                                                         "GPU_ONLY"):
                est = est * self.coproc_margin
            c = p.c_share
            return est + c * c_load + (1.0 - c) * g_load

        plan, from_cache = self._sticky_choose(
            sig, make_candidates, effective,
            keep_key=lambda p: (p.algorithm, p.scheme, p.cached),
            count_key=lambda p: (p.algorithm,
                                 "cached" if cached else p.scheme),
            record=record)
        if from_cache:
            return dataclasses.replace(plan, max_out=int(max_out))
        plan.max_out = int(max_out)
        return plan

    def choose_degraded(self, build_n: int, probe_n: int, *, max_out: int,
                        cached: bool = False, kind: str = "inner",
                        record: bool = True) -> QueryPlan:
        """The *cheapest* realizable plan — deadline-degraded execution.

        Admission uses this when a query's preferred plan already misses
        its deadline: raw minimum ``est_s`` over the same candidate set as
        ``choose``, with no co-processing handicap and no load bias (a
        degraded query wants out of the system as fast as possible, not a
        balanced placement).  A resident build table makes the probe-only
        variant the usual winner.  Sticky under its own signature, so
        degraded traffic reuses compiled executables like any other.
        """
        sig = ("degraded", build_n, probe_n, cached, kind)

        def make_candidates():
            cands = list(self._shj_candidates(build_n, probe_n, cached,
                                              kind))
            if self.allow_phj and kind == "inner":
                phj = self._phj_candidate(build_n, probe_n)
                if phj is not None:
                    cands.append(phj)
            return cands

        plan, from_cache = self._sticky_choose(
            sig, make_candidates, lambda p: p.est_s,
            keep_key=lambda p: (p.algorithm, p.scheme, p.cached),
            count_key=lambda p: (p.algorithm, "degraded"),
            record=record)
        if from_cache:
            return dataclasses.replace(plan, max_out=int(max_out))
        plan.max_out = int(max_out)
        return plan

    @staticmethod
    def _load_bucket(c_load: float, g_load: float) -> int:
        """Coarse load-imbalance bucket: plans stay sticky under balanced
        load, but a strongly lopsided group gets its own (sticky) variant
        — bounded to three compiled variants per shape.  The dead zone is
        wide on purpose: each extra variant is an extra compilation."""
        if abs(c_load - g_load) <= max(0.5 * max(c_load, g_load), 0.2):
            return 0
        return 1 if c_load > g_load else -1

    def _sticky_choose(self, sig, make_candidates, effective, *,
                       keep_key, count_key, record: bool = True):
        """Sticky cost-model choice shared by join and group-by planning.

        A cached plan for ``sig`` is reused until the online calibration
        version moves; on a re-price, the incumbent (matched by
        ``keep_key``) keeps its compiled executables unless the challenger
        beats it by ``replan_margin`` (near-tie flips trade compiled code
        for XLA recompiles).  Returns ``(plan, from_cache)``.
        ``record=False`` skips the plan-count bookkeeping — admission-time
        pricing must not inflate the execution mix the benches report.
        """
        with self._lock:
            hit = self._plan_cache.get(sig)
        if hit is not None and hit[0] == self.online.version:
            plan = hit[1]
            if record:
                with self._lock:
                    k = count_key(plan)
                    self.plan_counts[k] = self.plan_counts.get(k, 0) + 1
            return plan, True
        candidates = make_candidates()
        best = min(candidates, key=effective)
        if hit is not None:
            prev = hit[1]
            keep = [p for p in candidates if keep_key(p) == keep_key(prev)]
            if keep and not self.replan_beats(effective(best),
                                              effective(keep[0])):
                best = keep[0]
        with self._lock:
            if len(self._plan_cache) > 512:
                self._plan_cache.clear()
            self._plan_cache[sig] = (self.online.version, best)
            if record:
                k = count_key(best)
                self.plan_counts[k] = self.plan_counts.get(k, 0) + 1
        return best, False

    def replan_beats(self, challenger_s: float, incumbent_s: float) -> bool:
        """The one replan-hysteresis rule: a challenger displaces an
        incumbent only by beating its estimate by ``replan_margin``.

        Shared by sticky per-stage re-pricing (above) and the executor's
        mid-pipeline order replans (``optimize.reprice_remaining``) —
        near-tie flips trade compiled executables and warmed caches for
        nothing, so both layers apply the identical margin.
        """
        return float(challenger_s) < self.replan_margin * float(incumbent_s)

    def flag_replan(self, *, algorithm: str | None = None,
                    scheme: str | None = None) -> int:
        """Flag matching sticky plans for re-pricing (the drift hook).

        Marks every cached plan matching ``algorithm``/``scheme`` (None =
        any) with a version that can never equal ``online.version``, so
        the next ``choose`` for that signature re-prices through the
        normal ``_sticky_choose`` path — candidates re-swept, incumbent
        kept unless a challenger beats it by ``replan_margin``.  No new
        invalidation machinery: drift reuses the same hysteresis a
        calibration version tick does.  Returns how many cached plans
        were flagged.
        """
        n = 0
        with self._lock:
            for sig, (ver, plan) in list(self._plan_cache.items()):
                if algorithm is not None and plan.algorithm != algorithm:
                    continue
                if scheme is not None and plan.scheme != scheme:
                    continue
                if ver != -1:
                    self._plan_cache[sig] = (-1, plan)
                    n += 1
            self._replan_flags += n
        return n

    # -- group-by aggregation (ops subsystem) --------------------------------
    def _groupby_sweep(self, n: int):
        return self._sweep("groupby_agg", BUILD_SERIES.steps, [n] * 4,
                           rand_scale=self.table_rand_scale(n))

    def _groupby_single(self, n: int, scheme: str,
                        sweep=None) -> QueryPlan:
        """Unpartitioned group-by on one group: the sort is the hash table,
        priced as the build series (same sort + boundary + reduce shape)
        with the full-relation random-access penalty."""
        _, t = (sweep or self._groupby_sweep(n))[scheme]
        t = t * self.online.scale_for(f"groupby_agg:{scheme}")
        r = 1.0 if scheme == "CPU_ONLY" else 0.0
        return QueryPlan(
            algorithm="groupby", scheme=scheme, build_ratios=(r,) * 4,
            probe_ratios=(r,) * 4, num_buckets=0, max_out=0, est_s=t,
            est_build_s=0.0, est_probe_s=t, schedule=None,
            partition_ratio=r, join_ratio=r)

    def _groupby_separate(self, n: int) -> QueryPlan:
        """Row-split DD group-by, separate partials + host merge (the
        paper's Fig. 3 separate-tables mode applied to aggregation): each
        group aggregates its row share concurrently, partial group lists
        merge on the host.  The merge is O(groups) — priced as the same
        fixed overhead as PHJ's ownership exchange."""
        m = self._series_model(BUILD_SERIES.steps, [n] * 4,
                               rand_scale=self.table_rand_scale(n))
        r, t = m.optimize_dd(delta=self.delta)
        t = t * self.online.scale_for("groupby_agg:DD")
        return QueryPlan(
            algorithm="groupby", scheme="DD", table_mode="separate",
            build_ratios=(float(r),) * 4, probe_ratios=(float(r),) * 4,
            num_buckets=0, max_out=0, est_s=t + self.phj_overhead_s,
            est_build_s=0.0, est_probe_s=t, schedule=None,
            partition_ratio=float(r), join_ratio=float(r))

    def _groupby_coproc(self, n: int) -> QueryPlan:
        """Partitioned DD group-by: the PHJ skeleton priced for one
        relation — planner-chosen radix schedule, then a cache-resident
        per-partition reduce split at one ownership ratio."""
        plan = self.pass_planner.plan(n)
        part_scale = self.online.scale_for("groupby_partition")
        est_part, part_ratio = 0.0, 0.5
        for i, bits in enumerate(plan.schedule):
            m = self.pass_planner.pass_model(
                n, bits, device_g=self.partition_device_g, link=self.link)
            r, t = m.optimize_dd(delta=self.delta)
            est_part += t * part_scale
            if i == 0:
                part_ratio = float(r)
        m_agg = self._series_model(BUILD_SERIES.steps, [n] * 4,
                                   rand_scale=1.0)
        agg_ratio, est_agg = m_agg.optimize_dd(delta=self.delta)
        est_agg = est_agg * self.online.scale_for("groupby_agg:DD_part")
        return QueryPlan(
            algorithm="groupby", scheme="DD",
            build_ratios=(part_ratio,) * 4,
            probe_ratios=(float(agg_ratio),) * 4, num_buckets=0, max_out=0,
            est_s=est_part + est_agg + self.phj_overhead_s,
            est_build_s=est_part, est_probe_s=est_agg,
            schedule=plan.schedule, partition_ratio=part_ratio,
            join_ratio=float(agg_ratio))

    def choose_groupby(self, n: int, *, c_load: float = 0.0,
                       g_load: float = 0.0,
                       record: bool = True) -> QueryPlan:
        """Plan one group-by aggregation over ``n`` tuples.

        Candidates follow ``allowed_schemes``: whole-relation aggregation
        on either single group (CPU_ONLY / GPU_ONLY), the row-split
        separate-partials DD, and the radix-partitioned DD split under the
        same ``PassPlanner`` schedule and ``coproc_margin`` handicap as
        PHJ.  Plans are sticky per (n, load bucket) like join plans.
        """
        sig = ("groupby", n, self._load_bucket(c_load, g_load))

        def make_candidates():
            sweep = self._groupby_sweep(n)
            cands = [self._groupby_single(n, s, sweep)
                     for s in ("CPU_ONLY", "GPU_ONLY")
                     if s in self.allowed_schemes]
            if "DD" in self.allowed_schemes:
                cands.append(self._groupby_separate(n))
            if self.allow_phj:
                cands.append(self._groupby_coproc(n))
            # Degenerate scheme catalog (e.g. OL/PL-only): nothing above
            # is realizable for group-by, fall back to the larger group.
            return cands or [self._groupby_single(n, "GPU_ONLY", sweep)]

        def effective(p: QueryPlan) -> float:
            est = p.est_s * (self.coproc_margin if p.scheme == "DD" else 1.0)
            c = p.c_share
            return est + c * c_load + (1.0 - c) * g_load

        plan, _ = self._sticky_choose(
            sig, make_candidates, effective,
            keep_key=lambda p: (p.scheme, bool(p.schedule)),
            count_key=lambda p: ("groupby", p.scheme), record=record)
        return plan

    # -- feedback (satellite: close the calibration loop online) -----------
    @staticmethod
    def phase_pairs(plan: QueryPlan, timing
                    ) -> list[tuple[str, str, float, float]]:
        """``(phase, scheme, est_s, measured_s)`` pairs for one executed
        plan — the phases the plan actually priced, matched against the
        ``Timing`` the executor measured.  This is the single source of
        truth for both the online calibration feedback (``observe``) and
        the cost-model audit trail (``repro.obs.CostAudit``)."""
        phases = timing.phase_s
        if plan.algorithm == "groupby":
            pairs = []
            if plan.schedule:
                pairs.append(("partition", plan.scheme, plan.est_build_s,
                              phases.get("partition", 0.0)))
            pairs.append(("agg", plan.scheme, plan.est_probe_s,
                          phases.get("agg", 0.0)))
            return pairs
        if plan.algorithm == "phj":
            return [("partition", plan.scheme, plan.est_build_s,
                     phases.get("partition", 0.0)),
                    ("join", plan.scheme, plan.est_probe_s,
                     phases.get("join", 0.0))]
        pairs = []
        if not plan.cached:
            pairs.append(("build", plan.scheme, plan.est_build_s,
                          phases.get("build", 0.0)))
        pairs.append(("probe", plan.scheme, plan.est_probe_s,
                      phases.get("probe", 0.0)))
        return pairs

    @staticmethod
    def _online_tag(plan: QueryPlan, phase: str) -> str:
        """The unit-cost series a (plan, phase) pair calibrates."""
        if plan.algorithm == "groupby":
            if phase == "partition":
                return "groupby_partition"
            return ("groupby_agg:DD_part" if plan.schedule
                    else f"groupby_agg:{plan.scheme}")
        if plan.algorithm == "phj":
            return "phj_partition" if phase == "partition" else "phj_join"
        if phase == "build":
            return f"shj_build:{plan.scheme}"
        probe_tag = ("shj_probe" if plan.kind == "inner"
                     else f"shj_probe[{plan.kind}]")
        return f"{probe_tag}:{plan.scheme}"

    def observe(self, plan: QueryPlan, timing) -> None:
        """Fold one executed query's measured phase times back in."""
        for phase, _scheme, est_s, measured_s in self.phase_pairs(plan,
                                                                  timing):
            self.online.observe(self._online_tag(plan, phase), est_s,
                                measured_s)

    def stats(self) -> dict:
        with self._lock:
            counts = {f"{a}/{s}": n for (a, s), n in
                      sorted(self.plan_counts.items())}
            replan_flags = self._replan_flags
        return {"plan_counts": counts, "replan_flags": replan_flags,
                "online": self.online.to_dict()}
