"""Resilience layer: preemption context, budget enforcement, recovery.

Admission (``admission.py``) prices risk once, up front.  This module is
what can still act *after* a query starts running:

  * :class:`QueryContext` — cancel token + absolute deadline + tenant
    budget meter, threaded from ``JoinQueryService.execute`` into
    ``CoProcessor.phj`` / ``groupby`` and checked cooperatively at radix
    pass boundaries and between pipeline waves.  A blown deadline raises
    :class:`DeadlineExceeded` (same ``QueueFull``/``Backpressure`` family
    admission sheds with, so every caller's structured-error handling
    already covers it); completed partition passes are checkpointed so a
    re-admitted query resumes instead of restarting.
  * :class:`BudgetEnforcer` — per-(tenant, device-group) token buckets
    fed by *measured* phase seconds off the ``CostAudit`` listener
    stream.  A tenant that under-predicted its C/G budget is throttled
    (short sleep at the next pass boundary) and, past a debt bound,
    preempted with :class:`BudgetExceeded` — budgets stop being
    admission-time fiction.
  * :class:`RetryPolicy` + :class:`BreakerBoard` — the service's recovery
    ladder: bounded seeded-jitter retries for *transient* faults, one
    degraded (cheapest-plan) retry, then per-``(algorithm, scheme)``
    circuit breakers that quarantine a repeatedly failing kernel variant
    and route it to the NumPy reference path until a half-open trial
    succeeds.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

from .admission import Backpressure

# Breaker states (the ``breaker_state`` gauge values).
CLOSED, OPEN, HALF_OPEN = 0, 1, 2
_STATE_NAMES = {CLOSED: "closed", OPEN: "open", HALF_OPEN: "half_open"}


class DeadlineExceeded(Backpressure):
    """Raised mid-flight when a query's absolute deadline has passed.

    Subclasses ``Backpressure`` (hence ``QueueFull``): preemption is a
    structured service decision, not an execution failure — callers that
    already treat sheds as backpressure handle it unchanged."""


class BudgetExceeded(Backpressure):
    """Raised when a tenant's measured C/G device-seconds debt exceeds
    the enforcement bound (runtime budget enforcement, not admission
    pricing)."""


class Cancelled(Backpressure):
    """The query's cancel token fired (service shutdown / caller abort)."""


@dataclasses.dataclass
class QueryContext:
    """Per-query cooperative control block.

    ``check(where)`` is called at pass boundaries (cheap: a clock read
    and two branches); it raises the structured abort or sleeps off a
    budget throttle.  ``note_partial`` captures a partially-partitioned
    relation when an abort lands mid-partitioning, so the service can
    checkpoint it under a schedule-prefix cache key.
    """

    query_id: int = -1
    tenant: str = "default"
    deadline_at: float | None = None
    clock: object = time.monotonic
    cancel: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    enforcer: "BudgetEnforcer | None" = None
    on_throttle: object = None       # fn(tenant, delay_s) | None
    # tag ("R"/"S") -> (partial Relation, completed pass count)
    partials: dict = dataclasses.field(default_factory=dict)
    # Resume bookkeeping the service fills in: tag -> completed passes of
    # the checkpoint the side was restored from.
    resume_from: dict = dataclasses.field(default_factory=dict)
    # Service-side metadata (cache keys, schedule) for checkpointing.
    meta: dict = dataclasses.field(default_factory=dict)

    def check(self, where: str = "") -> None:
        if self.cancel.is_set():
            raise Cancelled(
                f"query {self.query_id} cancelled at {where or 'check'}",
                reason="cancelled", tenant=self.tenant,
                query_id=self.query_id)
        if self.deadline_at is not None and self.clock() > self.deadline_at:
            raise DeadlineExceeded(
                f"query {self.query_id} deadline passed at "
                f"{where or 'check'}", reason="deadline_exceeded",
                tenant=self.tenant, query_id=self.query_id,
                deadline_s=0.0)
        if self.enforcer is not None:
            verdict, amount = self.enforcer.check(self.tenant)
            if verdict == "throttle":
                if self.on_throttle is not None:
                    self.on_throttle(self.tenant, amount)
                time.sleep(amount)
            elif verdict == "preempt":
                raise BudgetExceeded(
                    f"tenant {self.tenant} exceeded its device-seconds "
                    f"budget by {amount:.3f}s (query {self.query_id} "
                    f"preempted at {where or 'check'})",
                    reason="budget", tenant=self.tenant,
                    query_id=self.query_id, retry_after_s=amount)

    def note_partial(self, tag: str, rel, passes_done: int) -> None:
        if passes_done > 0:
            self.partials[tag] = (rel, int(passes_done))


# Scheme -> C-group share of measured phase seconds (mirrors the planner's
# quantized execution: single-group schemes are exact, split schemes are
# charged half-and-half — enforcement is a bound, not an attribution).
_SCHEME_C_SHARE = {"CPU_ONLY": 1.0, "GPU_ONLY": 0.0}


class _TokenBucket:
    """Seconds-of-device-time bucket: refills at ``rate`` per wall
    second up to ``burst_s``; charges may drive it negative (debt)."""

    def __init__(self, rate: float, burst_s: float, now: float):
        self.rate = float(rate)
        self.burst_s = float(burst_s)
        self.level = float(burst_s)
        self.last_t = float(now)

    def _refill(self, now: float) -> None:
        dt = max(0.0, now - self.last_t)
        self.last_t = now
        self.level = min(self.burst_s, self.level + dt * self.rate)

    def charge(self, amount: float, now: float) -> None:
        self._refill(now)
        self.level -= float(amount)

    def debt(self, now: float) -> float:
        self._refill(now)
        return max(0.0, -self.level)


class BudgetEnforcer:
    """Runtime C/G budget enforcement off the measured-cost stream.

    Registered as a ``CostAudit`` listener: every executed phase's
    *measured* seconds are charged to the billed tenant's per-group
    bucket, split by the executed scheme.  Bucket refill rate is the
    tenant's ``c_budget``/``g_budget`` share (device-seconds per wall
    second); ``burst_s`` seconds of headroom absorb normal variance.
    ``check`` is consulted at pass boundaries: small debt throttles
    (bounded sleep proportional to the debt), debt past
    ``preempt_debt_s`` preempts.
    """

    def __init__(self, admission, *, burst_s: float = 1.0,
                 preempt_debt_s: float = 2.0,
                 max_throttle_s: float = 0.05,
                 clock=time.monotonic, metrics=None):
        self.admission = admission
        self.burst_s = float(burst_s)
        self.preempt_debt_s = float(preempt_debt_s)
        self.max_throttle_s = float(max_throttle_s)
        self._clock = clock
        self.metrics = metrics
        self._lock = threading.Lock()
        self._buckets: dict[tuple[str, str], _TokenBucket] = {}

    def _bucket(self, tenant: str, group: str, now: float) -> _TokenBucket:
        key = (tenant, group)
        b = self._buckets.get(key)
        if b is None:
            t = self.admission.tenant(tenant)
            rate = t.c_budget if group == "C" else t.g_budget
            b = self._buckets[key] = _TokenBucket(
                max(rate, 1e-6), self.burst_s, now)
        return b

    def on_record(self, rec: dict) -> None:
        """CostAudit listener: charge one measured phase."""
        measured = float(rec.get("measured_s") or 0.0)
        if measured <= 0.0:
            return
        tenant = rec.get("tenant") or "default"
        c_share = _SCHEME_C_SHARE.get(rec.get("scheme"), 0.5)
        now = self._clock()
        with self._lock:
            if c_share > 0.0:
                self._bucket(tenant, "C", now).charge(
                    measured * c_share, now)
            if c_share < 1.0:
                self._bucket(tenant, "G", now).charge(
                    measured * (1.0 - c_share), now)

    def check(self, tenant: str) -> tuple[str, float]:
        """("ok" | "throttle" | "preempt", delay-or-debt seconds)."""
        now = self._clock()
        with self._lock:
            debt = max((b.debt(now)
                        for (t, _), b in self._buckets.items()
                        if t == tenant), default=0.0)
        if debt <= 0.0:
            return "ok", 0.0
        if debt >= self.preempt_debt_s:
            return "preempt", debt
        return "throttle", min(self.max_throttle_s, debt)

    def summary(self) -> dict:
        now = self._clock()
        with self._lock:
            return {f"{t}/{g}": {"level": round(b.level, 4),
                                 "rate": b.rate}
                    for (t, g), b in self._buckets.items()}


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with seeded jittered backoff, transient faults only."""

    max_retries: int = 2
    base_backoff_s: float = 0.002
    max_backoff_s: float = 0.05
    seed: int = 0

    def __post_init__(self):
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()

    @staticmethod
    def is_transient(e: BaseException) -> bool:
        return bool(getattr(e, "transient", False))

    def backoff_s(self, attempt: int) -> float:
        base = min(self.max_backoff_s,
                   self.base_backoff_s * (2 ** max(attempt - 1, 0)))
        with self._lock:
            return base * (0.5 + self._rng.random())


class _Breaker:
    __slots__ = ("state", "fails", "opened_at", "half_open_inflight")

    def __init__(self):
        self.state = CLOSED
        self.fails = 0
        self.opened_at = 0.0
        self.half_open_inflight = False


class BreakerBoard:
    """Per-``(algorithm, scheme)`` circuit breakers.

    CLOSED counts consecutive transient failures; at ``threshold`` the
    breaker OPENs (the service routes that plan variant to the NumPy
    reference path).  After ``cooldown_s`` the next query is a HALF_OPEN
    trial on the real kernels: success closes, failure re-opens.  Every
    transition lands as a ``breaker_state`` gauge + structured event +
    flight-recorder entry.
    """

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic, metrics=None, flight=None):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.metrics = metrics
        self.flight = flight
        self._lock = threading.Lock()
        self._breakers: dict[tuple[str, str], _Breaker] = {}

    def _emit(self, key: tuple[str, str], b: _Breaker, why: str) -> None:
        phase, scheme = key
        if self.metrics is not None:
            self.metrics.set_gauge("breaker_state", float(b.state),
                                   phase=phase, scheme=scheme)
            self.metrics.event("breaker", phase=phase, scheme=scheme,
                               state=_STATE_NAMES[b.state], why=why)
        if self.flight is not None:
            self.flight.record_resilience(
                "breaker", phase=phase, scheme=scheme,
                state=_STATE_NAMES[b.state], why=why)

    def allow(self, key: tuple[str, str]) -> bool:
        """May this plan variant run on the real kernels right now?
        ``False`` = quarantined (route to the reference path)."""
        with self._lock:
            b = self._breakers.get(key)
            if b is None or b.state == CLOSED:
                return True
            if b.state == OPEN:
                if self._clock() - b.opened_at >= self.cooldown_s:
                    b.state = HALF_OPEN
                    b.half_open_inflight = True
                    self._emit(key, b, "cooldown_elapsed")
                    return True
                return False
            # HALF_OPEN: exactly one in-flight trial at a time.
            if b.half_open_inflight:
                return False
            b.half_open_inflight = True
            return True

    def record_failure(self, key: tuple[str, str]) -> bool:
        """One transient failure of the variant; True when it (re)opened."""
        with self._lock:
            b = self._breakers.setdefault(key, _Breaker())
            if b.state == HALF_OPEN:
                b.state = OPEN
                b.opened_at = self._clock()
                b.half_open_inflight = False
                self._emit(key, b, "half_open_trial_failed")
                return True
            b.fails += 1
            if b.state == CLOSED and b.fails >= self.threshold:
                b.state = OPEN
                b.opened_at = self._clock()
                self._emit(key, b, "failure_threshold")
                return True
            return False

    def record_success(self, key: tuple[str, str]) -> None:
        with self._lock:
            b = self._breakers.get(key)
            if b is None:
                return
            if b.state == HALF_OPEN:
                b.state = CLOSED
                b.fails = 0
                b.half_open_inflight = False
                self._emit(key, b, "half_open_trial_ok")
            elif b.state == CLOSED:
                b.fails = 0

    def release(self, key: tuple[str, str]) -> None:
        """A half-open trial ended without a verdict (preempted /
        cancelled): free the trial slot without a state transition."""
        with self._lock:
            b = self._breakers.get(key)
            if b is not None and b.state == HALF_OPEN:
                b.half_open_inflight = False

    def state_of(self, key: tuple[str, str]) -> int:
        with self._lock:
            b = self._breakers.get(key)
            return CLOSED if b is None else b.state

    def summary(self) -> dict:
        with self._lock:
            return {f"{p}/{s}": {"state": _STATE_NAMES[b.state],
                                 "fails": b.fails}
                    for (p, s), b in self._breakers.items()}
