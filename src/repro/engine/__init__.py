"""Concurrent join-query engine: admission, adaptive planning, caching.

The layer the ROADMAP's "production-scale system" needs over the join
stack: a service that accepts a stream of heterogeneous join requests,
plans each through the paper's cost model (scheme *and* SHJ-vs-PHJ choice
per query), executes on a shared two-group ``CoProcessor``, and reuses
resident build tables across queries.

  * ``JoinQueryService`` / ``JoinQuery``  — admission + execution
  * ``QueryPlanner`` / ``QueryPlan``      — per-query cost-model planning
  * ``BuildTableCache``                   — LRU build-table reuse
  * ``WorkloadGenerator`` / ``make_workload`` — scenario mixes
  * ``Tenant`` / ``TenantFairQueue`` / ``AdmissionController`` — the
    multi-tenant SLO layer: weighted fair share across tenants, EDF
    within, cost-priced shed/degrade with structured ``Backpressure``
  * ``open_loop`` — open-loop traffic simulation (Poisson/burst arrivals,
    tenant mixes, hot-tenant skew) for the ``slo_bench`` benchmark
  * ``QueryContext`` / ``BudgetEnforcer`` / ``RetryPolicy`` /
    ``BreakerBoard`` — the resilience layer: cooperative deadline
    preemption with checkpoint/resume, runtime C/G budget enforcement,
    and the retry → degrade → breaker → reference-path recovery ladder
  * ``FaultInjector`` / ``injected`` — deterministic seed-driven fault
    injection at the engine's kernel/h2d/d2h/worker/cache sites
  * ``Tracer`` / ``MetricsRegistry`` / ``CostAudit`` (re-exported from
    ``repro.obs``) — query-lifecycle spans, the labeled-counter registry
    behind ``stats()``, and the predicted-vs-measured cost-model audit
"""
from repro.obs import (CostAudit, DriftDetector, FlightRecorder,
                       MetricsRegistry, NULL_TRACER, NullTracer,
                       SLObjective, SLOMonitor, Tracer)

from .admission import (AdmissionController, AdmissionDecision,
                        Backpressure, Tenant, TenantFairQueue, jain_index)
from .faults import (FaultInjected, FaultInjector, FaultSpec, injected,
                     install as install_fault_injector, maybe_fault)
from .planner import (EXECUTABLE_SCHEMES, SCHEMES, QueryPlan, QueryPlanner)
from .resilience import (BreakerBoard, BudgetEnforcer, BudgetExceeded,
                         Cancelled, DeadlineExceeded, QueryContext,
                         RetryPolicy)
from .service import (GroupByQuery, JoinQuery, JoinQueryService,
                      PriorityAgingQueue, QueryOutcome, QueueFull)
from .table_cache import (BuildTableCache, partition_layout_key,
                          relation_fingerprint, table_nbytes)
from .workload import (MIXES, TrafficEvent, WorkloadGenerator,
                       make_workload, open_loop, zipf_keys)

__all__ = [n for n in dir() if not n.startswith("_")]
