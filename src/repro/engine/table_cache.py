"""Build-table cache — the paper's cache-reuse insight at the query level.

The paper's coupled-architecture win partly comes from the build table
staying resident in the shared cache between phases (§3.3, Table 3:
fine-grained steps "reuse the hash table in cache" where coarse-grained
private tables cannot).  A query *engine* gets the same effect one level
up: across queries, repeated probes against a hot build relation should
find the finished hash table already resident and skip the build phase
entirely.

``BuildTableCache`` is an LRU keyed by a content fingerprint of the build
relation (plus the bucket count, since tables of different geometry are not
interchangeable), bounded by a byte budget over the dense CSR arrays.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict

import jax
import numpy as np

from repro.core.relation import Relation


def relation_fingerprint(rel: Relation, num_buckets: int) -> str:
    """Content hash of a build relation + table geometry.

    Hashes the host bytes of both columns, so regenerating an identical
    relation (same generator, same seed) hits the same cache line even
    though the array objects differ.
    """
    h = hashlib.sha1()
    h.update(np.asarray(rel.key).tobytes())
    h.update(np.asarray(rel.rid).tobytes())
    h.update(f"|n={rel.size}|b={num_buckets}".encode())
    return h.hexdigest()


def table_nbytes(table) -> int:
    return int(sum(x.nbytes for x in jax.tree.leaves(table)))


def partition_layout_key(fingerprint: str, schedule, side: str = "R") -> str:
    """Cache key for a PHJ partitioned layout: content + pass schedule.

    Layouts produced under different radix schedules assign different
    partition ids, so they are not interchangeable.  ``side`` separates
    build ("R") from probe ("S") layouts: both are cached since the
    probe-side satellite, and the pad sentinels baked into a padded layout
    differ per side.
    """
    sched = tuple(int(b) for b in schedule)
    tag = "" if side == "R" else f"|side={side}"
    return f"part:{fingerprint}|sched={sched}{tag}"


class BuildTableCache:
    """LRU cache of finished build state under one byte budget.  Thread-safe.

    Two kinds of entries share the budget and the LRU order:

      * **hash tables** (SHJ) — the finished CSR table; a hit runs
        probe-only.
      * **partitioned layouts** (PHJ) — the build relation after its n1–n3
        radix passes (``partition_layout_key``); a hit skips the build-side
        partition passes, the PHJ analogue of table reuse (ROADMAP open
        item: "caching partitions would extend the reuse story").

    Hit/miss counters are kept per kind so ``stats()`` can attribute reuse.
    """

    def __init__(self, budget_bytes: int = 256 << 20,
                 tenant_budget_bytes=None):
        self.budget_bytes = int(budget_bytes)
        # Optional per-tenant byte cap (ROADMAP item 1 remainder): an int
        # applies the same cap to every tenant, a dict caps only the named
        # tenants.  A tenant over its own cap evicts its own LRU entries
        # *before* the shared-capacity sweep can touch anyone else's.
        self.tenant_budget_bytes = tenant_budget_bytes
        # key -> (obj, nbytes, owner_tenant, kind); the owner is whoever
        # inserted the entry — eviction attribution needs the victim's
        # identity, not just its key.
        self._entries: OrderedDict[str, tuple] = OrderedDict()
        self._tenant_bytes: dict[str, int] = {}
        self._registry = None          # optional MetricsRegistry
        self._lock = threading.Lock()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0
        self.budget_evictions = 0
        self.partition_hits = 0
        self.partition_misses = 0
        self.partition_puts = 0
        self.probe_partition_hits = 0
        self.probe_partition_misses = 0
        self.probe_partition_puts = 0

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key: str):
        """Lookup without touching stats or LRU order.

        The engine peeks before planning: a resident table the planner
        then decides *not* to use (PHJ wins) is neither a hit nor a miss.
        """
        with self._lock:
            ent = self._entries.get(key)
            return ent[0] if ent is not None else None

    def _emit(self, name: str, tenant: str, kind: str) -> None:
        """Per-tenant labeled counter into the attached registry.  Called
        *after* the cache lock is released (the service's lock discipline:
        components do not call into the registry under their own locks)."""
        if self._registry is not None:
            self._registry.inc(name, tenant=tenant, kind=kind)

    def get(self, key: str, tenant: str = "default"):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.misses += 1
            else:
                self._entries.move_to_end(key)
                self.hits += 1
        self._emit("cache_hits" if ent is not None else "cache_misses",
                   tenant, "table")
        return ent[0] if ent is not None else None

    def record_miss(self, tenant: str = "default"):
        """Count a lookup that found nothing (pairs with ``peek``)."""
        with self._lock:
            self.misses += 1
        self._emit("cache_misses", tenant, "table")

    def put(self, key: str, table, tenant: str = "default") -> bool:
        """Insert; evicts LRU entries until under budget.  Returns False if
        the table alone exceeds the whole budget (not cached)."""
        return self._put(key, table, "table", tenant)

    # -- partitioned layouts (PHJ build side) -------------------------------
    def peek_partition(self, key: str):
        """Partition-layout lookup without touching stats or LRU order."""
        return self.peek(key)

    def get_partition(self, key: str, tenant: str = "default"):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.partition_misses += 1
            else:
                self._entries.move_to_end(key)
                self.partition_hits += 1
        self._emit("cache_hits" if ent is not None else "cache_misses",
                   tenant, "partition")
        return ent[0] if ent is not None else None

    def record_partition_miss(self, tenant: str = "default"):
        with self._lock:
            self.partition_misses += 1
        self._emit("cache_misses", tenant, "partition")

    def put_partition(self, key: str, layout,
                      tenant: str = "default") -> bool:
        return self._put(key, layout, "partition", tenant)

    # -- probe-side partitioned layouts (satellite: probe reuse) ------------
    def get_probe_partition(self, key: str, tenant: str = "default"):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.probe_partition_misses += 1
            else:
                self._entries.move_to_end(key)
                self.probe_partition_hits += 1
        self._emit("cache_hits" if ent is not None else "cache_misses",
                   tenant, "probe_partition")
        return ent[0] if ent is not None else None

    def record_probe_partition_miss(self, tenant: str = "default"):
        with self._lock:
            self.probe_partition_misses += 1
        self._emit("cache_misses", tenant, "probe_partition")

    def put_probe_partition(self, key: str, layout,
                            tenant: str = "default") -> bool:
        return self._put(key, layout, "probe_partition", tenant)

    def _tenant_cap(self, tenant: str):
        cap = self.tenant_budget_bytes
        if cap is None:
            return None
        if isinstance(cap, dict):
            cap = cap.get(tenant)
            return None if cap is None else int(cap)
        return int(cap)

    def _evict_locked(self, key: str, evicted: list, reason: str) -> None:
        _, ev_bytes, ev_tenant, ev_kind = self._entries.pop(key)
        self.bytes -= ev_bytes
        left = self._tenant_bytes.get(ev_tenant, 0) - ev_bytes
        if left > 0:
            self._tenant_bytes[ev_tenant] = left
        else:
            self._tenant_bytes.pop(ev_tenant, None)
        self.evictions += 1
        if reason == "tenant_budget":
            self.budget_evictions += 1
        evicted.append((key, ev_bytes, ev_tenant, ev_kind, reason))

    def _put(self, key: str, obj, kind: str,
             tenant: str = "default") -> bool:
        nbytes = table_nbytes(obj)
        if nbytes > self.budget_bytes:
            return False
        cap = self._tenant_cap(tenant)
        if cap is not None and nbytes > cap:
            return False        # mirrors the whole-budget rule: not cached
        evicted = []
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return True
            self._entries[key] = (obj, nbytes, tenant, kind)
            self.bytes += nbytes
            self._tenant_bytes[tenant] = \
                self._tenant_bytes.get(tenant, 0) + nbytes
            if kind == "partition":
                self.partition_puts += 1
            elif kind == "probe_partition":
                self.probe_partition_puts += 1
            else:
                self.puts += 1
            # Per-tenant budget first: a hot tenant over its own cap evicts
            # its OWN oldest entries (never the one just inserted — the
            # entry alone fits the cap, so an older one must exist) before
            # the shared sweep below can push out anyone else's.
            if cap is not None:
                while self._tenant_bytes.get(tenant, 0) > cap:
                    victim = next(k for k, e in self._entries.items()
                                  if e[2] == tenant and k != key)
                    self._evict_locked(victim, evicted, "tenant_budget")
            while self.bytes > self.budget_bytes:
                self._evict_locked(next(iter(self._entries)), evicted,
                                   "capacity")
        # Eviction attribution (outside the lock): which tenant's insert
        # pushed out which tenant's entry, and whether the victim fell to
        # its owner's budget or to shared capacity (ROADMAP item 1).
        if self._registry is not None:
            for ev_key, ev_bytes, ev_tenant, ev_kind, reason in evicted:
                self._registry.inc("cache_evictions", tenant=ev_tenant,
                                   kind=ev_kind)
                if reason == "tenant_budget":
                    self._registry.inc("cache_budget_evictions",
                                       tenant=ev_tenant, kind=ev_kind)
                self._registry.event(
                    "cache_eviction", evictor=tenant, victim=ev_tenant,
                    kind=ev_kind, nbytes=int(ev_bytes), reason=reason,
                    key=ev_key[:16])
        return True

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._tenant_bytes.clear()
            self.bytes = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def partition_hit_rate(self) -> float:
        total = self.partition_hits + self.partition_misses
        return self.partition_hits / total if total else 0.0

    def register_metrics(self, registry, name: str = "cache") -> None:
        """Expose this cache's counters as a ``MetricsRegistry`` collector
        and attach the registry for per-tenant hit/miss/eviction series
        (``cache_hits{tenant=..,kind=..}`` etc.) plus eviction-attribution
        events.

        ``stats()`` reads everything under the cache's own lock, and the
        registry invokes collectors outside its lock, so the engine's
        lock-ordering rule (registry lock is a leaf) holds; per-tenant
        emission likewise happens after the cache lock is released.
        """
        self._registry = registry
        registry.register_collector(name, self.stats)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self.bytes,
                    "budget_bytes": self.budget_bytes, "hits": self.hits,
                    "misses": self.misses, "puts": self.puts,
                    "evictions": self.evictions,
                    "budget_evictions": self.budget_evictions,
                    "tenant_bytes": dict(self._tenant_bytes),
                    "hit_rate": self.hit_rate,
                    "partition_hits": self.partition_hits,
                    "partition_misses": self.partition_misses,
                    "partition_puts": self.partition_puts,
                    "partition_hit_rate": self.partition_hit_rate,
                    "probe_partition_hits": self.probe_partition_hits,
                    "probe_partition_misses": self.probe_partition_misses,
                    "probe_partition_puts": self.probe_partition_puts}
