"""Build-table cache — the paper's cache-reuse insight at the query level.

The paper's coupled-architecture win partly comes from the build table
staying resident in the shared cache between phases (§3.3, Table 3:
fine-grained steps "reuse the hash table in cache" where coarse-grained
private tables cannot).  A query *engine* gets the same effect one level
up: across queries, repeated probes against a hot build relation should
find the finished hash table already resident and skip the build phase
entirely.

``BuildTableCache`` is an LRU keyed by a content fingerprint of the build
relation (plus the bucket count, since tables of different geometry are not
interchangeable), bounded by a byte budget over the dense CSR arrays.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict

import jax
import numpy as np

from repro.core.relation import Relation


def relation_fingerprint(rel: Relation, num_buckets: int) -> str:
    """Content hash of a build relation + table geometry.

    Hashes the host bytes of both columns, so regenerating an identical
    relation (same generator, same seed) hits the same cache line even
    though the array objects differ.
    """
    h = hashlib.sha1()
    h.update(np.asarray(rel.key).tobytes())
    h.update(np.asarray(rel.rid).tobytes())
    h.update(f"|n={rel.size}|b={num_buckets}".encode())
    return h.hexdigest()


def table_nbytes(table) -> int:
    return int(sum(x.nbytes for x in jax.tree.leaves(table)))


class BuildTableCache:
    """LRU hash-table cache under a byte budget.  Thread-safe."""

    def __init__(self, budget_bytes: int = 256 << 20):
        self.budget_bytes = int(budget_bytes)
        self._entries: OrderedDict[str, tuple[object, int]] = OrderedDict()
        self._lock = threading.Lock()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key: str):
        """Lookup without touching stats or LRU order.

        The engine peeks before planning: a resident table the planner
        then decides *not* to use (PHJ wins) is neither a hit nor a miss.
        """
        with self._lock:
            ent = self._entries.get(key)
            return ent[0] if ent is not None else None

    def get(self, key: str):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return ent[0]

    def record_miss(self):
        """Count a lookup that found nothing (pairs with ``peek``)."""
        with self._lock:
            self.misses += 1

    def put(self, key: str, table) -> bool:
        """Insert; evicts LRU entries until under budget.  Returns False if
        the table alone exceeds the whole budget (not cached)."""
        nbytes = table_nbytes(table)
        if nbytes > self.budget_bytes:
            return False
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return True
            self._entries[key] = (table, nbytes)
            self.bytes += nbytes
            self.puts += 1
            while self.bytes > self.budget_bytes:
                _, (_, ev_bytes) = self._entries.popitem(last=False)
                self.bytes -= ev_bytes
                self.evictions += 1
            return True

    def clear(self):
        with self._lock:
            self._entries.clear()
            self.bytes = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self.bytes,
                    "budget_bytes": self.budget_bytes, "hits": self.hits,
                    "misses": self.misses, "puts": self.puts,
                    "evictions": self.evictions,
                    "hit_rate": self.hit_rate}
