"""Concurrent join-query service over one shared ``CoProcessor``.

The repo's benchmark drivers run one hand-configured join at a time; the
paper's headline — keep *both* processor groups busy and reuse resident
state — only pays off under a stream of queries.  ``JoinQueryService``
provides that layer:

  * **admission** — a bounded, tenant-aware two-level queue
    (``TenantFairQueue``: weighted fair share across tenants, EDF within
    one); ``submit`` enqueues (blocking or not), worker threads drain it.
    XLA dispatch is asynchronous, so while one worker's C-group slices
    are in flight another worker's G-group work from a *different* query
    overlaps on the device timeline.
  * **SLO enforcement** — a query with a deadline is priced at admission
    (``AdmissionController``): predicted completion past the deadline
    first *degrades* the query to the planner's cheapest plan, and if
    even that misses, *sheds* it with a structured ``Backpressure`` error
    carrying a retry-after hint (never a silent timeout).
  * **load-aware planning** — each query is planned by ``QueryPlanner``
    (cost-model scheme + algorithm choice) given the outstanding estimated
    seconds per group, so near-tie plans land on the idler group.
  * **build-table cache** — before planning, the build relation is
    fingerprinted against ``BuildTableCache``; a hit skips the build phase
    entirely (probe-only SHJ), a miss on a previously-seen fingerprint
    biases planning toward SHJ so the table becomes cacheable.
  * **feedback** — measured phase timings flow back into the planner's
    online unit-cost scales after every query.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

from repro.core.coprocess import CoProcessor, Timing
from repro.core.hash_table import JoinResult, default_num_buckets
from repro.obs import (CardinalityAudit, CostAudit, DriftDetector,
                       FlightRecorder, MetricsRegistry, NULL_TRACER,
                       SLOMonitor, Tracer, TransferLedger)

from .admission import (AdmissionController, Backpressure, QueueFull,
                        Tenant, TenantFairQueue)
from .faults import (FaultInjected, active as _faults_active,
                     layout_checksum, maybe_corrupt, maybe_fault)
from .planner import QueryPlan, QueryPlanner
from .resilience import (BreakerBoard, BudgetEnforcer, BudgetExceeded,
                         DeadlineExceeded, QueryContext, RetryPolicy)
from .table_cache import (BuildTableCache, partition_layout_key,
                          relation_fingerprint)


@dataclasses.dataclass
class JoinQuery:
    """One join request: build (R) and probe (S) relations plus limits."""

    build: object                 # Relation
    probe: object                 # Relation
    tag: str = "adhoc"
    max_out: int | None = None    # result capacity; defaulted from |S|
    query_id: int = -1
    priority: int = 0             # higher runs earlier (aged, so no starving)
    # Join-variant semantics: "inner" | "semi" | "anti" | "left_outer".
    # Non-inner kinds probe the same (cacheable) build table but emit
    # match flags / unmatched rows instead of the full expansion.
    kind: str = "inner"
    # Multi-tenant SLO fields: ``tenant`` names the workload container the
    # query is billed to; ``deadline_s`` is a relative deadline stamped
    # into the absolute ``deadline_at`` at admission (a tenant's default
    # deadline class applies when neither is set).  ``degraded`` marks a
    # query admission re-priced onto the planner's cheapest plan.
    tenant: str = "default"
    deadline_s: float | None = None
    deadline_at: float | None = None
    degraded: bool = False


@dataclasses.dataclass
class GroupByQuery:
    """One group-by aggregation request (the ops subsystem's operator).

    ``keys.rid`` must index rows of ``values`` (the arange gather
    convention); the service plans it like a join (scheme choice, group
    locks, calibration feedback) and runs ``CoProcessor.groupby``.
    """

    keys: object                  # Relation: key = group key, rid = row id
    values: object                # (n,) int32 value column (host or device)
    tag: str = "groupby"
    query_id: int = -1
    priority: int = 0
    # Legacy int32-wrapping sum accumulator (oracle-parity tests); the
    # default accumulates wide (exact int64 sums).
    wrap32: bool = False
    # Multi-tenant SLO fields (see JoinQuery).
    tenant: str = "default"
    deadline_s: float | None = None
    deadline_at: float | None = None
    degraded: bool = False


@dataclasses.dataclass
class QueryOutcome:
    query_id: int
    tag: str
    plan: QueryPlan
    timing: Timing
    cache_hit: bool
    queued_s: float
    wall_s: float                 # plan + execute (excludes queue wait)
    result: object                # JoinResult | GroupByResult
    partition_cache_hit: bool = False
    priority: int = 0
    probe_partition_cache_hit: bool = False
    # SLO bookkeeping: the billed tenant, whether admission degraded the
    # plan, the inherited absolute deadline (None = best-effort), and
    # whether execution finished inside it (None when no deadline).
    tenant: str = "default"
    degraded: bool = False
    deadline_at: float | None = None
    deadline_hit: bool | None = None
    # Host-boundary bytes the *caller* moved to hand this query its inputs
    # and consume its outputs (H2D + D2H for query intermediates).  The
    # query-pipeline executor fills this in per stage: ~0 on the fused
    # device-resident path, the full gather/re-upload volume on the
    # host-materialize path.  Engine-internal movement (group splits,
    # concats) is tracked separately by Timing.transfer_bytes.
    host_bytes_moved: int = 0
    # Structured per-query trace: the span dicts recorded for this
    # execution (admit -> queue -> plan -> phases), in completion order.
    # None when the service's tracer is disabled.  Deliberately excluded
    # from to_dict() — bench rollups aggregate thousands of outcomes and
    # the Chrome-trace artifact already carries the spans.
    trace: list | None = None

    def to_dict(self) -> dict:
        """Everything a bench rollup needs to segment latency by plan type
        — algorithm/scheme/kind, the cache-hit flags, and the PHJ schedule
        — without re-deriving any of it from the plan object."""
        matches = (int(self.result.count)
                   if isinstance(self.result, JoinResult)
                   else int(self.result.num_groups))
        return {"query_id": self.query_id, "tag": self.tag,
                "priority": self.priority,
                "tenant": self.tenant, "degraded": self.degraded,
                "deadline_hit": self.deadline_hit,
                "algorithm": self.plan.algorithm,
                "scheme": self.plan.scheme,
                "kind": self.plan.kind,
                "table_mode": self.plan.table_mode,
                "cache_hit": self.cache_hit,
                "partition_cache_hit": self.partition_cache_hit,
                "probe_partition_cache_hit": self.probe_partition_cache_hit,
                "schedule": (list(self.plan.schedule)
                             if self.plan.schedule else None),
                "est_s": self.plan.est_s,
                "queued_s": self.queued_s, "wall_s": self.wall_s,
                "matches": matches,
                "host_bytes_moved": int(self.host_bytes_moved),
                "timing": self.timing.to_dict()}


class PriorityAgingQueue:
    """Bounded priority queue: highest priority first, FIFO within a level.

    Waiting items age — effective priority is ``priority + waited/aging_s``
    — so a steady stream of high-priority queries cannot starve a low-
    priority one: after ``aging_s * gap`` seconds the old query outranks
    every fresh arrival.  ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, maxsize: int = 0, *, aging_s: float = 5.0,
                 clock=time.monotonic):
        self.maxsize = int(maxsize)
        self.aging_s = float(aging_s)
        self._clock = clock
        self._items: list[tuple[int, int, float, object]] = []
        self._cond = threading.Condition()
        self._seq = 0

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    qsize = __len__

    def put(self, item, priority: int = 0, block: bool = True,
            timeout: float | None = None):
        with self._cond:
            if self.maxsize > 0:
                if not block and len(self._items) >= self.maxsize:
                    raise queue.Full
                end = None if timeout is None else self._clock() + timeout
                while len(self._items) >= self.maxsize:
                    rem = None if end is None else end - self._clock()
                    if rem is not None and rem <= 0:
                        raise queue.Full
                    if not self._cond.wait(rem):
                        raise queue.Full
            self._seq += 1
            self._items.append((int(priority), self._seq, self._clock(),
                                item))
            self._cond.notify()

    def _pop_best(self):
        now = self._clock()

        def eff(entry):
            prio, seq, enq_t, _ = entry
            # Tie-break on -seq: among equal effective priorities the
            # oldest admission wins (FIFO within a level).
            return (prio + (now - enq_t) / self.aging_s, -seq)

        i = max(range(len(self._items)), key=lambda j: eff(self._items[j]))
        entry = self._items.pop(i)
        self._cond.notify()          # a blocked put may now have room
        return entry[3]

    def get(self, timeout: float | None = None):
        with self._cond:
            end = None if timeout is None else self._clock() + timeout
            while not self._items:
                rem = None if end is None else end - self._clock()
                if rem is not None and rem <= 0:
                    raise queue.Empty
                if not self._cond.wait(rem):
                    raise queue.Empty
            return self._pop_best()

    def get_nowait(self):
        with self._cond:
            if not self._items:
                raise queue.Empty
            return self._pop_best()

    def task_done(self):              # queue.Queue API compat (no join())
        pass


def _plan_groups(plan: QueryPlan) -> set[str]:
    """Which device groups a plan's execution can touch.

    Conservative: any CPU-side share > 0 uses C, any share < 1 uses G;
    split phases additionally merge/concat on C.
    """
    if plan.algorithm in ("phj", "groupby"):
        rats = [plan.partition_ratio, plan.join_ratio]
    else:
        rats = list(plan.probe_ratios)
        if not plan.cached:
            rats += list(plan.build_ratios)
    used = set()
    if any(r > 0.0 for r in rats):
        used.add("C")
    if any(r < 1.0 for r in rats):
        used.add("G")
    if any(0.0 < r < 1.0 for r in rats):
        used.add("C")               # merge/concat runs on the C-group
    return used or {"C"}


class JoinQueryService:
    """Plans and executes a stream of join queries on shared groups."""

    def __init__(self, cp: CoProcessor | None = None,
                 planner: QueryPlanner | None = None, *,
                 cache_budget_bytes: int = 256 << 20,
                 tenant_cache_budget_bytes=None,
                 max_queue: int = 128, num_workers: int = 2,
                 priority_aging_s: float = 5.0,
                 tenants=None, admission_mode: str = "cost",
                 max_deferred: int | None = None,
                 clock=time.monotonic,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None,
                 flight: FlightRecorder | None = None,
                 slo: SLOMonitor | None = None,
                 drift: DriftDetector | None = None,
                 preempt: bool = False,
                 enforce_budgets: bool = False,
                 retry: RetryPolicy | None = None,
                 breakers: BreakerBoard | None = None,
                 budget: BudgetEnforcer | None = None):
        self.cp = cp or CoProcessor()
        self.planner = planner or QueryPlanner()
        self.cache = BuildTableCache(
            cache_budget_bytes, tenant_budget_bytes=tenant_cache_budget_bytes)
        self.num_workers = int(num_workers)
        self._clock = clock
        # Observability: spans (query lifecycle), a metrics registry (all
        # service counters live there — one lock, one coherent snapshot),
        # and the predicted-vs-measured cost-model audit trail.  Pass
        # ``tracer=NULL_TRACER`` to run with tracing disabled.
        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.audit = CostAudit()
        # Data-path observability: every host-boundary byte is attributed
        # to (stage, column, cause) in the transfer ledger — the flat
        # ``host_bytes_moved`` counter is the ledger's intermediate-cause
        # sum view — and every executed stage's (estimated, observed)
        # cardinality pair lands in the cardinality audit.
        self.ledger = TransferLedger(self.metrics)
        self.cardinality = CardinalityAudit()
        # A CoProcessor constructed standalone carries the no-op tracer;
        # adopt it into this service's tracer so its phase spans land in
        # the query lifecycle.  An explicitly-traced CoProcessor is left
        # alone.
        if getattr(self.cp, "tracer", None) is NULL_TRACER:
            self.cp.tracer = self.tracer
        # Deadline-aware multi-tenant admission: the controller prices
        # admit/degrade/shed decisions from planner estimates; the queue
        # serves tenants weighted-fair, EDF within each.  ``fifo`` mode is
        # the count-only baseline slo_bench measures against.
        self.admission = AdmissionController(
            tenants, num_workers=max(1, self.num_workers),
            mode=admission_mode)
        self._queue = TenantFairQueue(
            maxsize=max_queue, aging_s=priority_aging_s, clock=clock,
            weight_fn=self.admission.weight_of,
            fifo=(admission_mode == "fifo"))
        # Deferred (pipeline-stage) submissions are bounded too: each
        # pending stage holds one slot, so a deep or wide pipeline blocks
        # (or bounces, block=False) instead of spawning unbounded threads.
        self._deferred_sem = threading.BoundedSemaphore(
            max_deferred if max_deferred is not None
            else max(1, int(max_queue) or 128))
        self._workers: list[threading.Thread] = []
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._loads = {"C": 0.0, "G": 0.0}
        self._seen_fingerprints: set[str] = set()
        self._observed_sigs: set[tuple] = set()
        self._inflight = 0
        self._exec_epoch = 0
        # Fingerprint memo keyed by array identity: hot-table traffic
        # re-submits the same Relation objects, and re-hashing 8 bytes per
        # tuple on every repeat would tax exactly the queries the cache
        # makes cheap.  Held references keep the ids stable; bounded FIFO.
        self._fp_cache: dict = {}
        # Service counters live in the metrics registry (per-tenant
        # labeled series; ``stats()`` reads them back in one snapshot).
        # Point-in-time views of components are registered as collectors
        # so the same snapshot carries queue depth, cache and planner
        # state, calibration version ticks and the audit summary.
        self.cache.register_metrics(self.metrics)
        self.metrics.register_collector("queue_depth",
                                        lambda: len(self._queue))
        self.metrics.register_collector("planner", self.planner.stats)
        self.metrics.register_collector(
            "calibration_version", lambda: int(self.planner.online.version))
        self.metrics.register_collector("prediction_error",
                                        self.audit.summary)
        self.metrics.register_collector("cardinality_error",
                                        self.cardinality.summary)
        self.metrics.register_collector("host_transfer_ledger",
                                        self.ledger.summary)
        # The closed loop: a flight recorder of recent lifecycles (dumps
        # itself on failures / shed storms / miss bursts), an SLO burn-
        # rate monitor over the per-tenant counters, and a drift detector
        # on the audit trail that flags stale sticky plans for re-pricing
        # and feeds per-tenant safety margins back into admission.  All
        # on by default; each is a bounded ring plus O(1) updates.
        self.flight = flight if flight is not None else \
            FlightRecorder(clock=clock)
        self.slo = slo if slo is not None else \
            SLOMonitor(self.metrics, clock=clock, tracer=self.tracer)
        self.drift = drift if drift is not None else DriftDetector(
            metrics=self.metrics, tracer=self.tracer,
            on_drift=self._on_drift, on_margin=self.admission.set_margin,
            clock=clock)
        self.audit.add_listener(self.drift.observe_record)
        self.metrics.register_collector("flight", self.flight.summary)
        self.metrics.register_collector("slo", self.slo.summary)
        self.metrics.register_collector("drift", self.drift.summary)
        self.metrics.set_gauge("audit_capacity",
                               float(self.audit.capacity))
        # Pre-seed so snapshot()["host_bytes_moved"] is always present —
        # the fused data path's whole point is to never increment it.
        self.metrics.inc("host_bytes_moved", 0)
        # Resilience layer (see ``engine.resilience``): cooperative
        # deadline preemption (``preempt=True`` threads a QueryContext
        # into the kernels, checked at pass boundaries), runtime C/G
        # budget enforcement off the measured-cost audit stream
        # (``enforce_budgets=True``), and the recovery ladder — bounded
        # retries for transient faults, one degraded retry, per-
        # (algorithm, scheme) circuit breakers quarantining a failing
        # kernel variant to the NumPy reference path.  All off by
        # default: the defaults keep every execution byte-identical to
        # the pre-resilience service.
        self.preempt = bool(preempt)
        self.retry = retry if retry is not None else RetryPolicy()
        self.breakers = breakers if breakers is not None else BreakerBoard(
            clock=clock, metrics=self.metrics, flight=self.flight)
        self.budget = budget
        if enforce_budgets and self.budget is None:
            self.budget = BudgetEnforcer(self.admission, clock=clock,
                                         metrics=self.metrics)
        if self.budget is not None:
            self.audit.add_listener(self.budget.on_record)
            self.metrics.register_collector("budget", self.budget.summary)
        self.metrics.register_collector("breakers", self.breakers.summary)
        self._closing = False
        self._busy_workers = 0
        # Injector-era layout checksums (content sums stored at cache-
        # insert time, validated at reuse); empty — and never consulted —
        # when no fault injector is installed.
        self._layout_sums: dict = {}
        for name in self._RESILIENCE_COUNTERS:
            self.metrics.inc(name, 0)

    # Per-tenant counter names mirrored into the registry (and the exact
    # key set ``stats()["tenants"][t]`` has always exposed).
    _TENANT_COUNTERS = ("admitted", "rejected", "shed", "degraded",
                        "completed", "deadline_hits", "deadline_misses")

    # Resilience counters pre-seeded at construction so ``stats()`` and
    # bench payloads always carry them (zero = nothing happened, absent =
    # nothing measured).
    _RESILIENCE_COUNTERS = (
        "preemptions", "budget_throttles", "retries", "worker_restarts",
        "checkpoints", "partition_resumes", "breaker_short_circuits",
        "cache_validation_failures", "cache_insert_failures",
        "cancelled_on_close")

    def _count(self, name: str, tenant: str | None = None) -> None:
        """Bump a service counter (and its per-tenant series).

        Never called under ``self._lock`` — the registry lock is a leaf
        lock (see ``MetricsRegistry``), which is what makes ``stats()``
        one coherent pass instead of the old counters-then-components
        split."""
        if tenant is None:
            self.metrics.inc(name)
        else:
            self.metrics.inc(name, tenant=tenant)

    def _admission_event(self, action: str, bp: Backpressure) -> None:
        """Persist one shed/reject decision: bump its counter and emit a
        structured event (reason, predicted_s, deadline_s,
        retry_after_s) into the registry plus an instant into the trace,
        so consumers read admission decisions from metrics instead of
        re-deriving them from raised ``Backpressure`` exceptions."""
        self._count("shed" if action == "shed" else "rejected", bp.tenant)
        self.metrics.event("admission", action=action, **bp.to_dict())
        self.tracer.instant(action, tenant=bp.tenant,
                            query_id=bp.query_id, reason=bp.reason)
        self.flight.record_admission(action, **bp.to_dict())
        self.slo.evaluate()

    # Which algorithm's sticky plans a drifted audit phase invalidates
    # ("partition" is shared by phj and groupby — match any algorithm).
    _DRIFT_ALGO = {"build": "shj", "probe": "shj", "join": "phj",
                   "agg": "groupby", "partition": None}

    def _on_drift(self, phase: str, scheme: str, stats: dict) -> None:
        """Sustained cost-model drift on (phase, scheme): flag the
        affected sticky plans for re-pricing through the planner's
        existing replan-hysteresis path."""
        flagged = self.planner.flag_replan(
            algorithm=self._DRIFT_ALGO.get(phase), scheme=scheme)
        if flagged:
            self.metrics.inc("plans_flagged_for_replan", flagged)

    # Read-only counter views (the attribute API the service always had).
    def _counter_total(self, name: str) -> int:
        return int(self.metrics.counter_value(name))

    admitted = property(lambda self: self._counter_total("admitted"))
    rejected = property(lambda self: self._counter_total("rejected"))
    completed = property(lambda self: self._counter_total("completed"))
    failed = property(lambda self: self._counter_total("failed"))
    shed = property(lambda self: self._counter_total("shed"))
    degraded = property(lambda self: self._counter_total("degraded"))
    host_bytes_moved = property(
        lambda self: self._counter_total("host_bytes_moved"))

    def note_host_bytes(self, nbytes: int, *, cause: str = "handoff",
                        stage: str = "-", column: str = "-",
                        direction: str = "d2h",
                        tenant: str = "default") -> None:
        """Attribute caller-side host-boundary traffic through the ledger.

        The ledger increments ``host_bytes_moved`` for every intermediate
        cause (``result`` bytes are attributed but excluded — final result
        delivery was never counted as intermediate traffic), so the flat
        counter stays a sum view over the ledger.
        """
        self.ledger.record(nbytes, cause=cause, stage=stage, column=column,
                           direction=direction, tenant=tenant)

    def _fingerprint(self, rel, num_buckets: int, *,
                     stage: str = "-", column: str = "key",
                     tenant: str = "default") -> str:
        # Structural fast path: a relation carrying an fp_hint (every
        # pipeline-built stage input does) is keyed without touching the
        # array contents — no D2H pull, nothing for the ledger.
        hint = getattr(rel, "fp_hint", None)
        if hint:
            return f"struct:{hint}|b={num_buckets}"
        memo_key = (id(rel.rid), id(rel.key), num_buckets)
        with self._lock:
            hit = self._fp_cache.get(memo_key)
            if hit is not None:
                return hit[0]
        # Content hash of a hint-less relation: for device-resident arrays
        # this pulls both columns across the boundary — attributed under
        # the ledger's ``fingerprint`` cause (memo-missed pulls only; a
        # repeat of the same array objects hits the memo above).
        pulled = sum(int(getattr(col, "nbytes", 0))
                     for col in (rel.rid, rel.key)
                     if not isinstance(col, np.ndarray))
        fp = relation_fingerprint(rel, num_buckets)
        if pulled:
            self.ledger.record(pulled, cause="fingerprint", stage=stage,
                               column=column, direction="d2h",
                               tenant=tenant)
        with self._lock:
            if len(self._fp_cache) > 256:
                self._fp_cache.clear()
            self._fp_cache[memo_key] = (fp, rel.rid, rel.key)
        return fp

    # -- synchronous execution path (also what workers run) -----------------
    def _make_ctx(self, q) -> QueryContext | None:
        """The query's cooperative control block — None when neither
        preemption nor budget enforcement is on (the kernels then take
        their exact pre-resilience fused paths)."""
        if not self.preempt and self.budget is None:
            return None
        return QueryContext(
            query_id=q.query_id, tenant=q.tenant,
            deadline_at=(q.deadline_at if self.preempt else None),
            clock=self._clock, enforcer=self.budget,
            on_throttle=self._on_throttle)

    def _on_throttle(self, tenant: str, delay_s: float) -> None:
        self.metrics.inc("budget_throttles", tenant=tenant)
        self.tracer.instant("budget_throttle", tenant=tenant)

    def _note_preempt(self, e: Backpressure, where: str = "") -> None:
        """Account one mid-flight preemption (deadline / budget / cancel)
        exactly once — a structured service decision, not a failure."""
        if getattr(e, "_svc_preempt_counted", False):
            return
        e._svc_preempt_counted = True
        cause = getattr(e, "reason", "backpressure")
        self.metrics.inc("preemptions", tenant=e.tenant, cause=cause)
        self.metrics.event("preempt", cause=cause, tenant=e.tenant,
                           query_id=e.query_id, where=where)
        self.tracer.instant("preempt", tenant=e.tenant,
                            query_id=e.query_id, reason=cause)
        self.flight.record_resilience("preempt", cause=cause,
                                      tenant=e.tenant,
                                      query_id=e.query_id, where=where)

    def execute(self, q, *, enqueued_at: float | None = None
                ) -> QueryOutcome:
        """Run one query now.  ``enqueued_at`` (a ``perf_counter`` stamp)
        is how queue wait reaches the outcome's ``queued_s`` — the direct
        path has no queue, so it reports 0.0 honestly."""
        queued_s = (0.0 if enqueued_at is None
                    else max(0.0, time.perf_counter() - enqueued_at))
        # Direct executions bypass submit(): stamp the deadline here so
        # the outcome's verdict (and deferred inheritance) still work.
        self._stamp_deadline(q, self._clock())
        ctx = self._make_ctx(q)
        try:
            if ctx is not None:
                # A query whose deadline already passed while queued is
                # dropped here in O(1) — the biggest capacity saver under
                # overload: no device seconds burned on a guaranteed miss.
                ctx.check("pre_execute")
            if isinstance(q, GroupByQuery):
                return self._execute_groupby(q, queued_s, ctx)
            return self._execute_join(q, queued_s, ctx)
        except Backpressure as e:
            self._note_preempt(e, where="execute")
            raise

    def _obs_begin(self, q):
        """Allocate the query's trace correlation key (``q_key``) and
        retro-record its queue-wait lane span (``_obs_enq`` was stamped
        at submit on the tracer clock; queue wait starts on the caller's
        thread and ends on a worker's, so it cannot nest on either
        thread's stack — it becomes an async lane interval)."""
        tr = self.tracer
        if not tr.enabled:
            return None
        key = getattr(q, "_obs_key", None)
        if key is None:
            key = q._obs_key = tr.next_key()
        enq = getattr(q, "_obs_enq", None)
        if enq is not None:
            q._obs_enq = None
            tr.lane("queue", enq, tr.now(), q_key=key,
                    query_id=q.query_id, tenant=q.tenant, tag=q.tag)
        return key

    def _finish_outcome(self, q) -> bool | None:
        """Completion bookkeeping: totals, per-tenant counts, deadline
        verdict (measured on the service clock the deadline was stamped
        with)."""
        deadline_hit = None
        if q.deadline_at is not None:
            deadline_hit = bool(self._clock() <= q.deadline_at)
        self._count("completed", q.tenant)
        if deadline_hit is True:
            self._count("deadline_hits", q.tenant)
        elif deadline_hit is False:
            self._count("deadline_misses", q.tenant)
        self.slo.evaluate()
        return deadline_hit

    def _execute_join(self, q: JoinQuery, queued_s: float = 0.0,
                      ctx: QueryContext | None = None) -> QueryOutcome:
        obs_key = self._obs_begin(q)
        with self.tracer.span("query", q_key=obs_key, query_id=q.query_id,
                              tenant=q.tenant, tag=q.tag,
                              kind=q.kind) as qspan:
            result, plan, timing, flags = self._run_join(q, qspan, ctx)
        # Audit EVERY executed plan (phase, scheme, est_s, measured_s):
        # calibration's warm/solo gating filters out contended samples,
        # but measuring how wrong the solo-time estimate was *under
        # contention* is exactly the audit's job.
        self.audit.record(self.planner.phase_pairs(plan, timing),
                          tenant=q.tenant, query_id=q.query_id)
        deadline_hit = self._finish_outcome(q)
        cache_hit, partition_hit, probe_partition_hit, wall = flags
        outcome = QueryOutcome(q.query_id, q.tag, plan, timing, cache_hit,
                               queued_s, wall, result,
                               partition_cache_hit=partition_hit,
                               probe_partition_cache_hit=probe_partition_hit,
                               priority=q.priority, tenant=q.tenant,
                               degraded=q.degraded,
                               deadline_at=q.deadline_at,
                               deadline_hit=deadline_hit)
        if obs_key is not None:
            outcome.trace = self.tracer.spans_for(obs_key)
        self.metrics.observe("query_latency_s", queued_s + wall,
                             tenant=q.tenant)
        self.flight.record_outcome(outcome)
        return outcome

    # -- resilience plumbing -------------------------------------------------
    def _peek_layout(self, layout_key):
        """Partition-layout cache peek with injector-era validation: when
        a fault injector is live, a stored layout whose content checksum
        no longer matches the one recorded at insert (a ``corrupt``-mode
        fault) is treated as a miss — corruption must surface as a cache
        miss, never as a wrong join result.  Checksums cost a D2H pull,
        so none of this runs in normal serving."""
        rel = self.cache.peek_partition(layout_key)
        if rel is None or not _faults_active():
            return rel
        expect = self._layout_sums.get(layout_key)
        if expect is not None and layout_checksum(rel) != expect:
            self.metrics.inc("cache_validation_failures")
            self.flight.record_resilience("cache_corruption",
                                          key=str(layout_key)[:120])
            return None
        return rel

    def _put_layout(self, putter, layout_key, rel, tenant: str) -> None:
        """Cache insert through the ``cache_insert`` fault site.  A raise-
        mode fault skips the insert (a failed cache write must never fail
        the query that computed the layout); a corrupt-mode fault stores
        a flipped layout whose checksum — taken from the *clean* relation
        — exposes it at the next peek."""
        if not _faults_active():
            putter(layout_key, rel, tenant)
            return
        clean_sum = layout_checksum(rel)
        try:
            maybe_fault("cache_insert")
        except FaultInjected as e:
            self.metrics.inc("cache_insert_failures")
            self.flight.record_resilience("cache_insert_failed",
                                          error=repr(e)[:120])
            return
        self._layout_sums[layout_key] = clean_sum
        putter(layout_key, maybe_corrupt("cache_insert", rel), tenant)

    def _store_checkpoints(self, q, ctx: QueryContext, plan) -> None:
        """Persist a preempted query's partial partition layouts under
        their completed-pass schedule-prefix keys, so a re-admitted run
        resumes at ``start_pass = k`` instead of restarting."""
        sched = tuple(plan.schedule or ())
        for tag, (rel, k) in list(ctx.partials.items()):
            base = ctx.meta.get("pkey_base" if tag == "R" else "skey_base")
            if base is None or not 0 < k < len(sched):
                continue
            prefix = sched[:k]
            if tag == "R":
                pk = partition_layout_key(base, prefix)
                self._put_layout(self.cache.put_partition, pk, rel,
                                 q.tenant)
            else:
                pk = partition_layout_key(base, prefix, side="S")
                self._put_layout(self.cache.put_probe_partition, pk, rel,
                                 q.tenant)
            self.metrics.inc("checkpoints", tenant=q.tenant)
            self.flight.record_resilience(
                "checkpoint", tag=tag, passes_done=k,
                schedule=list(sched), query_id=q.query_id,
                tenant=q.tenant)

    def _resume_probe(self, base_fp: str, schedule, side: str = "R"):
        """Longest-first probe of schedule-prefix checkpoint keys.
        Returns ``(partial layout, completed passes)`` or ``(None, None)``."""
        from repro.core.phj import schedule_prefixes
        if not self.preempt or not schedule:
            return None, None
        for prefix in schedule_prefixes(schedule):
            pk = (partition_layout_key(base_fp, prefix) if side == "R"
                  else partition_layout_key(base_fp, prefix, side="S"))
            cand = self._peek_layout(pk)
            if cand is not None:
                return cand, len(prefix)
        return None, None

    def _run_join(self, q: JoinQuery, qspan=None,
                  ctx: QueryContext | None = None):
        """Plan + execute one join (the body of ``_execute_join``, run
        inside its query span).  Returns ``(result, plan, timing,
        (cache_hit, partition_hit, probe_partition_hit, wall_s))``."""
        t0 = time.perf_counter()
        build_n, probe_n = q.build.size, q.probe.size
        # ``is None`` (not falsy) — an explicit max_out=0 is a legitimate
        # capacity for expected-empty probes and must not be replaced by
        # the heuristic default.
        max_out = (q.max_out if q.max_out is not None
                   else 4 * probe_n + 1024)
        nb = default_num_buckets(build_n)
        key = self._fingerprint(q.build, nb, stage=q.tag,
                                column="build.key", tenant=q.tenant)
        table = self.cache.peek(key)
        with self._lock:
            seen = key in self._seen_fingerprints
            self._seen_fingerprints.add(key)
            c_load, g_load = self._loads["C"], self._loads["G"]
        with self.tracer.span("plan"):
            if q.degraded:
                # Deadline-degraded: admission promised the cheapest plan.
                plan = self.planner.choose_degraded(
                    build_n, probe_n, max_out=max_out,
                    cached=table is not None, kind=q.kind)
            else:
                plan = self.planner.choose(
                    build_n, probe_n, max_out=max_out,
                    cached=table is not None,
                    expect_reuse=seen and table is None,
                    c_load=c_load, g_load=g_load, kind=q.kind)
        if qspan is not None:
            # Ambient for the phase spans opened below on this thread.
            qspan.set(algorithm=plan.algorithm, scheme=plan.scheme)
        # Circuit breaker: a quarantined (algorithm, scheme) variant runs
        # on the NumPy reference path — slower, but correct and immune to
        # whatever is killing the kernels.  HALF_OPEN lets one trial
        # through onto the real path.
        plan_key = (plan.algorithm, plan.scheme)
        if not self.breakers.allow(plan_key):
            self.metrics.inc("breaker_short_circuits", tenant=q.tenant)
            self.flight.record_resilience(
                "breaker_short_circuit", phase=plan.algorithm,
                scheme=plan.scheme, query_id=q.query_id, tenant=q.tenant)
            result = self._reference_join_result(q, max_out)
            timing = Timing(tracer=self.cp.tracer)
            timing.notes["reference_path"] = True
            wall = time.perf_counter() - t0
            timing.phase_s["reference"] = wall
            timing.wall_s = wall
            return result, plan, timing, (False, False, False, wall)
        share = plan.c_share
        with self._lock:
            self._loads["C"] += plan.est_s * share
            self._loads["G"] += plan.est_s * (1.0 - share)
            self._inflight += 1
            inflight_at_start = self._inflight
            start_epoch = self._exec_epoch
            self._exec_epoch += 1
        # Execution is serialized per device group (two collective programs
        # interleaved on one group deadlock XLA's rendezvous); disjoint
        # plans — one C-only, one G-only — run concurrently, which is the
        # overlap the admission queue exists to create.  Fixed C-then-G
        # acquisition order.
        held = [self.cp.group_locks[g] for g in ("C", "G")
                if g in _plan_groups(plan)]
        for lock in held:
            lock.acquire()
        partition_hit = False
        probe_partition_hit = False
        try:
            from repro.ops.join_variants import probe_table_variant
            cache_hit = table is not None and plan.cached
            if cache_hit:
                self.cache.get(key, q.tenant)  # record the hit + LRU touch
                timing = Timing(tracer=self.cp.tracer)
                timing.phase_s["build"] = 0.0
                result, timing = probe_table_variant(
                    self.cp, q.probe, table, kind=q.kind, max_out=max_out,
                    ratios=plan.probe_ratios, timing=timing)
            elif plan.algorithm == "phj":
                # Partition-layout cache: a repeated PHJ build OR probe
                # side skips its n1–n3 passes off the resident partitioned
                # layout (keyed by content + schedule + side; hits counted
                # separately per side).
                pkey = partition_layout_key(key, plan.schedule)
                layout = self._peek_layout(pkey)
                # Probe layouts depend only on content + schedule — NOT on
                # the build table's bucket count — so the same probe
                # relation re-probed against differently-sized build
                # tables still hits (fingerprinted at num_buckets=0).
                probe_fp = self._fingerprint(q.probe, 0, stage=q.tag,
                                             column="probe.key",
                                             tenant=q.tenant)
                skey = partition_layout_key(probe_fp, plan.schedule,
                                            side="S")
                probe_layout = self._peek_layout(skey)
                # Checkpoint resume: a full-layout miss probes the
                # schedule-prefix keys a preempted run stored; a hit
                # resumes partitioning at its completed-pass count.
                build_resume = probe_resume = None
                if layout is None:
                    layout, build_resume = self._resume_probe(
                        key, plan.schedule)
                if probe_layout is None:
                    probe_layout, probe_resume = self._resume_probe(
                        probe_fp, plan.schedule, side="S")
                for tag, k in (("R", build_resume), ("S", probe_resume)):
                    if k is not None:
                        self.metrics.inc("partition_resumes",
                                         tenant=q.tenant)
                        self.flight.record_resilience(
                            "partition_resume", tag=tag, passes_done=k,
                            query_id=q.query_id, tenant=q.tenant)
                if ctx is not None:
                    ctx.meta.update(pkey_base=key, skey_base=probe_fp)
                parts_out: dict = {}
                result, timing = self.cp.phj(
                    q.build, q.probe, schedule=plan.schedule,
                    shj_bits=plan.shj_bits, max_out=max_out,
                    partition_ratio=plan.partition_ratio,
                    join_ratio=plan.join_ratio,
                    build_parts=layout, probe_parts=probe_layout,
                    parts_out=parts_out, ctx=ctx,
                    build_resume=build_resume, probe_resume=probe_resume)
                if layout is not None and build_resume is None:
                    self.cache.get_partition(pkey, q.tenant)  # hit + touch
                    partition_hit = True
                else:
                    self.cache.record_partition_miss(q.tenant)
                    self._put_layout(self.cache.put_partition, pkey,
                                     parts_out["R"], q.tenant)
                if probe_layout is not None and probe_resume is None:
                    self.cache.get_probe_partition(skey, q.tenant)
                    probe_partition_hit = True
                else:
                    self.cache.record_probe_partition_miss(q.tenant)
                    self._put_layout(self.cache.put_probe_partition, skey,
                                     parts_out["S"], q.tenant)
            else:
                # Miss accounting mirrors hit accounting: only a plan that
                # would have *used* a resident table counts as a miss (a
                # PHJ plan never wants one, in either direction).
                self.cache.record_miss(q.tenant)
                table, timing = self.cp.build_table(
                    q.build, num_buckets=plan.num_buckets,
                    ratios=plan.build_ratios, table_mode=plan.table_mode)
                result, timing = probe_table_variant(
                    self.cp, q.probe, table, kind=q.kind, max_out=max_out,
                    ratios=plan.probe_ratios, timing=timing)
                self.cache.put(key, table, q.tenant)
        except Backpressure:
            # Preempted mid-flight (deadline / budget / cancel): free a
            # half-open breaker trial without a verdict and checkpoint
            # any completed partition passes for the re-admitted run.
            self.breakers.release(plan_key)
            if ctx is not None and ctx.partials:
                self._store_checkpoints(q, ctx, plan)
            raise
        except Exception as e:
            # Tag the failing plan variant so the recovery ladder can
            # feed the breaker for this (algorithm, scheme).
            e._svc_plan_key = plan_key
            raise
        finally:
            for lock in reversed(held):
                lock.release()
            with self._lock:
                self._loads["C"] -= plan.est_s * share
                self._loads["G"] -= plan.est_s * (1.0 - share)
                self._inflight -= 1
                # Solo = nothing was running when we started and nothing
                # started while we ran: the measured time is free of
                # cross-query CPU contention.
                solo = (inflight_at_start == 1
                        and self._exec_epoch == start_epoch + 1)
        # Clean execution: reset the variant's consecutive-failure count
        # (and close a successful half-open trial).
        self.breakers.record_success(plan_key)
        # Feedback gates: (a) the first execution of an (algorithm, scheme,
        # shape) signature is dominated by XLA compilation; (b) a query
        # that overlapped another execution measured shared-core contention
        # on top of its own cost — one tainted sample can exile a scheme
        # for good (its scale only corrects when it runs again).  Only
        # warmed, solo samples calibrate the model.  (Ratios are
        # deliberately excluded from the signature: they come from the
        # unscaled sweep, so they are a function of it already.)
        # max_out is part of the signature: it reaches jit static args, so
        # a different value recompiles even at identical relation shapes.
        sig = (plan.algorithm, plan.scheme, plan.cached, plan.kind,
               build_n, probe_n, max_out)
        with self._lock:
            warmed = sig in self._observed_sigs
            self._observed_sigs.add(sig)
        # A partition-cache hit (either side) skipped partition passes, so
        # its partition phase time is not a clean sample of the estimate;
        # a tiny query measures dispatch overhead, not per-item cost (see
        # QueryPlanner.min_feedback_items).
        big_enough = (build_n + probe_n
                      >= getattr(self.planner, "min_feedback_items", 0))
        if (warmed and solo and not partition_hit
                and not probe_partition_hit and big_enough):
            self.planner.observe(plan, timing)
        wall = time.perf_counter() - t0
        return result, plan, timing, (cache_hit, partition_hit,
                                      probe_partition_hit, wall)

    # -- group-by aggregation (ops subsystem) --------------------------------
    def _execute_groupby(self, q: GroupByQuery, queued_s: float = 0.0,
                         ctx: QueryContext | None = None) -> QueryOutcome:
        """Plan + run one group-by under the same locks/feedback regime."""
        obs_key = self._obs_begin(q)
        with self.tracer.span("query", q_key=obs_key, query_id=q.query_id,
                              tenant=q.tenant, tag=q.tag,
                              kind="groupby") as qspan:
            result, plan, timing, wall = self._run_groupby(q, qspan, ctx)
        self.audit.record(self.planner.phase_pairs(plan, timing),
                          tenant=q.tenant, query_id=q.query_id)
        deadline_hit = self._finish_outcome(q)
        outcome = QueryOutcome(q.query_id, q.tag, plan, timing, False,
                               queued_s, wall, result, priority=q.priority,
                               tenant=q.tenant, degraded=q.degraded,
                               deadline_at=q.deadline_at,
                               deadline_hit=deadline_hit)
        if obs_key is not None:
            outcome.trace = self.tracer.spans_for(obs_key)
        self.metrics.observe("query_latency_s", queued_s + wall,
                             tenant=q.tenant)
        self.flight.record_outcome(outcome)
        return outcome

    def _run_groupby(self, q: GroupByQuery, qspan=None,
                     ctx: QueryContext | None = None):
        from repro.ops.groupby import groupby_coprocessed
        t0 = time.perf_counter()
        n = q.keys.size
        with self._lock:
            c_load, g_load = self._loads["C"], self._loads["G"]
        with self.tracer.span("plan"):
            plan = self.planner.choose_groupby(n, c_load=c_load,
                                               g_load=g_load)
        if qspan is not None:
            qspan.set(algorithm=plan.algorithm, scheme=plan.scheme)
        plan_key = (plan.algorithm, plan.scheme)
        if not self.breakers.allow(plan_key):
            self.metrics.inc("breaker_short_circuits", tenant=q.tenant)
            self.flight.record_resilience(
                "breaker_short_circuit", phase=plan.algorithm,
                scheme=plan.scheme, query_id=q.query_id, tenant=q.tenant)
            result = self._reference_groupby_result(q)
            timing = Timing(tracer=self.cp.tracer)
            timing.notes["reference_path"] = True
            wall = time.perf_counter() - t0
            timing.phase_s["reference"] = wall
            timing.wall_s = wall
            return result, plan, timing, wall
        share = plan.c_share
        with self._lock:
            self._loads["C"] += plan.est_s * share
            self._loads["G"] += plan.est_s * (1.0 - share)
            self._inflight += 1
            inflight_at_start = self._inflight
            start_epoch = self._exec_epoch
            self._exec_epoch += 1
        held = [self.cp.group_locks[g] for g in ("C", "G")
                if g in _plan_groups(plan)]
        for lock in held:
            lock.acquire()
        try:
            result, timing = groupby_coprocessed(
                self.cp, q.keys, q.values, schedule=plan.schedule,
                partition_ratio=plan.partition_ratio,
                agg_ratio=plan.join_ratio, wrap32=q.wrap32, ctx=ctx)
        except Backpressure:
            self.breakers.release(plan_key)
            raise
        except Exception as e:
            e._svc_plan_key = plan_key
            raise
        finally:
            for lock in reversed(held):
                lock.release()
            with self._lock:
                self._loads["C"] -= plan.est_s * share
                self._loads["G"] -= plan.est_s * (1.0 - share)
                self._inflight -= 1
                solo = (inflight_at_start == 1
                        and self._exec_epoch == start_epoch + 1)
        self.breakers.record_success(plan_key)
        # wrap32 belongs in the warm-up signature: the wide (int64 bit-
        # chunk) and wrapping accumulators compile different executables,
        # so the first wide run after a wrap32 run of the same size is a
        # fresh XLA compile — treating it as "warmed" would calibrate the
        # cost model on compile time.
        sig = ("groupby", plan.scheme, n, q.wrap32)
        with self._lock:
            warmed = sig in self._observed_sigs
            self._observed_sigs.add(sig)
        big_enough = n >= getattr(self.planner, "min_feedback_items", 0)
        if warmed and solo and big_enough:
            self.planner.observe(plan, timing)
        wall = time.perf_counter() - t0
        return result, plan, timing, wall

    # -- recovery ladder (reference path, retries, breakers) -----------------
    def _reference_join_result(self, q: JoinQuery,
                               max_out: int) -> JoinResult:
        """NumPy reference join honoring the query's variant kind — the
        breaker's quarantine destination and the ladder's last rung.  No
        device work at all, so it cannot share the kernels' failure mode."""
        from repro.ops.join_variants import join_variant_oracle
        pairs = join_variant_oracle(q.build, q.probe, q.kind)
        cnt = min(len(pairs), int(max_out))
        probe_rid = np.asarray(pairs[:cnt, 0], dtype=np.int32)
        build_rid = np.asarray(pairs[:cnt, 1], dtype=np.int32)
        return JoinResult(probe_rid, build_rid, np.int32(cnt))

    def _reference_groupby_result(self, q: GroupByQuery):
        """NumPy reference group-by (the tested oracle) for the ladder."""
        from repro.core.hash_table import INVALID
        from repro.ops.groupby import groupby_ref
        keys = np.asarray(q.keys.key)
        rid = np.asarray(q.keys.rid)
        vals = np.asarray(q.values)
        safe = np.clip(rid, 0, max(vals.shape[0] - 1, 0))
        gathered = np.where(rid >= 0,
                            vals[safe] if vals.shape[0] else 0,
                            0).astype(np.int64)
        live = rid != int(INVALID)
        return groupby_ref(keys[live], gathered[live], wrap32=q.wrap32)

    def _execute_reference(self, q, queued_s: float = 0.0) -> QueryOutcome:
        """Full reference-path execution with honest outcome bookkeeping
        (completed / deadline verdict / latency / flight record)."""
        t0 = time.perf_counter()
        if isinstance(q, GroupByQuery):
            result = self._reference_groupby_result(q)
            plan = self.planner.choose_groupby(q.keys.size, c_load=0.0,
                                               g_load=0.0, record=False)
        else:
            max_out = (q.max_out if q.max_out is not None
                       else 4 * q.probe.size + 1024)
            result = self._reference_join_result(q, max_out)
            plan = self.planner.choose_degraded(
                q.build.size, q.probe.size, max_out=max_out,
                cached=False, kind=q.kind, record=False)
        timing = Timing(tracer=self.cp.tracer)
        timing.notes["reference_path"] = True
        wall = time.perf_counter() - t0
        timing.phase_s["reference"] = wall
        timing.wall_s = wall
        deadline_hit = self._finish_outcome(q)
        outcome = QueryOutcome(q.query_id, q.tag, plan, timing, False,
                               queued_s, wall, result, priority=q.priority,
                               tenant=q.tenant, degraded=q.degraded,
                               deadline_at=q.deadline_at,
                               deadline_hit=deadline_hit)
        self.metrics.observe("query_latency_s", queued_s + wall,
                             tenant=q.tenant)
        self.flight.record_outcome(outcome)
        return outcome

    def _note_recovery(self, what: str, q, e, **extra) -> None:
        self.metrics.event("recovery", what=what, tenant=q.tenant,
                           query_id=q.query_id, error=repr(e)[:120],
                           **extra)
        self.tracer.instant(what, tenant=q.tenant, query_id=q.query_id)
        self.flight.record_resilience(what, tenant=q.tenant,
                                      query_id=q.query_id,
                                      error=repr(e)[:120], **extra)

    def _run_with_recovery(self, q, *, enqueued_at: float | None = None
                           ) -> QueryOutcome:
        """The worker-path recovery ladder, engaged for *transient*
        failures only (deterministic errors — bad shapes, malformed
        queries — still fail fast):

          1. bounded retries with seeded jittered backoff;
          2. one degraded (cheapest-plan) retry;
          3. feed the per-(algorithm, scheme) breaker and fall back to
             the NumPy reference path, which always succeeds.

        Preemptions (``Backpressure``) pass straight through — they are
        service decisions, not faults."""
        attempt = 0
        degraded_tried = False
        while True:
            try:
                return self.execute(q, enqueued_at=enqueued_at)
            except Exception as e:
                if isinstance(e, QueueFull) or not self.retry.is_transient(e):
                    raise
                plan_key = getattr(e, "_svc_plan_key", None)
                if plan_key is not None:
                    self.breakers.record_failure(plan_key)
                attempt += 1
                if attempt <= self.retry.max_retries:
                    delay = self.retry.backoff_s(attempt)
                    self.metrics.inc("retries", tenant=q.tenant)
                    self._note_recovery("retry", q, e, attempt=attempt,
                                        backoff_s=round(delay, 5))
                    time.sleep(delay)
                    continue
                if (not degraded_tried and isinstance(q, JoinQuery)
                        and not q.degraded):
                    degraded_tried = True
                    q.degraded = True
                    self._count("degraded", q.tenant)
                    self._note_recovery("degrade_fallback", q, e)
                    continue
                self._note_recovery("reference_fallback", q, e)
                return self._execute_reference(
                    q, queued_s=(0.0 if enqueued_at is None else
                                 max(0.0,
                                     time.perf_counter() - enqueued_at)))

    # -- admission + workers -------------------------------------------------
    def _ensure_workers(self):
        with self._lock:               # concurrent first submits race here
            if self.num_workers <= 0 or self._workers:
                return
            for i in range(self.num_workers):
                t = threading.Thread(target=self._worker_main,
                                     name=f"join-worker-{i}", daemon=True)
                t.start()
                self._workers.append(t)

    def _worker_main(self):
        """Worker supervisor: restart the serving loop if it ever dies
        unexpectedly (restart hygiene — a killed worker must never
        silently shrink service capacity)."""
        while True:
            try:
                self._worker_loop()
                return                 # loop exited normally (stop set)
            except BaseException as e:
                if self._stop.is_set():
                    return
                self.metrics.inc("worker_restarts")
                self.metrics.event("worker_restart", error=repr(e)[:200])
                self.flight.record_resilience("worker_restart",
                                              error=repr(e)[:200])

    def _worker_loop(self):
        while not self._stop.is_set():
            # Fault site BEFORE the dequeue: an injected worker death
            # never strands a claimed item (its waiter would hang).
            maybe_fault("worker")
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            q, enq_t, box, done = item
            with self._lock:
                self._busy_workers += 1
            try:
                box["outcome"] = self._run_with_recovery(q,
                                                         enqueued_at=enq_t)
            except Exception as e:  # surface to the waiter, keep serving
                # Mark the failure counted: a deferred-stage waiter
                # re-raising this exception must not count it again.
                e._svc_failure_counted = True
                box["error"] = e
                if isinstance(e, QueueFull):
                    # Preempted / shed mid-flight: structured
                    # backpressure, accounted by _note_preempt — a
                    # service decision, never an execution failure.
                    self._note_preempt(e, where="worker")
                else:
                    self._count("failed")
                    self.flight.record_failure(
                        tenant=getattr(q, "tenant", "default"),
                        query_id=getattr(q, "query_id", -1),
                        where="worker", error=repr(e))
            finally:
                with self._lock:
                    self._busy_workers -= 1
                done.set()
                self._queue.task_done()

    # -- admission pricing ---------------------------------------------------
    def _admission_estimate(self, q) -> tuple[float, float]:
        """(est_s, c_share) for admission: the same sticky plan the
        executor will pick, priced without perturbing plan counters."""
        try:
            with self._lock:
                c_load, g_load = self._loads["C"], self._loads["G"]
            if isinstance(q, GroupByQuery):
                plan = self.planner.choose_groupby(
                    q.keys.size, c_load=c_load, g_load=g_load,
                    record=False)
            else:
                build_n, probe_n = q.build.size, q.probe.size
                max_out = (q.max_out if q.max_out is not None
                           else 4 * probe_n + 1024)
                key = self._fingerprint(q.build,
                                        default_num_buckets(build_n),
                                        stage=q.tag, column="build.key",
                                        tenant=q.tenant)
                table = self.cache.peek(key)
                with self._lock:
                    seen = key in self._seen_fingerprints
                plan = self.planner.choose(
                    build_n, probe_n, max_out=max_out,
                    cached=table is not None,
                    expect_reuse=seen and table is None,
                    c_load=c_load, g_load=g_load, kind=q.kind,
                    record=False)
            return float(plan.est_s), float(plan.c_share)
        except Exception:
            return 0.0, 0.5    # unpriceable -> admit-by-count semantics

    def _degraded_estimate(self, q) -> float | None:
        """Cheapest-plan estimate (the degrade option); None when the
        query has no cheaper realizable variant (group-by)."""
        if isinstance(q, GroupByQuery):
            return None
        try:
            build_n, probe_n = q.build.size, q.probe.size
            max_out = (q.max_out if q.max_out is not None
                       else 4 * probe_n + 1024)
            key = self._fingerprint(q.build, default_num_buckets(build_n),
                                    stage=q.tag, column="build.key",
                                    tenant=q.tenant)
            plan = self.planner.choose_degraded(
                build_n, probe_n, max_out=max_out,
                cached=self.cache.peek(key) is not None, kind=q.kind,
                record=False)
            return float(plan.est_s)
        except Exception:
            return None

    def _stamp_deadline(self, q, now: float) -> None:
        """Resolve the query's absolute deadline: explicit ``deadline_at``
        wins, then a relative ``deadline_s``, then the tenant's default
        deadline class."""
        if q.deadline_at is not None:
            return
        rel = q.deadline_s
        if rel is None:
            rel = self.admission.tenant(q.tenant).deadline_s
        if rel is not None:
            q.deadline_at = now + float(rel)

    def _admission_snapshot(self, tenant: str) -> tuple[float, float]:
        """(in-flight estimated seconds, active fair-share weight)."""
        with self._lock:
            inflight = sum(self._loads.values())
        active = set(self._queue.active_tenants()) | {tenant}
        active_w = sum(self.admission.tenant(x).weight for x in active)
        return inflight, active_w

    def submit(self, q, *, block: bool = True,
               timeout: float | None = None, preadmitted: bool = False):
        """Admit a query.  Returns a ``wait()``-able handle.

        Deadline-aware: a query whose predicted completion misses its
        deadline is degraded to the cheapest plan when that still fits,
        else shed with a structured ``Backpressure`` (counted in
        ``shed``).  Non-blocking submits raise ``Backpressure`` (a
        ``QueueFull``) when the admission queue is at capacity (counted
        in ``rejected``).  ``preadmitted`` skips the shed/degrade
        decision — pipeline stages whose root already passed admission.
        """
        tenant = q.tenant or "default"
        with self._lock:
            closing = self._closing
        if closing:
            bp = Backpressure(
                f"service closing, query {q.query_id} not admitted",
                reason="service_closing", tenant=tenant,
                query_id=q.query_id, retry_after_s=0.1)
            self._admission_event("reject", bp)
            raise bp
        self._ensure_workers()
        tr = self.tracer
        if tr.enabled and getattr(q, "_obs_key", None) is None:
            q._obs_key = tr.next_key()
        with tr.span("admit", q_key=getattr(q, "_obs_key", None),
                     query_id=q.query_id, tenant=tenant, tag=q.tag):
            est, c_share = self._admission_estimate(q)
            now = self._clock()
            self._stamp_deadline(q, now)
            if (not preadmitted and self.admission.mode == "cost"
                    and q.deadline_at is not None):
                inflight, active_w = self._admission_snapshot(tenant)
                decision = self.admission.decide(
                    tenant, est_s=est, deadline_s=q.deadline_at - now,
                    degraded_est_fn=lambda: self._degraded_estimate(q),
                    c_share=c_share, inflight_s=inflight,
                    tenant_backlog_s=self._queue.backlog_s(tenant),
                    active_weight=active_w)
                if decision.action == "shed":
                    bp = Backpressure(
                        f"query {q.query_id} shed: predicted completion "
                        f"{decision.predicted_s:.3f}s misses deadline "
                        f"{q.deadline_at - now:.3f}s "
                        f"(retry after {decision.retry_after_s:.3f}s)",
                        reason="deadline", tenant=tenant,
                        query_id=q.query_id,
                        retry_after_s=decision.retry_after_s,
                        predicted_s=decision.predicted_s,
                        deadline_s=q.deadline_at - now)
                    self._admission_event("shed", bp)
                    raise bp
                if decision.action == "degrade":
                    q.degraded = True
                    self._count("degraded", tenant)
                    self.metrics.event(
                        "admission", action="degrade", reason="deadline",
                        tenant=tenant, query_id=q.query_id,
                        predicted_s=decision.predicted_s,
                        deadline_s=q.deadline_at - now,
                        retry_after_s=decision.retry_after_s)
                    tr.instant("degrade", tenant=tenant,
                               query_id=q.query_id)
                    self.flight.record_admission(
                        "degrade", tenant=tenant, query_id=q.query_id,
                        predicted_s=decision.predicted_s)
            box: dict = {}
            done = threading.Event()
            try:
                if tr.enabled:
                    q._obs_enq = tr.now()
                self._queue.put((q, time.perf_counter(), box, done),
                                priority=q.priority, block=block,
                                timeout=timeout, tenant=tenant,
                                deadline_at=q.deadline_at, est_s=est)
            except queue.Full:
                with self._lock:
                    inflight = sum(self._loads.values())
                backlog = self._queue.backlog_s()
                bp = Backpressure(
                    f"admission queue full (query {q.query_id})",
                    reason="queue_full", tenant=tenant,
                    query_id=q.query_id,
                    retry_after_s=max(0.05, (inflight + backlog)
                                     / max(1, self.num_workers)))
                self._admission_event("reject", bp)
                raise bp
            self._count("admitted", tenant)

        def wait(timeout: float | None = None) -> QueryOutcome:
            if not done.wait(timeout):
                raise TimeoutError(f"query {q.query_id} still running")
            if "error" in box:
                raise box["error"]
            return box["outcome"]

        return wait

    def admit_pipeline(self, *, tenant: str = "default",
                       est_s: float = 0.0,
                       deadline_s: float | None = None,
                       deadline_at: float | None = None,
                       query_id: int = -1,
                       degraded_est_s: float | None = None
                       ) -> tuple[float | None, bool]:
        """Admit (or shed) a whole pipeline up front on its total cost.

        Returns ``(deadline_at, degraded)``: the absolute deadline every
        stage of the pipeline should carry (``None`` when neither the
        caller nor the tenant's deadline class sets one) and whether the
        pipeline must run its stages degraded.  Raises ``Backpressure``
        when the predicted completion cannot meet the deadline even
        degraded — the whole pipeline is shed coherently instead of
        failing half-way through.
        """
        tenant = tenant or "default"
        now = self._clock()
        if deadline_at is None:
            rel = deadline_s
            if rel is None:
                rel = self.admission.tenant(tenant).deadline_s
            if rel is not None:
                deadline_at = now + float(rel)
        if (self.admission.mode != "cost" or deadline_at is None):
            return deadline_at, False
        inflight, active_w = self._admission_snapshot(tenant)
        decision = self.admission.decide(
            tenant, est_s=est_s, deadline_s=deadline_at - now,
            degraded_est_fn=(None if degraded_est_s is None
                             else (lambda: degraded_est_s)),
            inflight_s=inflight,
            tenant_backlog_s=self._queue.backlog_s(tenant),
            active_weight=active_w)
        if decision.action == "shed":
            bp = Backpressure(
                f"pipeline {query_id} shed: predicted completion "
                f"{decision.predicted_s:.3f}s misses deadline "
                f"{deadline_at - now:.3f}s "
                f"(retry after {decision.retry_after_s:.3f}s)",
                reason="deadline", tenant=tenant, query_id=query_id,
                retry_after_s=decision.retry_after_s,
                predicted_s=decision.predicted_s,
                deadline_s=deadline_at - now)
            self._admission_event("shed", bp)
            raise bp
        if decision.action == "degrade":
            self._count("degraded", tenant)
            self.metrics.event(
                "admission", action="degrade", reason="deadline",
                tenant=tenant, query_id=query_id,
                predicted_s=decision.predicted_s,
                deadline_s=deadline_at - now,
                retry_after_s=decision.retry_after_s)
            self.flight.record_admission(
                "degrade", tenant=tenant, query_id=query_id,
                predicted_s=decision.predicted_s)
            return deadline_at, True
        return deadline_at, False

    def submit_deferred(self, make_query, deps=(), *, finalize=None,
                        priority: int | None = None,
                        tenant: str | None = None,
                        deadline_at: float | None = None,
                        preadmitted: bool = True,
                        block: bool = True,
                        timeout: float | None = None):
        """Admit one pipeline stage that depends on earlier stages.

        ``make_query(dep_outcomes)`` is called — with the outcomes of the
        ``deps`` handles, in order — only once they have all resolved, and
        must return the stage's ``JoinQuery`` (its inputs typically do not
        exist before its dependencies finish).  ``finalize(outcome)``, when
        given, runs before the returned handle resolves; the query-pipeline
        executor publishes stage intermediates there so dependent stages
        always find them — on the fused path those are *device handles*
        (``StageView``: result rid vectors still resident on device), not
        host rows, and the per-device-group locks already serialize any
        group work the dependents dispatch.  Returns a ``wait()``-able like
        ``submit``.  Stages with disjoint dependency sets go through the
        normal admission queue concurrently — that is where independent
        subtrees of a join tree overlap on the two device groups.

        Deferred stages are *bounded*: each holds one slot of the service's
        deferred-stage semaphore while pending, so a deep or wide pipeline
        cannot spawn unbounded threads past admission (non-blocking submits
        raise ``Backpressure`` when no slot is free).  The stage inherits
        its tenant and absolute deadline from its dependencies' outcomes —
        or takes the explicit ``tenant``/``deadline_at`` overrides — so a
        whole pipeline is admitted or shed coherently; ``preadmitted``
        (default) skips per-stage shed/degrade decisions because the root
        decision via ``admit_pipeline`` already covered the pipeline.
        """
        if not self._deferred_sem.acquire(blocking=block, timeout=timeout):
            bp = Backpressure(
                "deferred-stage capacity exhausted",
                reason="queue_full", tenant=tenant or "default",
                retry_after_s=0.05)
            self._admission_event("reject", bp)
            raise bp
        box: dict = {}
        done = threading.Event()

        def runner():
            try:
                try:
                    outs = [d() for d in deps]
                except Exception as e:
                    # Dep failures propagate but were already counted at
                    # the failing stage — don't double-count here.
                    box["error"] = e
                    return
                try:
                    q = make_query(outs)
                    if priority is not None:
                        q.priority = priority
                    # Inherit tenant/deadline: explicit override first,
                    # then the dependencies' outcomes, then the query's
                    # own fields.
                    if tenant is not None:
                        q.tenant = tenant
                    elif outs and getattr(q, "tenant", "default") == "default":
                        q.tenant = outs[0].tenant
                    if deadline_at is not None:
                        q.deadline_at = deadline_at
                    elif q.deadline_at is None and outs:
                        q.deadline_at = outs[0].deadline_at
                    if self.num_workers <= 0:
                        out = self.execute(q)
                    else:
                        out = self.submit(q, preadmitted=preadmitted)()
                    if finalize is not None:
                        finalize(out)
                    box["outcome"] = out
                except Exception as e:
                    # Admission outcomes (shed / queue-full) are already
                    # counted as shed/rejected, not execution failures.
                    if (not isinstance(e, QueueFull)
                            and not getattr(e, "_svc_failure_counted",
                                            False)):
                        e._svc_failure_counted = True
                        self._count("failed")
                        self.flight.record_failure(
                            tenant=tenant or "default",
                            where="deferred", error=repr(e))
                    box["error"] = e
            finally:
                self._deferred_sem.release()
                done.set()

        threading.Thread(target=runner, daemon=True,
                         name="join-deferred").start()

        def wait(timeout: float | None = None) -> QueryOutcome:
            if not done.wait(timeout):
                raise TimeoutError("deferred query still running")
            if "error" in box:
                raise box["error"]
            return box["outcome"]

        return wait

    def run(self, queries) -> list[QueryOutcome]:
        """Drain a whole workload; outcomes in submission order."""
        if self.num_workers <= 0:
            return [self.execute(q) for q in queries]
        waiters = [self.submit(q) for q in queries]
        return [w() for w in waiters]

    # -- lifecycle / stats ---------------------------------------------------
    def close(self, drain: bool = True, timeout: float = 5.0):
        """Shut the service down.

        ``drain=True`` (default) lets the workers finish everything
        already admitted (bounded by ``timeout`` of *real* wall time —
        the injectable clock may be fake and would never advance a drain
        wait) before stopping them; ``drain=False`` stops them at the
        next dequeue.  Either way, anything still queued afterwards is
        cancelled with a structured ``Backpressure(service_closing)`` —
        a shutdown decision, not an execution failure — so no waiter
        ever blocks on a queue nobody drains.  Once closed, ``submit``
        rejects with the same structured error; direct ``execute`` calls
        still work.
        """
        with self._lock:
            self._closing = True
        if drain and self._workers:
            end = time.monotonic() + float(timeout)
            while time.monotonic() < end:
                with self._lock:
                    busy = self._busy_workers
                if len(self._queue) == 0 and busy == 0:
                    break
                time.sleep(0.005)
        self._stop.set()
        for t in self._workers:
            t.join(timeout=float(timeout))
        # Cancel queries still sitting in the admission queue.
        for item in self._queue.drain():
            q, _, box, done = item
            bp = Backpressure(
                f"service closed before query {q.query_id} ran",
                reason="service_closing",
                tenant=getattr(q, "tenant", "default"),
                query_id=getattr(q, "query_id", -1))
            box["error"] = bp
            done.set()
            self.metrics.inc("cancelled_on_close",
                             tenant=getattr(q, "tenant", "default"))
            self.metrics.event("admission", action="cancel",
                               **bp.to_dict())
            self.flight.record_admission("cancel", **bp.to_dict())
        self._workers.clear()
        self._stop.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        """One coherent snapshot, routed through ``metrics.snapshot()``.

        All service counters (global and per-tenant) come out of a single
        locked registry read; queue depth, cache, planner and audit state
        are registry collectors invoked in the same pass — the old
        counters-then-components split (where ``queue_depth`` and
        ``cache.stats()`` were read at a later instant than the counter
        snapshot) is gone.  The full registry snapshot rides along under
        ``"metrics"`` for consumers that want the labeled series, the
        prediction-error summary, or the calibration version.
        """
        snap = self.metrics.snapshot()
        counters = {name: int(snap.get(name, 0))
                    for name in ("admitted", "rejected", "completed",
                                 "failed", "shed", "degraded")}
        tenants: dict[str, dict] = {}
        for name in self._TENANT_COUNTERS:
            prefix = name + "{tenant="
            for key, value in snap.items():
                if (isinstance(key, str) and key.startswith(prefix)
                        and key.endswith("}")):
                    t = key[len(prefix):-1]
                    tenants.setdefault(
                        t, {n: 0 for n in self._TENANT_COUNTERS}
                    )[name] = int(value)
        resilience = {name: int(self.metrics.counter_value(name))
                      for name in self._RESILIENCE_COUNTERS}
        resilience["breakers"] = snap.get("breakers")
        resilience["budget"] = snap.get("budget")
        return {**counters,
                "host_bytes_moved": int(snap.get("host_bytes_moved", 0)),
                "queue_depth": snap.get("queue_depth", 0),
                "tenants": tenants, "cache": snap.get("cache"),
                "planner": snap.get("planner"),
                "flight": snap.get("flight"), "slo": snap.get("slo"),
                "drift": snap.get("drift"),
                "host_transfer_ledger": snap.get("host_transfer_ledger"),
                "cardinality_error": snap.get("cardinality_error"),
                "resilience": resilience,
                "metrics": snap}
