"""Multi-tenant, deadline-aware admission for ``JoinQueryService``.

The planner already *predicts* per-query runtime (``QueryPlan.est_s``);
this module turns that prediction into serving policy — the layer the
query-acceleration survey flags as what discrete-GPU engines lack:

  * ``Tenant`` — a budgeted workload container: fair-share ``weight``, a
    default ``deadline_s`` class, and C/G resource-share budgets that cap
    the service rate its admission pricing may assume.
  * ``TenantFairQueue`` — the two-level scheduler replacing the single
    priority queue: weighted fair share *across* tenants (stride-style
    virtual time, advanced by each dequeued query's estimated seconds
    over the tenant's weight), earliest-deadline-first *within* a tenant
    (no-deadline queries fall back to the old aged-priority order, so
    single-tenant traffic behaves exactly as before).
  * ``AdmissionController`` — the admit / degrade / shed decision: a
    query's predicted completion (current in-flight load + the tenant's
    queued backlog at its fair service rate + its own estimate) is
    compared against its deadline at admission time.  A hopeless query is
    first re-priced with the planner's cheapest plan (*degrade*); if even
    that misses, it is *shed* with a structured ``Backpressure`` error
    carrying a retry-after hint — callers get an immediate, actionable
    signal instead of a timeout.

Everything takes an injectable ``clock`` so scheduling decisions are
deterministically testable.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time


class QueueFull(RuntimeError):
    """Admission rejected: the service is at capacity."""


class Backpressure(QueueFull):
    """Structured admission rejection (shed / capacity backpressure).

    Subclasses ``QueueFull`` so existing callers' except clauses keep
    working; carries the machine-readable context a client needs to back
    off sensibly instead of guessing from a timeout.
    """

    def __init__(self, msg: str, *, reason: str = "shed",
                 tenant: str = "default", query_id: int = -1,
                 retry_after_s: float = 0.0,
                 predicted_s: float | None = None,
                 deadline_s: float | None = None):
        super().__init__(msg)
        self.reason = reason            # "deadline" | "queue_full" | ...
        self.tenant = tenant
        self.query_id = query_id
        self.retry_after_s = float(retry_after_s)
        self.predicted_s = predicted_s  # predicted completion (relative s)
        self.deadline_s = deadline_s    # the deadline it would have missed

    def to_dict(self) -> dict:
        return {"reason": self.reason, "tenant": self.tenant,
                "query_id": self.query_id,
                "retry_after_s": self.retry_after_s,
                "predicted_s": self.predicted_s,
                "deadline_s": self.deadline_s}


@dataclasses.dataclass
class Tenant:
    """One workload container sharing the engine.

    ``weight`` drives the cross-tenant fair share (2.0 gets twice the
    service rate of 1.0 under contention).  ``deadline_s`` is the
    tenant's default deadline class — queries without an explicit
    deadline inherit it (``None`` = best-effort, never shed on deadline).
    ``c_budget``/``g_budget`` bound the share of each device group the
    tenant's admission pricing may assume (a tenant budgeted at 0.25 of C
    cannot count on more than a quarter of the C-group's service rate
    when predicting completion, however idle the engine is — the simpy
    Container idiom priced instead of locked).
    """

    name: str
    weight: float = 1.0
    deadline_s: float | None = None
    c_budget: float = 1.0
    g_budget: float = 1.0


@dataclasses.dataclass
class AdmissionDecision:
    action: str                   # "admit" | "degrade" | "shed"
    predicted_s: float            # predicted completion, relative seconds
    retry_after_s: float = 0.0

    def to_dict(self) -> dict:
        """Structured-event payload: the service emits every shed/degrade
        into its ``MetricsRegistry`` so consumers (``slo_bench``) read
        decisions from metrics instead of re-deriving them from raised
        ``Backpressure`` exceptions."""
        return {"action": self.action,
                "predicted_s": float(self.predicted_s),
                "retry_after_s": float(self.retry_after_s)}


@dataclasses.dataclass
class _Entry:
    priority: int
    seq: int
    enq_t: float
    deadline_at: float | None
    est_s: float
    item: object


class TenantFairQueue:
    """Bounded two-level scheduler: weighted fair share across tenants,
    EDF within a tenant.

    Each tenant owns a lane.  Lane selection is stride scheduling over
    per-tenant virtual time: dequeuing a query advances its tenant's
    vtime by ``max(est_s, est_floor_s) / weight``, so under contention a
    weight-2 tenant receives twice the estimated service seconds of a
    weight-1 tenant — *cost*-weighted fairness, not query-count fairness.
    A tenant going active after idling is clamped to the minimum active
    vtime (idle time is not banked).  Within a lane the earliest deadline
    wins; queries without a deadline sort after all deadlined ones by
    aged priority (exactly the old ``PriorityAgingQueue`` order, so
    deadline-free single-tenant traffic is unchanged).  ``fifo=True``
    degrades the whole thing to a count-only FIFO — the baseline the
    ``slo_bench`` benchmark measures cost-aware admission against.

    ``weight_fn`` maps a tenant name to its weight (late registrations
    seen live); ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, maxsize: int = 0, *, aging_s: float = 5.0,
                 clock=time.monotonic, weight_fn=None, fifo: bool = False,
                 est_floor_s: float = 1e-3):
        self.maxsize = int(maxsize)
        self.aging_s = float(aging_s)
        self._clock = clock
        self._weight_fn = weight_fn or (lambda tenant: 1.0)
        self.fifo = bool(fifo)
        self.est_floor_s = float(est_floor_s)
        self._lanes: dict[str, list[_Entry]] = {}
        self._vtime: dict[str, float] = {}
        self._backlog: dict[str, float] = {}
        self._cond = threading.Condition()
        self._seq = 0
        self._size = 0

    def __len__(self) -> int:
        with self._cond:
            return self._size

    qsize = __len__

    def active_tenants(self) -> list[str]:
        with self._cond:
            return [t for t, lane in self._lanes.items() if lane]

    def backlog_s(self, tenant: str | None = None) -> float:
        """Summed estimated seconds queued (for one tenant, or all)."""
        with self._cond:
            if tenant is not None:
                return self._backlog.get(tenant, 0.0)
            return sum(self._backlog.values())

    def put(self, item, priority: int = 0, block: bool = True,
            timeout: float | None = None, *, tenant: str = "default",
            deadline_at: float | None = None, est_s: float = 0.0):
        with self._cond:
            if self.maxsize > 0:
                if not block and self._size >= self.maxsize:
                    raise queue.Full
                end = None if timeout is None else self._clock() + timeout
                while self._size >= self.maxsize:
                    rem = None if end is None else end - self._clock()
                    if rem is not None and rem <= 0:
                        raise queue.Full
                    if not self._cond.wait(rem):
                        raise queue.Full
            self._seq += 1
            lane = self._lanes.setdefault(tenant, [])
            if not lane:
                # Fresh-active tenant: clamp to the active minimum so idle
                # time is not banked into a starvation-length head start.
                floor = min((self._vtime[t] for t, ln in self._lanes.items()
                             if ln and t != tenant), default=0.0)
                self._vtime[tenant] = max(self._vtime.get(tenant, 0.0),
                                          floor)
            lane.append(_Entry(int(priority), self._seq, self._clock(),
                               deadline_at, max(0.0, float(est_s)), item))
            self._backlog[tenant] = self._backlog.get(tenant, 0.0) + \
                max(0.0, float(est_s))
            self._size += 1
            self._cond.notify()

    def _pop_best(self):
        now = self._clock()
        active = [t for t, lane in self._lanes.items() if lane]
        if self.fifo:
            # Count-only baseline: global arrival order, tenants ignored.
            t = min(active, key=lambda x: self._lanes[x][0].seq)
            lane = self._lanes[t]
            i = min(range(len(lane)), key=lambda j: lane[j].seq)
        else:
            # Level 1: weighted fair share — smallest virtual time wins
            # (name tie-break keeps selection deterministic under tests).
            t = min(active, key=lambda x: (self._vtime.get(x, 0.0), x))
            lane = self._lanes[t]

            # Level 2: EDF; deadline-free entries sort after every
            # deadlined one, ordered by aged priority then FIFO.
            def key(e: _Entry):
                dl = math.inf if e.deadline_at is None else e.deadline_at
                aged = e.priority + (now - e.enq_t) / self.aging_s
                return (dl, -aged, e.seq)

            i = min(range(len(lane)), key=lambda j: key(lane[j]))
        e = lane.pop(i)
        if not self.fifo:
            w = max(float(self._weight_fn(t)), 1e-6)
            self._vtime[t] = self._vtime.get(t, 0.0) + \
                max(e.est_s, self.est_floor_s) / w
        self._backlog[t] = max(0.0, self._backlog.get(t, 0.0) - e.est_s)
        self._size -= 1
        self._cond.notify()          # a blocked put may now have room
        return e.item

    def get(self, timeout: float | None = None):
        with self._cond:
            end = None if timeout is None else self._clock() + timeout
            while not self._size:
                rem = None if end is None else end - self._clock()
                if rem is not None and rem <= 0:
                    raise queue.Empty
                if not self._cond.wait(rem):
                    raise queue.Empty
            return self._pop_best()

    def get_nowait(self):
        with self._cond:
            if not self._size:
                raise queue.Empty
            return self._pop_best()

    def drain(self) -> list:
        """Remove and return every queued item at once (service close:
        the caller cancels each with a structured error).  Blocked
        ``put`` calls wake to the freed capacity."""
        with self._cond:
            items = [e.item for lane in self._lanes.values() for e in lane]
            self._lanes.clear()
            self._backlog.clear()
            self._size = 0
            self._cond.notify_all()
        return items

    def task_done(self):              # queue.Queue API compat (no join())
        pass


class AdmissionController:
    """Admit / degrade / shed, priced by the planner's estimates.

    Predicted completion for a query from tenant *t*:

        wait = inflight_s / workers  +  backlog_t / (workers * share_t)
        share_t = min(weight_t / active_weight,
                      c_budget*c_share + g_budget*(1 - c_share))

    i.e. the in-flight work drains across all workers, but the tenant's
    *queued* backlog drains only at its fair (and budget-capped) share of
    the service rate.  ``mode="fifo"`` disables deadline decisions
    entirely — the count-only baseline.
    """

    def __init__(self, tenants=None, *, num_workers: int = 2,
                 mode: str = "cost", min_retry_s: float = 0.05):
        if mode not in ("cost", "fifo"):
            raise ValueError(f"unknown admission mode {mode!r}")
        self.mode = mode
        self.num_workers = max(1, int(num_workers))
        self.min_retry_s = float(min_retry_s)
        self._tenants: dict[str, Tenant] = {}
        # Per-tenant safety margins on estimate pricing (>= 1.0): the
        # drift detector widens a tenant's margin when its measured/
        # estimated ratio runs sustainedly high, so admission predicts
        # completion from estimates inflated to what this tenant's
        # queries actually cost — prediction error fed back into
        # admission (ROADMAP item 1).
        self._margins: dict[str, float] = {}
        self._lock = threading.Lock()
        for t in (tenants or ()):
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        with self._lock:
            self._tenants[tenant.name] = tenant
        return tenant

    def tenant(self, name: str) -> Tenant:
        """Look up a tenant, auto-registering defaults for unknown names
        (best-effort weight-1 container) so untagged traffic just works."""
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                t = self._tenants[name] = Tenant(name)
            return t

    def tenants(self) -> list[Tenant]:
        with self._lock:
            return list(self._tenants.values())

    def weight_of(self, name: str) -> float:
        return self.tenant(name).weight

    def set_margin(self, name: str, margin: float) -> None:
        """Set a tenant's estimate safety margin (clamped to >= 1.0)."""
        with self._lock:
            self._margins[name] = max(1.0, float(margin))

    def margin_of(self, name: str) -> float:
        with self._lock:
            return self._margins.get(name, 1.0)

    def margins(self) -> dict[str, float]:
        with self._lock:
            return dict(self._margins)

    def decide(self, tenant_name: str, *, est_s: float,
               deadline_s: float | None, degraded_est_fn=None,
               c_share: float = 0.5, inflight_s: float = 0.0,
               tenant_backlog_s: float = 0.0,
               active_weight: float | None = None) -> AdmissionDecision:
        """One admission decision.  ``deadline_s`` is relative (seconds
        from now); ``degraded_est_fn`` lazily prices the cheapest plan —
        only evaluated when the preferred plan already misses."""
        t = self.tenant(tenant_name)
        # The drift-priced safety margin inflates every estimate used in
        # this decision: a tenant whose queries sustainedly run over
        # estimate is priced at what they actually cost.
        margin = self.margin_of(tenant_name)
        est_s = max(0.0, float(est_s)) * margin
        total_w = max(active_weight if active_weight else t.weight, 1e-9)
        share = t.weight / total_w
        budget_cap = (t.c_budget * c_share
                      + t.g_budget * (1.0 - c_share))
        share = max(min(share, budget_cap), 1e-6)
        wait = (inflight_s / self.num_workers
                + tenant_backlog_s / (self.num_workers * share))
        predicted = wait + est_s
        if self.mode != "cost" or deadline_s is None:
            return AdmissionDecision("admit", predicted)
        if predicted <= deadline_s:
            return AdmissionDecision("admit", predicted)
        degraded_est = degraded_est_fn() if degraded_est_fn else None
        if degraded_est is not None:
            degraded_est = float(degraded_est) * margin
        if degraded_est is not None and wait + degraded_est <= deadline_s:
            return AdmissionDecision("degrade", wait + degraded_est)
        cheapest = min([x for x in (est_s, degraded_est)
                        if x is not None] or [0.0])
        retry = max(self.min_retry_s, wait + cheapest - deadline_s)
        return AdmissionDecision("shed", predicted, retry_after_s=retry)


def jain_index(values) -> float:
    """Jain's fairness index over per-tenant allocations: 1.0 = perfectly
    even, 1/n = one tenant took everything."""
    xs = [max(0.0, float(v)) for v in values]
    if not xs or sum(xs) == 0.0:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs))
