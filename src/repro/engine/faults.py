"""Deterministic, seed-driven fault injection for the execution path.

Resilience claims are only testable if failure is reproducible: this
module plants named *fault sites* through the engine — kernel launch,
H2D/D2H transfer, the worker loop, cache inserts — each a single
``maybe_fault(site)`` call that is a no-op branch when no injector is
installed.  A test (or the chaos section of ``slo_bench``) installs a
:class:`FaultInjector` whose per-site schedule is derived from one seed,
so the same seed always raises/delays/corrupts on the same calls.

Modes per site:

  * ``raise``   — raise :class:`FaultInjected` (marked ``transient`` so
    the service's recovery ladder retries / degrades / falls back to the
    reference path instead of failing the query);
  * ``delay``   — sleep ``delay_s`` (a slow pass / stalled transfer);
  * ``corrupt`` — flag the call so the caller's ``maybe_corrupt`` hook
    flips payload bits (cache inserts: the checksum validation on reuse
    must catch it, never the query result).

Scheduling per site: explicit call numbers (``at``), a period
(``every``), or a seeded Bernoulli rate (``p``) — all 1-based on the
site's own call counter, optionally capped by ``max_faults``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
import zlib

import numpy as np

#: Canonical site names (callers may use any string; these are the ones
#: the engine plants).
KERNEL = "kernel"            # DeviceGroup.jit'd program launch
H2D = "h2d"                  # DeviceGroup.put_items host-to-device
D2H = "d2h"                  # device_get collection points
WORKER = "worker"            # service worker loop (kills the thread)
CACHE_INSERT = "cache_insert"  # partition-layout / table cache puts

SITES = (KERNEL, H2D, D2H, WORKER, CACHE_INSERT)


class FaultInjected(RuntimeError):
    """An injected fault.  ``transient`` marks it retryable: the service's
    recovery ladder (retry -> degrade -> breaker -> reference path)
    engages for transient errors only — deterministic errors (bad query
    shapes etc.) still fail fast."""

    transient = True

    def __init__(self, site: str, nth: int):
        super().__init__(f"injected fault at site '{site}' (call #{nth})")
        self.site = site
        self.nth = nth


@dataclasses.dataclass
class FaultSpec:
    """Schedule for one site.  Any combination of triggers may be set;
    a call fires when any of them matches (subject to ``max_faults``)."""

    mode: str = "raise"            # "raise" | "delay" | "corrupt"
    at: tuple[int, ...] = ()       # explicit 1-based call numbers
    every: int | None = None       # every n-th call
    p: float = 0.0                 # seeded Bernoulli per call
    delay_s: float = 0.005         # sleep length for mode="delay"
    max_faults: int | None = None  # stop firing after this many


class FaultInjector:
    """Seed-deterministic fault scheduler over named sites.

    One ``random.Random`` per site (seeded from ``seed`` and the site
    name) drives the Bernoulli trigger, so sites fire independently but
    reproducibly regardless of call interleaving across threads.
    """

    def __init__(self, seed: int = 0, sites: dict[str, FaultSpec] | None
                 = None):
        self.seed = int(seed)
        self.sites = dict(sites or {})
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        self._fired: dict[str, int] = {}
        self._corrupt_pending: set[str] = set()
        # crc32, not hash(): str hashing is randomized per process, and
        # the whole point is the same seed firing on the same calls.
        self._rngs = {s: random.Random(self.seed ^ zlib.crc32(s.encode()))
                      for s in self.sites}

    def stats(self) -> dict:
        with self._lock:
            return {"calls": dict(self._calls), "fired": dict(self._fired)}

    def _decide(self, site: str) -> tuple[str | None, int, float]:
        """(mode-to-fire-or-None, call number, delay_s) for this call."""
        spec = self.sites.get(site)
        with self._lock:
            n = self._calls[site] = self._calls.get(site, 0) + 1
            if spec is None:
                return None, n, 0.0
            fired = self._fired.get(site, 0)
            if spec.max_faults is not None and fired >= spec.max_faults:
                return None, n, 0.0
            hit = (n in spec.at
                   or (spec.every and n % spec.every == 0)
                   or (spec.p > 0.0
                       and self._rngs[site].random() < spec.p))
            if not hit:
                return None, n, 0.0
            self._fired[site] = fired + 1
            if spec.mode == "corrupt":
                self._corrupt_pending.add(site)
            return spec.mode, n, spec.delay_s

    def visit(self, site: str) -> None:
        mode, n, delay_s = self._decide(site)
        if mode == "raise":
            raise FaultInjected(site, n)
        if mode == "delay":
            time.sleep(delay_s)
        # "corrupt" arms the site; the caller's maybe_corrupt consumes it.

    def take_corrupt(self, site: str) -> bool:
        """Consume a pending corruption for ``site`` (armed by visit)."""
        with self._lock:
            if site in self._corrupt_pending:
                self._corrupt_pending.discard(site)
                return True
            return False


# Module-level installed injector: ``maybe_fault`` must cost one load and
# one branch on the hot path when inactive.
_injector: FaultInjector | None = None


def install(injector: FaultInjector | None) -> None:
    global _injector
    _injector = injector
    # Plant (or clear) the core-layer hook.  ``core.coprocess`` must not
    # import the engine package, so the binding runs in this direction:
    # the hook slot is a module global the core layer reads per call.
    from repro.core import coprocess
    coprocess._FAULT_HOOK = maybe_fault if injector is not None else None


def active() -> bool:
    return _injector is not None


def current() -> FaultInjector | None:
    return _injector


def maybe_fault(site: str) -> None:
    """The hook planted at every fault site (no-op when uninstalled)."""
    inj = _injector
    if inj is None:
        return
    inj.visit(site)


def maybe_corrupt(site: str, rel):
    """Return ``rel`` (a Relation-like with int ``key``/``rid`` columns),
    corrupted when the site's injector armed a corruption on this call.
    The corruption flips key values — a stored partition layout that no
    longer matches its checksum, which the service's validation on reuse
    must detect and treat as a cache miss."""
    inj = _injector
    if inj is None or not inj.take_corrupt(site):
        return rel
    key = np.array(np.asarray(rel.key), copy=True)
    if key.size:
        idx = random.Random(inj.seed ^ key.size).randrange(key.size)
        key[idx] = np.int32(np.bitwise_xor(np.int64(key[idx]), 0x55) &
                            0x7fffffff)
    return type(rel)(rel.rid, key)


@contextlib.contextmanager
def injected(injector: FaultInjector):
    """Install ``injector`` for the duration of a with-block."""
    prev = _injector
    install(injector)
    try:
        yield injector
    finally:
        install(prev)


def layout_checksum(rel) -> int:
    """Cheap content checksum of a partition layout (key + rid columns).
    Only computed when an injector is active — normal serving never pays
    the D2H pull this forces on device-resident layouts."""
    key = np.asarray(rel.key, dtype=np.int64)
    rid = np.asarray(rel.rid, dtype=np.int64)
    return int((key.sum() * 1000003 + rid.sum()) & 0x7fffffffffffffff)
