"""Workload generation: streams of heterogeneous join queries.

Scenario catalog (each yields ``JoinQuery`` instances with varying relation
sizes, skew, and selectivity — the axes the paper sweeps in §5):

  * ``uniform``     — both sides uniform keys, sizes drawn from a small
                      grid around the base size (bounds recompilation).
  * ``zipf``        — PK build side, probe keys Zipf-distributed over it
                      (skewed foreign keys: matches stay ≤ |S|).
  * ``selectivity`` — PK build side, probe selectivity cycling through the
                      paper's {12.5%, 50%, 100%} (§5.5).
  * ``hot_table``   — fresh probes against a small pool of recurring build
                      relations: the scenario the build-table cache exists
                      for (every repeat skips the build phase).
  * ``star``        — multi-join traffic: a star-shaped *logical query*
                      (fresh fact table, dimensions drawn from a recurring
                      hot pool) for ``repro.queries.PipelineExecutor`` —
                      the engine sees its stages as ordinary join queries,
                      so dimension reuse hits the build-side caches.
  * ``analytic``    — the ops-subsystem mix: star-shaped logical queries
                      whose edges cycle through semi/anti/outer variants
                      and whose sink cycles through group-by aggregates
                      (count/sum/min/max/avg over the fact measure).

``make_workload`` assembles a weighted mix; ``MIXES`` names the standard
mixes the benchmarks and tests use.  ``star`` produces ``queries.Query``
objects (not ``JoinQuery``), so it is replayed through the query-pipeline
executor rather than ``stream``.

``open_loop`` extends the generator into an open-loop traffic simulator:
queries arrive on a Poisson (or bursty on/off) process, tagged with a
tenant drawn from a mix (optionally Zipf-skewed toward a hot tenant) and
that tenant's deadline — the arrival schedule the ``slo_bench`` benchmark
replays against the service's admission control.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.relation import (Relation, probe_with_selectivity,
                                 uniform_relation, unique_relation)
from .service import JoinQuery

# Size multipliers: a deliberately small grid so repeated shapes reuse
# compiled executables instead of forcing a fresh jit per query.
SIZE_GRID = (0.5, 1.0, 2.0)

MIXES = {
    "uniform": (("uniform", 1.0),),
    "zipf": (("zipf", 1.0),),
    "selectivity": (("selectivity", 1.0),),
    "hot_table": (("hot_table", 1.0),),
    # The headline mixed workload: enough hot-table traffic that cache
    # reuse matters, plus every other axis of heterogeneity.
    "mixed": (("uniform", 0.2), ("zipf", 0.2), ("selectivity", 0.2),
              ("hot_table", 0.4)),
}


def zipf_keys(rng: np.random.Generator, n: int, key_range: int,
              theta: float = 1.3) -> np.ndarray:
    """Zipf-distributed int32 keys folded into [0, key_range)."""
    return ((rng.zipf(theta, size=n) - 1) % key_range).astype(np.int32)


def _size(rng: np.random.Generator, base: int) -> int:
    return max(256, int(base * rng.choice(SIZE_GRID)))


class WorkloadGenerator:
    """Deterministic (seeded) query stream over the scenario catalog."""

    def __init__(self, base_tuples: int = 65536, *, seed: int = 0,
                 hot_pool: int = 3, zipf_theta: float = 1.3):
        self.base = int(base_tuples)
        self.rng = np.random.default_rng(seed)
        self.zipf_theta = zipf_theta
        # Hot build relations are materialized once and re-submitted, so
        # their fingerprints recur (and their generation cost isn't paid
        # per query).
        self._hot_pool: list[Relation] = [
            unique_relation(_size(self.rng, self.base), seed=1000 + i)
            for i in range(hot_pool)]
        self._sel_cycle = (0.125, 0.5, 1.0)
        self._sel_i = 0
        # Star scenario: recurring dimension tables + a short selectivity
        # cycle (repeats make the per-stage build sides cacheable).
        self._star_pool: list = []
        self._star_sels = (None, 0.1, 0.4)
        self._star_i = 0
        # Analytic scenario: cycle variants and grouped aggregates so a
        # replayed stream exercises every operator the ops subsystem adds.
        self._variant_cycle = (("inner", "semi"), ("inner", "anti"),
                               ("left_outer", "inner"), ("semi", "inner"))
        self._agg_cycle = (("count",), ("sum", "F.m"), ("min", "F.m"),
                           ("max", "F.m"), ("avg", "F.m"))
        self._analytic_i = 0
        self._qid = 0

    # -- scenarios ----------------------------------------------------------
    def uniform(self) -> JoinQuery:
        nb, ns = _size(self.rng, self.base), _size(self.rng, self.base)
        b = uniform_relation(nb, seed=int(self.rng.integers(1 << 30)))
        s = uniform_relation(ns, key_range=nb,
                             seed=int(self.rng.integers(1 << 30)))
        # Uniform build keys collide, so matches can exceed |S| slightly.
        return self._query(b, s, "uniform", max_out=8 * ns + 1024)

    def zipf(self) -> JoinQuery:
        nb, ns = _size(self.rng, self.base), _size(self.rng, self.base)
        b = unique_relation(nb, seed=int(self.rng.integers(1 << 30)))
        keys = zipf_keys(self.rng, ns, nb, self.zipf_theta)
        import jax.numpy as jnp
        s = Relation(jnp.arange(ns, dtype=jnp.int32), jnp.asarray(keys))
        return self._query(b, s, "zipf", max_out=ns + 64)  # PK side: ≤ |S|

    def selectivity(self) -> JoinQuery:
        sel = self._sel_cycle[self._sel_i % len(self._sel_cycle)]
        self._sel_i += 1
        nb, ns = _size(self.rng, self.base), _size(self.rng, self.base)
        b = unique_relation(nb, seed=int(self.rng.integers(1 << 30)))
        s = probe_with_selectivity(b, ns, selectivity=sel,
                                   seed=int(self.rng.integers(1 << 30)))
        return self._query(b, s, f"sel_{sel}", max_out=ns + 64)

    def hot_table(self) -> JoinQuery:
        b = self._hot_pool[int(self.rng.integers(len(self._hot_pool)))]
        ns = _size(self.rng, self.base)
        keys = zipf_keys(self.rng, ns, b.size, self.zipf_theta)
        import jax.numpy as jnp
        s = Relation(jnp.arange(ns, dtype=jnp.int32), jnp.asarray(keys))
        return self._query(b, s, "hot_table", max_out=ns + 64)

    def star(self, num_dims: int = 3):
        """A star-shaped logical ``repro.queries.Query`` (multi-join).

        The fact table is fresh per call; the dimensions come from a
        recurring pool with a small cycle of filter selectivities, so
        replaying stars through ``PipelineExecutor`` produces repeated
        build sides — the cross-operator reuse the caches exist for.
        """
        from repro.queries import make_star_query
        self._ensure_star_pool()
        idx = sorted(self.rng.choice(len(self._star_pool),
                                     size=min(num_dims,
                                              len(self._star_pool)),
                                     replace=False))
        dims = [self._star_pool[i] for i in idx]
        sels = [self._star_sels[(self._star_i + k) % len(self._star_sels)]
                for k in range(len(dims))]
        self._star_i += 1
        self._qid += 1
        return make_star_query(
            _size(self.rng, 2 * self.base), [d.size for d in dims],
            selectivities=sels, seed=int(self.rng.integers(1 << 30)),
            aggregate=("count",), dim_tables=dims)

    def _ensure_star_pool(self):
        if self._star_pool:
            return
        from repro.queries import Table
        rng = np.random.default_rng(int(self.rng.integers(1 << 30)))
        for i in range(len(self._hot_pool)):
            n = _size(rng, max(1024, self.base // 2))
            self._star_pool.append(Table(f"D{i}", {
                "id": rng.permutation(n).astype(np.int32),
                "a": rng.integers(0, 1000, size=n, dtype=np.int32)}))

    def analytic(self, num_dims: int = 2):
        """A star query with join variants and a group-by sink.

        Two dimensions from the recurring pool, edge kinds and the grouped
        aggregate cycling deterministically; grouped on the fact table's
        low-cardinality ``g`` attribute so results stay small however the
        joins land.  Replayed through ``PipelineExecutor`` like ``star``.
        """
        from repro.queries import make_star_query
        self._ensure_star_pool()
        i = self._analytic_i
        self._analytic_i += 1
        idx = sorted(self.rng.choice(len(self._star_pool),
                                     size=min(num_dims,
                                              len(self._star_pool)),
                                     replace=False))
        dims = [self._star_pool[k] for k in idx]
        kinds = list(self._variant_cycle[i % len(self._variant_cycle)])
        kinds = (kinds * num_dims)[:len(dims)]
        sels = [self._star_sels[(i + k) % len(self._star_sels)]
                for k in range(len(dims))]
        self._qid += 1
        return make_star_query(
            _size(self.rng, 2 * self.base), [d.size for d in dims],
            selectivities=sels, seed=int(self.rng.integers(1 << 30)),
            aggregate=self._agg_cycle[i % len(self._agg_cycle)],
            dim_tables=dims, join_kinds=kinds, group_by=("F.g",))

    def _query(self, b, s, tag, *, max_out) -> JoinQuery:
        self._qid += 1
        return JoinQuery(build=b, probe=s, tag=tag, max_out=max_out,
                         query_id=self._qid)

    # -- mixes --------------------------------------------------------------
    def stream(self, num_queries: int, mix="mixed") -> list[JoinQuery]:
        spec = MIXES[mix] if isinstance(mix, str) else tuple(mix)
        names = [n for n, _ in spec]
        w = np.array([float(x) for _, x in spec])
        w = w / w.sum()
        return [getattr(self, names[int(self.rng.choice(len(names), p=w))])()
                for _ in range(num_queries)]


def make_workload(mix: str = "mixed", num_queries: int = 32, *,
                  base_tuples: int = 65536, seed: int = 0,
                  **kw) -> list[JoinQuery]:
    """One-call workload: a seeded list of queries from a named mix."""
    return WorkloadGenerator(base_tuples, seed=seed, **kw).stream(
        num_queries, mix)


# -- open-loop traffic simulation -------------------------------------------
@dataclasses.dataclass
class TrafficEvent:
    """One arrival of the open-loop schedule: submit ``query`` at
    ``at_s`` (seconds from stream start) on behalf of ``tenant``."""

    at_s: float
    tenant: str
    query: JoinQuery


def open_loop(num_queries: int, *, rate_qps: float = 20.0,
              tenant_mix=(("default", 1.0),), mix="mixed",
              arrivals: str = "poisson", burst_factor: float = 8.0,
              burst_fraction: float = 0.25, hot_tenant: str | None = None,
              hot_skew: float = 0.0, deadlines: dict | None = None,
              base_tuples: int = 65536, seed: int = 0,
              **gen_kw) -> list[TrafficEvent]:
    """Build an open-loop arrival schedule (arrivals don't wait on
    completions — the load that makes admission control earn its keep).

    ``arrivals="poisson"`` draws i.i.d. exponential gaps at ``rate_qps``;
    ``"burst"`` is an on/off process: a ``burst_fraction`` of the timeline
    runs at ``burst_factor``× the base rate (the overload the shed path is
    for), the rest at the base rate.  ``tenant_mix`` weights tenant names;
    ``hot_tenant``/``hot_skew`` shift extra probability mass (``hot_skew``
    in [0, 1)) onto one tenant on top of its mix weight.  ``deadlines``
    maps tenant name → relative deadline seconds stamped on each query
    (tenants absent from the map submit best-effort queries).

    The schedule is deterministic in ``seed`` — the same events can be
    replayed against different admission modes for a fair comparison.
    """
    rng = np.random.default_rng(seed)
    gen = WorkloadGenerator(base_tuples, seed=seed + 1, **gen_kw)
    queries = gen.stream(num_queries, mix)

    names = [n for n, _ in tenant_mix]
    w = np.array([float(x) for _, x in tenant_mix], dtype=np.float64)
    w = w / w.sum()
    if hot_tenant is not None and hot_skew > 0.0:
        if hot_tenant not in names:
            names.append(hot_tenant)
            w = np.append(w, 0.0)
        w = w * (1.0 - hot_skew)
        w[names.index(hot_tenant)] += hot_skew

    base_gap = 1.0 / max(rate_qps, 1e-9)
    events: list[TrafficEvent] = []
    t = 0.0
    for q in queries:
        if arrivals == "poisson":
            t += float(rng.exponential(base_gap))
        elif arrivals == "burst":
            in_burst = rng.random() < burst_fraction
            gap = base_gap / (burst_factor if in_burst else 1.0)
            t += float(rng.exponential(gap))
        elif arrivals == "uniform":
            t += base_gap
        else:
            raise ValueError(f"unknown arrival process {arrivals!r}")
        tenant = names[int(rng.choice(len(names), p=w))]
        q.tenant = tenant
        if deadlines and tenant in deadlines:
            q.deadline_s = float(deadlines[tenant])
        events.append(TrafficEvent(t, tenant, q))
    return events
