"""Granite-MoE-3B-A800M [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512, 40 experts top-8, vocab=49155.  [hf:ibm-granite; hf]

40 experts do not divide the 16-way model axis: expert weights fall back
to TP over the expert FFN dim ("expert_mlp") — the cost-model-guided
EP-vs-TP decision of DESIGN.md §3.2.
"""
from .base import ModelConfig, MoECfg, register

CONFIG = register(ModelConfig(
    name="granite_moe_3b", family="moe", num_layers=32, d_model=1536,
    num_heads=24, num_kv_heads=8, head_dim=64, d_ff=512,
    vocab_size=49155, tie_embeddings=True, rope_theta=1e4,
    pattern_unit="E",
    moe=MoECfg(num_experts=40, top_k=8, d_ff=512, shared_d_ff=0,
               capacity_factor=1.25, group_size=1024),
    source="hf:ibm-granite/granite-3.0-3b-a800m-base"))
