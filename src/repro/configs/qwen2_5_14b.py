"""Qwen2.5-14B [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5 family; hf]

40 heads do not divide the 16-way model axis: the sharding engine falls
back to head_dim/sequence sharding (DESIGN.md §5, §Perf cell candidate).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2_5_14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=13824,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    pattern_unit="D", source="hf:Qwen/Qwen2.5-14B"))
