"""Mamba2-2.7B [ssm]: 64L d_model=2560 (attention-free), ssm_state=128,
vocab=50280 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Sub-quadratic: runs long_500k (state is O(1) in sequence length).
"""
from .base import ModelConfig, SSMCfg, register

CONFIG = register(ModelConfig(
    name="mamba2_2_7b", family="ssm", num_layers=64, d_model=2560,
    num_heads=1, num_kv_heads=1, head_dim=64, d_ff=0,
    vocab_size=50280, tie_embeddings=True,
    pattern_unit="M", sub_quadratic=True,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    source="arXiv:2405.21060"))
