"""Llama-4-Maverick-400B-A17B [moe]: 48L d_model=5120 40H (GQA kv=8)
MoE d_ff=8192, 128 experts top-1, shared expert; vocab=202048; MoE on
every other layer (pattern DE), dense layers d_ff=16384 — early fusion.
[hf:meta-llama/Llama-4-*; unverified]

Totals ~400B params / ~17B active (see ModelConfig.param_count).
"""
from .base import ModelConfig, MoECfg, register

CONFIG = register(ModelConfig(
    name="llama4_maverick_400b", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=16384,
    vocab_size=202048, rope_theta=5e5,
    pattern_unit="DE",
    moe=MoECfg(num_experts=128, top_k=1, d_ff=8192, shared_d_ff=8192,
               capacity_factor=1.25, group_size=1024),
    train_accum=8,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (scaled)"))
