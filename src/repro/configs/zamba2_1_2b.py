"""Zamba2-1.2B [hybrid]: 38L d_model=2048, Mamba2 backbone with shared
attention blocks (32H kv=32, block MLP d_ff=8192), ssm_state=64,
vocab=32000.  [arXiv:2411.15242; hf]

Pattern: 6 x (5 Mamba2 + 1 attention) + 2 Mamba2 tail = 38 layers.
Sub-quadratic: runs long_500k.
"""
from .base import ModelConfig, SSMCfg, register

CONFIG = register(ModelConfig(
    name="zamba2_1_2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192,
    vocab_size=32000, tie_embeddings=True, rope_theta=1e4,
    pattern_unit="MMMMMA", tail="MM", sub_quadratic=True,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    source="arXiv:2411.15242"))
