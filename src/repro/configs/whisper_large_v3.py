"""Whisper-large-v3 [audio]: enc-dec, 32 encoder + 32 decoder layers,
d_model=1280 20H (kv=20) d_ff=5120 vocab=51866 — conv frontend is a STUB
(input_specs supplies precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]

Backbone-only spec: we use the shared RoPE/RMSNorm decoder substrate
(adaptation noted in DESIGN.md §4).  20 heads do not divide the model
axis -> head_dim/seq fallback sharding.
"""
from .base import ModelConfig, EncoderCfg, register

CONFIG = register(ModelConfig(
    name="whisper_large_v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, head_dim=64, d_ff=5120,
    vocab_size=51866, rope_theta=1e4,
    pattern_unit="D", frontend="audio",
    encoder=EncoderCfg(num_layers=32, num_frames=1500),
    source="arXiv:2212.04356"))
