"""Qwen3-32B [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3_32b", family="dense", num_layers=64, d_model=5120,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=25600,
    vocab_size=151936, qk_norm=True, rope_theta=1e6,
    pattern_unit="D", source="hf:Qwen/Qwen3-32B"))
