"""Chameleon-34B [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early fusion, VQ image tokens (stub frontend supplies
precomputed token ids; image tokens share the text vocab).
[arXiv:2405.09818; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon_34b", family="vlm", num_layers=48, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=22016,
    vocab_size=65536, qk_norm=True, rope_theta=1e4,
    pattern_unit="D", frontend="vq_image",
    source="arXiv:2405.09818"))
