"""Phi-3-mini-3.8B [dense]: 32L d_model=3072 32H (kv=32, i.e. MHA)
d_ff=8192 vocab=32064 — RoPE SwiGLU.  [arXiv:2404.14219; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3_mini_3_8b", family="dense", num_layers=32, d_model=3072,
    num_heads=32, num_kv_heads=32, head_dim=96, d_ff=8192,
    vocab_size=32064, rope_theta=1e4,
    pattern_unit="D", source="arXiv:2404.14219"))
