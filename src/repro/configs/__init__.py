from .base import (ModelConfig, MoECfg, SSMCfg, EncoderCfg, ShapeSpec,
                   SHAPES, runnable, register, get_config, all_configs,
                   reduced, ARCH_IDS, load_all)
