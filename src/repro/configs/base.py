"""Model/shape configuration system.

Every assigned architecture is a ``ModelConfig`` built from a repeating
*pattern unit* of block types so the model can be lowered as a single
``lax.scan`` over stacked per-unit parameters (compile-time critical for the
40-cell dry-run):

  block chars:  D = attention + dense MLP        (all dense archs)
                E = attention + MoE FFN          (llama4 alternates D/E)
                M = Mamba2 (SSD) block           (mamba2, zamba2)
                A = attention + dense MLP        (zamba2's shared-attention
                                                  blocks; same math as D,
                                                  kept distinct for clarity)

``layers = pattern_unit * num_units + tail``.

Shape specs are the assigned input shapes; ``runnable`` marks the cells that
execute (long_500k only for sub-quadratic archs, per the assignment).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff: int                   # per-expert FFN width
    shared_d_ff: int = 0        # always-on shared expert (llama4)
    capacity_factor: float = 1.25
    group_size: int = 1024      # tokens per dispatch group (dense dispatch)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256            # SSD chunk length


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    num_layers: int
    num_frames: int = 1500      # whisper conv-frontend output length (stub)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    pattern_unit: str = "D"
    tail: str = ""
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    encoder: EncoderCfg | None = None
    frontend: str | None = None          # "audio" | "vq_image" (stubs)
    sub_quadratic: bool = False          # can run long_500k
    use_pallas: bool = False             # Pallas kernels (TPU only)
    moe_impl: str = "dense"              # "dense" (pjit) | "sorted" (paper)
    dtype: str = "bfloat16"
    remat: str = "full"                  # "none"|"full"|"dots"
    scan_layers: bool = True             # False: unroll (cost extrapolation)
    train_accum: int = 1                 # gradient-accumulation microbatches
    source: str = ""                     # provenance note

    def __post_init__(self):
        unit = len(self.pattern_unit)
        assert (self.num_layers - len(self.tail)) % unit == 0, \
            (self.name, self.num_layers, self.pattern_unit, self.tail)

    @property
    def num_units(self) -> int:
        return (self.num_layers - len(self.tail)) // len(self.pattern_unit)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.num_heads * hd * 2 \
            + d * self.num_kv_heads * hd * 2
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = 0
        if self.moe:
            moe_ffn = 3 * d * self.moe.d_ff * self.moe.num_experts \
                + 3 * d * self.moe.shared_d_ff + d * self.moe.num_experts
        per = {"D": attn + dense_ffn, "A": attn + dense_ffn,
               "E": attn + moe_ffn, "M": self._mamba_params()}
        pattern = self.pattern_unit * self.num_units + self.tail
        total = sum(per[c] for c in pattern)
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.encoder:
            total += self.encoder.num_layers * (attn + dense_ffn) \
                + self.num_layers * (attn + dense_ffn)  # cross attn approx
        return total

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        inactive = 3 * d * self.moe.d_ff * \
            (self.moe.num_experts - self.moe.top_k)
        n_moe = sum(1 for c in self.pattern_unit * self.num_units + self.tail
                    if c == "E")
        return self.param_count() - n_moe * inactive

    def _mamba_params(self) -> int:
        if not self.ssm:
            return 0
        d, s = self.d_model, self.ssm
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.d_state
        return (d * (2 * d_in + 2 * s.d_state + nheads)   # in_proj
                + conv_dim * s.conv_kernel                 # conv
                + 2 * nheads + nheads                      # A, D, dt_bias
                + d_in * d)                                # out_proj


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch, shape) a runnable cell?  (False, why) if assigned-skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k decode needs sub-quadratic "
                       "attention (skip noted in DESIGN.md §4)")
    return True, ""


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


ARCH_IDS = [
    "qwen3_8b", "qwen3_32b", "qwen2_5_14b", "phi3_mini_3_8b",
    "llama4_maverick_400b", "granite_moe_3b", "zamba2_1_2b", "mamba2_2_7b",
    "whisper_large_v3", "chameleon_34b",
]


def load_all() -> None:
    import importlib
    for arch in ARCH_IDS:
        importlib.import_module(f"repro.configs.{arch}")


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests (one step, no NaNs)."""
    kw: dict = dict(
        name=cfg.name + "_smoke", d_model=64, num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads
        < cfg.num_heads else 4,
        head_dim=16, d_ff=128, vocab_size=503,  # odd on purpose (padding)
        num_layers=len(cfg.pattern_unit) + len(cfg.tail),
        tail=cfg.tail[:2], rope_theta=1e4, remat="none",
    )
    kw["num_layers"] = len(cfg.pattern_unit) + len(kw["tail"])
    if cfg.moe:
        kw["moe"] = MoECfg(num_experts=8, top_k=min(cfg.moe.top_k, 2),
                           d_ff=32, shared_d_ff=32 if cfg.moe.shared_d_ff
                           else 0, group_size=32)
    if cfg.ssm:
        kw["ssm"] = SSMCfg(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                           chunk=16)
    if cfg.encoder:
        kw["encoder"] = EncoderCfg(num_layers=1, num_frames=24)
    return dataclasses.replace(cfg, **kw)
