"""Cost-model join ordering: from a logical ``Query`` to a stage pipeline.

Any permutation of a query's join edges is executable as a *bushy* plan:
each edge joins the two components currently containing its endpoints (a
base table or an earlier stage's output).  The optimizer

  1. estimates every stage's intermediate cardinality with the classic
     System-R formulas — base tables from their selectivity annotations,
     joins as ``|A| * |B| / max(ndv(a), ndv(b))``;
  2. prices each stage through the engine's ``QueryPlanner.choose`` (the
     paper's §3.2/§4 machinery: co-processing scheme *and* SHJ-vs-PHJ per
     stage, from the calibrated ``SeriesCostModel``), build side = the
     smaller estimated input;
  3. searches orders — exhaustive over all edge permutations up to
     ``exhaustive_joins`` edges (Shanbhag et al.'s point that placement
     must be decided per operator makes per-stage pricing cheap enough to
     afford it), greedy cheapest-next-edge beyond that (>4 relations).

The emitted ``PhysicalPlan`` is a DAG of ``PipelineStage``s annotated with
the chosen scheme and algorithm; stages whose dependency sets are disjoint
(independent subtrees) run concurrently in the executor.  The estimate is
therefore an upper bound on wall time — pricing sums stages serially.

Stage hand-off pricing: every intermediate a later stage consumes pays a
transfer term.  Under ``handoff="host"`` (the materialize path) that is
``QueryPlanner.host_handoff_s`` over the result's rid pairs down and the
next stage's key relation back up — measured H2D/D2H unit cost; under
``handoff="device"`` (the fused path) intermediates never cross the host
and the term is ~0.  Because the term scales with the intermediate's
cardinality, a host-mode optimizer now sees what the serial left-to-right
sum alone could not: orders that keep the *large* intermediate off the
host boundary price ahead.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.engine.planner import QueryPlan, QueryPlanner

from .plan import Join, Query

# Result-capacity headroom over the estimated output cardinality; actual
# capacities are re-derived from realized input sizes at execution time.
EST_OUT_SLACK = 1.25

# Bytes one host-materialized hand-off moves per intermediate row: the
# (probe_rid, build_rid) result pair gathered down (8 B) plus the next
# stage's (rid, key) relation uploaded back (8 B).  Payload columns are
# gathered host-side from host-resident sources, so they cross no device
# boundary and are not priced here.
HOST_HANDOFF_BYTES_PER_ROW = 16


@dataclasses.dataclass
class PipelineStage:
    """One physical join stage of the pipeline (JoinQuery-compatible).

    ``build_input`` / ``probe_input`` name either a base table (str) or an
    earlier stage's output (int stage id); ``deps`` lists the stage ids
    this stage must wait for.
    """

    stage_id: int
    join: Join
    build_input: object           # str table name | int stage id
    probe_input: object
    build_col: str                # qualified "table.column"
    probe_col: str
    est_build: int
    est_probe: int
    est_out: int
    plan: QueryPlan               # scheme + SHJ-vs-PHJ annotation
    deps: tuple

    @property
    def kind(self) -> str:
        return self.join.kind

    def to_dict(self) -> dict:
        return {"stage_id": self.stage_id, "join": str(self.join),
                "kind": self.kind,
                "build_input": self.build_input,
                "probe_input": self.probe_input,
                "est_build": self.est_build, "est_probe": self.est_probe,
                "est_out": self.est_out, "algorithm": self.plan.algorithm,
                "scheme": self.plan.scheme, "est_s": self.plan.est_s,
                "deps": list(self.deps)}


@dataclasses.dataclass
class PhysicalPlan:
    stages: list
    order: tuple                  # the join-edge order that produced it
    est_total_s: float
    aggregate: tuple | None = None
    # Cycle edges: a join whose endpoints already share a component is a
    # residual equality filter, applied to that component's output —
    # (ref, left_q, right_q) where ref is a table name or stage id.
    residuals: tuple = ()
    # Group-by sink (when the query has one): the planner's scheme choice
    # for the aggregation stage, priced into est_total_s.
    group_by: tuple = ()
    agg_plan: QueryPlan | None = None

    def describe(self) -> str:
        lines = [f"physical plan — est {self.est_total_s * 1e3:.2f} ms"]
        for s in self.stages:
            src = (lambda x: x if isinstance(x, str) else f"#{x}")
            lines.append(
                f"  #{s.stage_id}: {src(s.build_input)} ⋈ "
                f"{src(s.probe_input)} on {s.join}  "
                f"[{s.plan.algorithm}/{s.plan.scheme}] "
                f"est {s.est_build}x{s.est_probe} -> {s.est_out}, "
                f"{s.plan.est_s * 1e3:.2f} ms"
                + (f" (after {list(s.deps)})" if s.deps else ""))
        if self.agg_plan is not None:
            lines.append(
                f"  sink: group by {list(self.group_by)} "
                f"[groupby/{self.agg_plan.scheme}] "
                f"{self.agg_plan.est_s * 1e3:.2f} ms")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"est_total_s": self.est_total_s,
                "order": [str(j) for j in self.order],
                "residuals": [[str(x) for x in r] for r in self.residuals],
                "group_by": list(self.group_by),
                "agg_scheme": (self.agg_plan.scheme
                               if self.agg_plan else None),
                "stages": [s.to_dict() for s in self.stages]}


class _Component:
    """Optimizer-side summary of a base table or intermediate result."""

    def __init__(self, ref, rows: float, ndv: dict):
        self.ref = ref            # str table name | int stage id
        self.rows = max(1.0, rows)
        self.ndv = ndv            # qualified col -> estimated distinct
        self.deps = () if isinstance(ref, str) else None  # set by caller

    def col_ndv(self, q: str) -> float:
        return max(1.0, min(self.ndv.get(q, self.rows), self.rows))


def _base_component(query: Query, name: str) -> _Component:
    t = query.tables[name]
    rows = t.est_rows()
    ndv = {f"{name}.{c}": t.ndv_est(c) for c in t.columns}
    return _Component(name, rows, ndv)


class JoinOrderOptimizer:
    """Enumerates and prices join orders; emits the cheapest pipeline."""

    def __init__(self, planner: QueryPlanner | None = None, *,
                 exhaustive_joins: int = 4, handoff: str = "device"):
        self.planner = planner or QueryPlanner()
        # > exhaustive_joins edges (i.e. > ~4-5 relations): greedy search.
        self.exhaustive_joins = int(exhaustive_joins)
        # How stage intermediates reach their consumers: "device" (fused
        # hand-off, ~free) or "host" (materialized, priced per row via
        # the planner's measured H2D/D2H unit cost).  Match the executor's
        # ``handoff`` mode so estimates track what will actually run.
        if handoff not in ("device", "host"):
            raise ValueError(f"unknown handoff mode {handoff!r}")
        self.handoff = handoff

    # -- pricing one order ---------------------------------------------------
    def price_order(self, query: Query, order, *,
                    observed_rows: dict | None = None,
                    record: bool = True) -> PhysicalPlan:
        """Simulate ``order`` edge by edge, pricing every stage.

        ``observed_rows`` maps ``id(join_edge) -> exact output rows`` for
        already-executed stages (the adaptive replan path): an overridden
        edge's output — and therefore everything the System-R recurrence
        derives downstream of it (input sizes, ndv caps, hand-off terms)
        — is priced from what the device actually measured instead of the
        estimate.  ``record=False`` keeps mid-pipeline re-pricing out of
        the planner's plan-count bookkeeping, exactly like admission-time
        pricing.
        """
        observed = observed_rows or {}
        comps = {name: _base_component(query, name) for name in query.tables}
        stages: list[PipelineStage] = []
        residuals: list = []
        total = 0.0
        final = next(iter(comps.values()))
        for join in order:
            left, right = comps[join.left], comps[join.right]
            if join.kind in ("semi", "anti"):
                # Filter edge: the right table builds, the left component
                # probes for match flags.  Output rows shrink to the
                # left's matching (or non-matching) fraction — this is
                # the cardinality reduction that makes the optimizer
                # schedule semi filters early.
                sel = 1.0 / max(left.col_ndv(join.left_q),
                                right.col_ndv(join.right_q))
                p_match = min(1.0, right.rows * sel)
                frac = p_match if join.kind == "semi" else 1.0 - p_match
                out_rows = max(1.0, left.rows * frac)
                if id(join) in observed:
                    out_rows = max(1.0, float(observed[id(join)]))
                plan = self.planner.choose(
                    int(round(right.rows)), int(round(left.rows)),
                    max_out=max(64, int(out_rows * EST_OUT_SLACK) + 64),
                    kind=join.kind, record=record)
                deps = tuple(sorted(
                    {r for r in (left.ref,) if isinstance(r, int)}))
                stage = PipelineStage(
                    stage_id=len(stages), join=join,
                    build_input=right.ref, probe_input=left.ref,
                    build_col=join.right_q, probe_col=join.left_q,
                    est_build=int(round(right.rows)),
                    est_probe=int(round(left.rows)),
                    est_out=int(round(out_rows)), plan=plan, deps=deps)
                stages.append(stage)
                total += plan.est_s
                merged = _Component(stage.stage_id, out_rows,
                                    {q: min(n, out_rows)
                                     for q, n in left.ndv.items()})
                for name, c in comps.items():
                    if c is left or c is right:
                        comps[name] = merged
                final = merged
                continue
            if join.kind == "left_outer" and left is not right:
                # Preserved side probes; every left row survives.
                sel = 1.0 / max(right.col_ndv(join.right_q),
                                left.col_ndv(join.left_q))
                inner_out = left.rows * right.rows * sel
                out_rows = max(left.rows, inner_out)
                if id(join) in observed:
                    out_rows = max(1.0, float(observed[id(join)]))
                plan = self.planner.choose(
                    int(round(right.rows)), int(round(left.rows)),
                    max_out=max(64, int(out_rows * EST_OUT_SLACK) + 64),
                    kind=join.kind, record=record)
                deps = tuple(sorted(
                    {r for r in (right.ref, left.ref)
                     if isinstance(r, int)}))
                stage = PipelineStage(
                    stage_id=len(stages), join=join,
                    build_input=right.ref, probe_input=left.ref,
                    build_col=join.right_q, probe_col=join.left_q,
                    est_build=int(round(right.rows)),
                    est_probe=int(round(left.rows)),
                    est_out=int(round(out_rows)), plan=plan, deps=deps)
                stages.append(stage)
                total += plan.est_s
                merged = _Component(stage.stage_id, out_rows,
                                    {q: min(n, out_rows)
                                     for q, n in {**right.ndv,
                                                  **left.ndv}.items()})
                for name, c in comps.items():
                    if c is left or c is right:
                        comps[name] = merged
                final = merged
                continue
            if left is right:
                # Cycle edge: both sides already joined — an equality
                # filter on the component, not a stage.
                sel = 1.0 / max(left.col_ndv(join.left_q),
                                left.col_ndv(join.right_q))
                rows = max(1.0, left.rows * sel)
                shrunk = _Component(left.ref, rows,
                                    {q: min(n, rows)
                                     for q, n in left.ndv.items()})
                residuals.append((left.ref, join.left_q, join.right_q))
                for name, c in comps.items():
                    if c is left:
                        comps[name] = shrunk
                final = shrunk
                continue
            # Build side = smaller estimated input (ties go right: dims
            # typically appear on the right of a star query's edges).
            if left.rows < right.rows:
                build, probe = left, right
                build_col, probe_col = join.left_q, join.right_q
            else:
                build, probe = right, left
                build_col, probe_col = join.right_q, join.left_q
            sel = 1.0 / max(build.col_ndv(build_col),
                            probe.col_ndv(probe_col))
            out_rows = max(1.0, build.rows * probe.rows * sel)
            if id(join) in observed:
                out_rows = max(1.0, float(observed[id(join)]))
            plan = self.planner.choose(
                int(round(build.rows)), int(round(probe.rows)),
                max_out=max(64, int(out_rows * EST_OUT_SLACK) + 64),
                record=record)
            deps = tuple(sorted(
                {r for r in (build.ref, probe.ref) if isinstance(r, int)}))
            stage = PipelineStage(
                stage_id=len(stages), join=join,
                build_input=build.ref, probe_input=probe.ref,
                build_col=build_col, probe_col=probe_col,
                est_build=int(round(build.rows)),
                est_probe=int(round(probe.rows)),
                est_out=int(round(out_rows)), plan=plan, deps=deps)
            stages.append(stage)
            total += plan.est_s
            merged = _Component(stage.stage_id, out_rows,
                                {q: min(n, out_rows)
                                 for q, n in {**build.ndv,
                                              **probe.ndv}.items()})
            for name, c in comps.items():
                if c is left or c is right:
                    comps[name] = merged
            final = merged
        # Hand-off term: every intermediate consumed by a later stage pays
        # its transfer cost — the measured host round trip when stages
        # materialize, ~0 when hand-off is device-resident.
        if self.handoff == "host":
            consumed = {d for s in stages for d in s.deps}
            for s in stages:
                if s.stage_id in consumed:
                    total += self.planner.host_handoff_s(
                        HOST_HANDOFF_BYTES_PER_ROW * s.est_out)
        agg_plan = None
        if query.group_by:
            # The aggregation sink, priced like any other operator: the
            # planner's scheme choice over the pipeline's estimated final
            # cardinality (group-by cost does not depend on join order
            # beyond that cardinality, so it cannot flip the ordering —
            # but it belongs in est_total_s for plan-vs-measured honesty).
            agg_plan = self.planner.choose_groupby(
                max(1, int(round(final.rows))), record=record)
            total += agg_plan.est_s
        return PhysicalPlan(stages=stages, order=tuple(order),
                            est_total_s=total, aggregate=query.aggregate,
                            residuals=tuple(residuals),
                            group_by=query.group_by, agg_plan=agg_plan)

    # -- search --------------------------------------------------------------
    def enumerate_orders(self, query: Query):
        """Every executable edge order (any permutation is a bushy plan).

        Inner joins commute, and semi/anti edges are per-row filters on
        their left component (duplication-insensitive), so they permute
        freely.  Left-outer joins do NOT commute with joins/filters that
        shrink the preserved side — a query containing one executes in
        textual order only, which is the order the reference defines.
        """
        if any(j.kind == "left_outer" for j in query.joins):
            return [tuple(query.joins)]
        return [tuple(p) for p in itertools.permutations(query.joins)]

    def _greedy_order(self, query: Query):
        """Cheapest-marginal-stage-first (for beyond-exhaustive edge counts).

        At each step, price every remaining edge as the *next* stage of the
        partial plan and commit the cheapest — O(edges²) planner calls.
        """
        remaining = list(query.joins)
        chosen: list[Join] = []
        while remaining:
            best, best_cost = None, None
            for j in remaining:
                candidate = chosen + [j]
                plan = self.price_order(query, candidate)
                cost = (plan.est_total_s, plan.stages[-1].est_out
                        if plan.stages else 0)
                if best_cost is None or cost < best_cost:
                    best, best_cost = j, cost
            chosen.append(best)
            remaining.remove(best)
        return tuple(chosen)

    def optimize(self, query: Query) -> PhysicalPlan:
        """The cheapest priced order (exhaustive when small, else greedy)."""
        if any(j.kind == "left_outer" for j in query.joins):
            candidates = [tuple(query.joins)]       # not reorderable
        elif len(query.joins) <= self.exhaustive_joins:
            candidates = self.enumerate_orders(query)
        else:
            candidates = [self._greedy_order(query)]
        priced = [self.price_order(query, order) for order in candidates]
        # Never worse than the textual left-deep order: it is always one of
        # the exhaustive candidates, and the greedy path falls back to it
        # if its pick prices above the baseline.
        baseline = self.price_order(query, query.joins)
        best = min(priced, key=lambda p: p.est_total_s)
        return best if best.est_total_s <= baseline.est_total_s else baseline

    def worst_order(self, query: Query) -> PhysicalPlan:
        """The most expensive enumerated order (benchmark foil)."""
        priced = [self.price_order(query, order)
                  for order in self.enumerate_orders(query)]
        return max(priced, key=lambda p: p.est_total_s)

    # -- adaptive mid-pipeline re-optimization -------------------------------
    def reprice_remaining(self, query: Query, executed_order,
                          remaining_order,
                          observed_rows: dict) -> PhysicalPlan | None:
        """Re-order not-yet-admitted stages from observed cardinalities.

        ``executed_order`` is the join-edge prefix the executor already
        ran (its exact output rows in ``observed_rows``, keyed by
        ``id(edge)``); ``remaining_order`` is the incumbent plan's tail.
        Every candidate keeps the executed prefix verbatim and permutes
        only the tail, so nothing already running is invalidated.  Returns
        the re-priced full plan when a different tail beats the incumbent
        tail by the planner's ``replan_margin`` (the same hysteresis that
        guards sticky per-stage replans — flipping stage order mid-flight
        trades warmed caches and compiled executables for the estimated
        gain, so near-ties stay put), else ``None``.

        Outer queries pin textual order (``enumerate_orders``); they are
        never re-ordered.  Tails beyond ``exhaustive_joins`` edges are
        left alone too — by then the executed prefix has shrunk the
        problem or it was greedy-planned to begin with.
        """
        executed = tuple(executed_order)
        remaining = tuple(remaining_order)
        if (len(remaining) < 2 or len(remaining) > self.exhaustive_joins
                or any(j.kind == "left_outer" for j in query.joins)):
            return None
        incumbent = self.price_order(query, executed + remaining,
                                     observed_rows=observed_rows,
                                     record=False)
        # One stage per executed edge (cycle edges produce residual
        # filters, not stages — the executor does not replan those).
        if len(incumbent.stages) != len(executed) + len(remaining):
            return None
        prefix_s = sum(s.plan.est_s
                       for s in incumbent.stages[:len(executed)])
        best, best_tail = incumbent, remaining
        for tail in itertools.permutations(remaining):
            if tail == remaining:
                continue
            cand = self.price_order(query, executed + tail,
                                    observed_rows=observed_rows,
                                    record=False)
            if cand.est_total_s < best.est_total_s:
                best, best_tail = cand, tail
        if best_tail == remaining:
            return None
        # Hysteresis over the *tail* cost: the executed prefix is sunk and
        # identical in both plans, so it must not dilute the margin.
        if not self.planner.replan_beats(best.est_total_s - prefix_s,
                                         incumbent.est_total_s - prefix_s):
            return None
        return best
