"""Multi-join query pipeline over the concurrent join engine.

The paper frames hash joins as the core of query co-processing; this
package adds the query half: a declarative multi-join IR (``plan``), a
cost-model join-order optimizer that prices each candidate stage through
the engine's ``QueryPlanner`` — including a transfer-cost term per stage
hand-off (``optimize``) — and a pipelined executor that streams the
stages through ``JoinQueryService`` with dependency-aware admission,
device-resident stage hand-off (``StageView`` rid-chains; the
host-materialize path remains as a baseline), and build-side cache reuse
(``executor``).

  * ``Table`` / ``Filter`` / ``Join`` / ``Query``      — logical plan IR
  * ``JoinOrderOptimizer`` / ``PhysicalPlan`` / ``PipelineStage``
  * ``PipelineExecutor`` / ``PipelineResult``
  * ``make_star_query`` / ``make_chain_query``          — query generators
  * ``reference_execute`` / ``rows_array``              — NumPy oracle
"""
from .executor import PipelineExecutor, PipelineResult, StageView
from .optimize import JoinOrderOptimizer, PhysicalPlan, PipelineStage
from .plan import (JOIN_KINDS, NULL_VALUE, Filter, Join, Query, Table,
                   agg_output_name, apply_aggregate, apply_group_by,
                   make_chain_query, make_star_query, reference_execute,
                   reference_rows, rows_array)

__all__ = [n for n in dir() if not n.startswith("_")]
