"""Logical-plan IR for multi-join queries (star / snowflake / chain shapes).

The engine executes one binary join per request; real analytical queries
chain several equi-joins over filtered base tables and end in an
aggregation.  This module is the *declarative* layer: named tables with
integer columns, selectivity-annotated range filters, a set of equi-join
edges, and an optional count/sum sink.  ``optimize.py`` turns a ``Query``
into a physical stage pipeline; ``executor.py`` runs it through the
engine.

Conventions:

  * columns are int32 NumPy arrays of equal length per table (the paper's
    4-byte-integer columnar layout, widened to many columns);
  * a row's identity is its position — join stages build core
    ``Relation``s with ``rid = arange(n)``, so match indices gather
    payload columns directly (``Relation.gather``'s convention);
  * qualified column names are ``"table.column"``; intermediates carry the
    union of their inputs' qualified columns.

A NumPy reference implementation (``reference_rows`` /
``reference_execute``) folds the joins in textual order; every physical
plan, whatever join order the optimizer picked, must reproduce exactly its
row multiset — that is the permutation-invariance contract the tests and
the ``query_pipeline`` benchmark enforce.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Filter:
    """Range predicate ``lo <= col < hi`` with a selectivity annotation.

    ``selectivity`` is the optimizer's estimate of the surviving fraction;
    when omitted it is estimated from the column's observed min/max under a
    uniformity assumption (the classic System-R default).
    """

    column: str
    lo: int
    hi: int
    selectivity: float | None = None

    def mask(self, col: np.ndarray) -> np.ndarray:
        return (col >= self.lo) & (col < self.hi)

    def estimate(self, col: np.ndarray) -> float:
        if self.selectivity is not None:
            return float(min(max(self.selectivity, 0.0), 1.0))
        if col.size == 0:
            return 1.0
        lo, hi = int(col.min()), int(col.max()) + 1
        width = max(1, hi - lo)
        covered = max(0, min(self.hi, hi) - max(self.lo, lo))
        return min(1.0, covered / width)


class Table:
    """A named base table: equal-length int32 columns plus scan filters."""

    def __init__(self, name: str, columns: dict, filters=()):
        self.name = name
        self.columns = {c: np.asarray(v, dtype=np.int32)
                        for c, v in columns.items()}
        sizes = {v.shape[0] for v in self.columns.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged columns in table {name!r}: {sizes}")
        self.filters = tuple(filters)
        self._filtered: "Table | None" = None
        self._scan_idx: np.ndarray | None = None
        self._ndv: dict[str, int] = {}

    @property
    def size(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def with_filters(self, *filters: Filter) -> "Table":
        return Table(self.name, self.columns, self.filters + tuple(filters))

    # -- executor side: actual data -----------------------------------------
    def filtered(self) -> "Table":
        """The table with its filters applied (memoized; no filters = self)."""
        if not self.filters:
            return self
        if self._filtered is None:
            mask = np.ones(self.size, dtype=bool)
            for f in self.filters:
                mask &= f.mask(self.columns[f.column])
            self._filtered = Table(
                self.name, {c: v[mask] for c, v in self.columns.items()})
        return self._filtered

    def qualified(self) -> dict:
        """Filtered columns under their qualified ``table.column`` names."""
        t = self.filtered()
        return {f"{self.name}.{c}": v for c, v in t.columns.items()}

    def scan_indices(self) -> np.ndarray | None:
        """Surviving row indices under the filters (memoized), or ``None``
        when unfiltered.

        This is the fused scan path: the executor composes this index
        directly into the gathers that consume the table instead of
        materializing every filtered column up front (``filtered()``
        stays for the NumPy reference).
        """
        if not self.filters:
            return None
        if self._scan_idx is None:
            mask = np.ones(self.size, dtype=bool)
            for f in self.filters:
                mask &= f.mask(self.columns[f.column])
            self._scan_idx = np.nonzero(mask)[0]
        return self._scan_idx

    # -- optimizer side: estimates only -------------------------------------
    def est_rows(self) -> float:
        """Estimated post-filter cardinality (annotations, not data)."""
        est = float(self.size)
        for f in self.filters:
            est *= f.estimate(self.columns[f.column])
        return max(1.0, est)

    def ndv_est(self, column: str) -> float:
        """Estimated distinct values of ``column`` after filtering.

        Exact distinct count on the unfiltered column (cheap, memoized),
        capped by the estimated surviving rows — filtering a uniform
        fraction keeps at most that many distinct values.
        """
        if column not in self._ndv:
            self._ndv[column] = int(np.unique(self.columns[column]).size)
        return max(1.0, min(float(self._ndv[column]), self.est_rows()))


JOIN_KINDS = ("inner", "semi", "anti", "left_outer")

# SQL NULL for the int32 column model: the right side of an unmatched
# left-outer row.  Join keys are validated non-negative, so the sentinel
# never collides with a real key (payload columns may hold any value the
# user put there; -1 payloads are indistinguishable from NULL by design).
NULL_VALUE = -1


@dataclasses.dataclass(frozen=True)
class Join:
    """One join edge: ``left.left_col == right.right_col``.

    ``kind`` selects the variant semantics:

      * ``inner``      — all matching row pairs (the default).
      * ``semi``       — left rows with ≥ 1 match; the right table is a
                         pure filter (its columns are consumed, and it may
                         appear in no other edge / group-by / aggregate).
      * ``anti``       — left rows with 0 matches; same right-side rules.
      * ``left_outer`` — all matching pairs plus unmatched left rows with
                         the right columns ``NULL_VALUE``-filled.
    """

    left: str
    left_col: str
    right: str
    right_col: str
    kind: str = "inner"

    @property
    def left_q(self) -> str:
        return f"{self.left}.{self.left_col}"

    @property
    def right_q(self) -> str:
        return f"{self.right}.{self.right_col}"

    def __str__(self) -> str:
        op = {"inner": "=", "semi": "⋉", "anti": "▷",
              "left_outer": "⟕"}.get(self.kind, "=")
        return f"{self.left_q}{op}{self.right_q}"


@dataclasses.dataclass
class Query:
    """A declarative multi-join query: tables, join edges, optional sink.

    ``joins`` in textual order is the naive left-deep baseline the
    optimizer must never price worse than.  ``aggregate`` is ``None``
    (return the joined rows), ``("count",)``, or ``("<agg>",
    "table.column")`` with ``<agg>`` in sum/min/max/avg.

    ``group_by`` names qualified key columns: the sink then aggregates per
    distinct key combination (default ``("count",)`` when no aggregate is
    given) and the query's result is one row per group.  Grouped sums
    (and the avg numerator) accumulate wide — exact int64 via the
    segmented-agg kernel's chunked channels — unless ``wrap32=True``
    requests the legacy int32-wrapping device accumulator (kept for
    oracle-parity tests); the NumPy reference reproduces either mode
    exactly.  Scalar sinks stay int64 host-side.
    """

    tables: dict
    joins: tuple
    aggregate: tuple | None = None
    group_by: tuple = ()
    wrap32: bool = False

    def _check_column_ref(self, ref: str, what: str):
        tbl, _, col = ref.partition(".")
        if (not col or tbl not in self.tables
                or col not in self.tables[tbl].columns):
            raise ValueError(f"{what} over unknown column {ref!r}")

    def __post_init__(self):
        self.joins = tuple(self.joins)
        self.group_by = tuple(self.group_by)
        for j in self.joins:
            for side, col in ((j.left, j.left_col), (j.right, j.right_col)):
                if side not in self.tables:
                    raise ValueError(f"join {j} references unknown table "
                                     f"{side!r}")
                if col not in self.tables[side].columns:
                    raise ValueError(f"join {j}: no column {col!r} on "
                                     f"{side!r}")
            if j.kind not in JOIN_KINDS:
                raise ValueError(f"unknown join kind {j.kind!r}")
            if j.kind != "inner" and j.left == j.right:
                raise ValueError(f"join {j}: cycle/self edges must be "
                                 f"inner (they are residual filters)")
        # Semi/anti right sides are pure filter tables: consumed by the
        # edge, so nothing downstream may reference their columns.
        self._consumed = tuple(j.right for j in self.joins
                               if j.kind in ("semi", "anti"))
        for j in self.joins:
            if j.kind not in ("semi", "anti"):
                continue
            uses = sum(1 for k in self.joins
                       if j.right in (k.left, k.right))
            if uses > 1:
                raise ValueError(
                    f"{j.kind} join {j}: filter table {j.right!r} may "
                    f"appear in no other join edge")
        # A left-outer edge NULL-pads its right table's columns; a later
        # join keyed on them would carry NULL_VALUE (-1) keys, which the
        # executor (correctly) refuses — reject at construction instead.
        # Outer queries execute in textual order, so "later" is textual;
        # edges BEFORE the outer join see the table pre-padding and are
        # fine (snowflake under an outer fact edge).
        for i, j in enumerate(self.joins):
            if j.kind != "left_outer":
                continue
            for k in self.joins[i + 1:]:
                if j.right in (k.left, k.right):
                    raise ValueError(
                        f"join {k} references {j.right!r} after left-outer "
                        f"join {j} NULL-padded its columns; joins on "
                        f"nullable columns are unsupported")
        for q in self.group_by:
            self._check_column_ref(q, "group_by")
            if q.partition(".")[0] in self._consumed:
                raise ValueError(f"group_by column {q!r} references a "
                                 f"semi/anti-consumed table")
        if self.aggregate is not None:
            kind = self.aggregate[0]
            if kind not in ("count", "sum", "min", "max", "avg"):
                raise ValueError(f"unknown aggregate {kind!r}")
            if kind != "count":
                ref = self.aggregate[1]
                self._check_column_ref(ref, kind)
                if ref.partition(".")[0] in self._consumed:
                    raise ValueError(f"{kind} column {ref!r} references a "
                                     f"semi/anti-consumed table")
        # The join graph must connect every table: a disconnected query
        # would need a cross product no stage expresses (the NumPy oracle
        # rejects it too, but at execution time — fail at construction).
        if len(self.tables) > 1:
            reached = {next(iter(self.tables))}
            frontier = True
            while frontier:
                frontier = False
                for j in self.joins:
                    if (j.left in reached) != (j.right in reached):
                        reached.update((j.left, j.right))
                        frontier = True
            missing = set(self.tables) - reached
            if missing:
                raise ValueError(f"join graph is disconnected: "
                                 f"{sorted(missing)} unreachable")

    def describe(self) -> str:
        parts = [f"{n}({t.size}{'σ' if t.filters else ''})"
                 for n, t in self.tables.items()]
        joins = " ⋈ ".join(str(j) for j in self.joins)
        gb = f" group by {list(self.group_by)}" if self.group_by else ""
        agg = f" -> {self.aggregate}" if self.aggregate else ""
        return f"[{', '.join(parts)}] {joins}{gb}{agg}"


# ---------------------------------------------------------------------------
# NumPy reference (textual join order) — the correctness oracle.
# ---------------------------------------------------------------------------

def _np_equijoin(left_cols: dict, right_cols: dict, left_q: str,
                 right_q: str) -> dict:
    """All matching row pairs of two qualified column sets (sort-merge)."""
    lk = left_cols[left_q].astype(np.int64)
    rk = right_cols[right_q].astype(np.int64)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    total = int(counts.sum())
    li = np.repeat(np.arange(lk.size), counts)
    # For row i of the left side, its matches are order[lo[i]:hi[i]]:
    # vectorized as lo repeated per match plus a within-group ramp.
    offsets = np.concatenate([[0], np.cumsum(counts)])
    within = np.arange(total) - np.repeat(offsets[:-1], counts)
    ri = order[np.repeat(lo, counts) + within]
    out = {q: v[li] for q, v in left_cols.items()}
    out.update({q: v[ri] for q, v in right_cols.items()})
    return out


def _np_left_outer(left_cols: dict, right_cols: dict, left_q: str,
                   right_q: str) -> dict:
    """Inner pairs plus NULL-padded unmatched left rows."""
    lk = left_cols[left_q].astype(np.int64)
    rk = right_cols[right_q].astype(np.int64)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    eff = np.maximum(counts, 1)               # unmatched rows emit once
    total = int(eff.sum())
    li = np.repeat(np.arange(lk.size), eff)
    offsets = np.concatenate([[0], np.cumsum(eff)])
    within = np.arange(total) - np.repeat(offsets[:-1], eff)
    matched = np.repeat(counts > 0, eff)
    ri = np.where(matched,
                  order[np.minimum(np.repeat(lo, eff) + within,
                                   max(rk.size - 1, 0))]
                  if rk.size else 0, 0)
    out = {q: v[li] for q, v in left_cols.items()}
    for q, v in right_cols.items():
        vals = v[ri] if v.shape[0] else np.zeros(total, v.dtype)
        out[q] = np.where(matched, vals, v.dtype.type(NULL_VALUE))
    return out


def reference_rows(query: Query) -> dict:
    """Fold the joins in textual order over filtered tables (pure NumPy)."""
    if not query.joins and len(query.tables) == 1:
        return next(iter(query.tables.values())).qualified()
    joined: dict[str, dict] = {}   # table name -> its current component cols
    absorbed: set[str] = set()     # semi/anti filter tables (consumed)

    def component_of(name: str) -> dict:
        if name not in joined:
            joined[name] = query.tables[name].qualified()
        return joined[name]

    for j in query.joins:
        left = component_of(j.left)
        if j.kind in ("semi", "anti"):
            # The right side is a validated pure filter table: keep left
            # rows by key membership, consume the table.
            right = query.tables[j.right].qualified()
            keep = np.isin(left[j.left_q], right[j.right_q])
            if j.kind == "anti":
                keep = ~keep
            merged = {q: v[keep] for q, v in left.items()}
            absorbed.add(j.right)
            for name, comp in list(joined.items()):
                if comp is left:
                    joined[name] = merged
            joined[j.right] = merged   # reachable, but contributes no cols
            continue
        right = component_of(j.right)
        if left is right:
            # Cycle edge within one component: a residual filter.
            merged = {q: v[left[j.left_q] == left[j.right_q]]
                      for q, v in left.items()}
        elif j.kind == "left_outer":
            merged = _np_left_outer(left, right, j.left_q, j.right_q)
        else:
            merged = _np_equijoin(left, right, j.left_q, j.right_q)
        for name, comp in list(joined.items()):
            if comp is left or comp is right:
                joined[name] = merged
    if not joined:
        return {}
    final = joined[query.joins[-1].left]
    if any(comp is not final for comp in joined.values()):
        raise ValueError("query's join graph is disconnected")
    return final


def rows_array(columns: dict) -> np.ndarray:
    """Canonical sorted (n, k) row array over sorted column names.

    Two executions are equivalent iff their ``rows_array`` outputs are
    identical — row order and column order are both normalized away.
    int64 unless a column is floating (grouped ``avg``), then float64 —
    both sides of a comparison compute the identical float64 division, so
    exact equality still holds.
    """
    names = sorted(columns)
    if not names:
        return np.empty((0, 0), dtype=np.int64)
    dtype = (np.float64 if any(np.issubdtype(columns[c].dtype, np.floating)
                               for c in names) else np.int64)
    mat = np.stack([columns[c].astype(dtype) for c in names], axis=1)
    return mat[np.lexsort(tuple(mat[:, k] for k in range(mat.shape[1] - 1,
                                                         -1, -1)))]


def apply_aggregate(columns: dict, aggregate: tuple | None):
    """Scalar sink over joined rows (host-side, int64-exact)."""
    if aggregate is None:
        return None
    kind = aggregate[0]
    if kind == "count":
        return int(next(iter(columns.values())).shape[0]) if columns else 0
    col = columns[aggregate[1]].astype(np.int64)
    if col.size == 0:
        return None if kind in ("min", "max", "avg") else 0
    if kind == "sum":
        return int(col.sum())
    if kind == "min":
        return int(col.min())
    if kind == "max":
        return int(col.max())
    return float(col.sum()) / col.size          # avg


def agg_output_name(aggregate: tuple) -> str:
    """Qualified name of the aggregate's output column in a grouped
    result (sorts after any ``table.column`` name, which keeps group keys
    leading in ``rows_array``'s canonical column order)."""
    return (f"~{aggregate[0]}()" if aggregate[0] == "count"
            else f"~{aggregate[0]}({aggregate[1]})")


def apply_group_by(columns: dict, group_by: tuple,
                   aggregate: tuple | None, wrap32: bool = False) -> dict:
    """Grouped aggregation over joined rows (the oracle's sink).

    Returns the group-key columns plus one aggregate column (named by
    ``agg_output_name``).  Count/min/max are int32; sums are exact int64
    (the wide device accumulator's semantics) unless ``wrap32=True``
    reproduces the legacy int32 wrap; avg is float64 of the (exact or
    wrapped) sum over the count.
    """
    aggregate = aggregate or ("count",)
    kind = aggregate[0]
    keys = np.stack([columns[q].astype(np.int64) for q in group_by], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    g = uniq.shape[0]
    cnt = np.bincount(inv, minlength=g).astype(np.int32)
    out = {q: uniq[:, i].astype(np.int32) for i, q in enumerate(group_by)}
    name = agg_output_name(aggregate)
    if kind == "count":
        out[name] = cnt
        return out
    vals = columns[aggregate[1]].astype(np.int64)
    sm = np.zeros(g, np.int64)
    np.add.at(sm, inv, vals)
    if wrap32:
        sm = sm.astype(np.int32)
    if kind == "sum":
        out[name] = sm
    elif kind == "avg":
        out[name] = sm.astype(np.float64) / np.maximum(cnt, 1)
    else:
        ext = np.full(g, 2**31 - 1 if kind == "min" else -(2**31), np.int64)
        (np.minimum if kind == "min" else np.maximum).at(ext, inv, vals)
        out[name] = ext.astype(np.int32)
    return out


def reference_execute(query: Query):
    """(sorted rows array, aggregate value) — the oracle for any join order.

    Grouped queries return the canonical group-row array with aggregate
    ``None`` (the aggregate is consumed per group, not a scalar).
    """
    cols = reference_rows(query)
    if query.group_by:
        return rows_array(apply_group_by(cols, query.group_by,
                                         query.aggregate,
                                         wrap32=query.wrap32)), None
    return rows_array(cols), apply_aggregate(cols, query.aggregate)


# ---------------------------------------------------------------------------
# Query generators (star / chain shapes for benchmarks, tests, workloads).
# ---------------------------------------------------------------------------

def make_star_query(fact_rows: int, dim_rows, *, selectivities=None,
                    seed: int = 0, aggregate: tuple | None = ("count",),
                    dim_tables=None, join_kinds=None,
                    group_by: tuple = ()) -> Query:
    """A star query: fact table F with one FK per dimension D0..Dk-1.

    Each dimension has a unique ``id`` key plus an ``a`` attribute in
    [0, 1000); ``selectivities[i]`` (None = no filter) adds a
    selectivity-annotated range filter on ``Di.a``.  ``dim_tables`` lets a
    caller (the workload generator's hot pool) supply recurring dimension
    tables so build-side caching pays across queries.  ``join_kinds[i]``
    (default inner) sets the variant of the i-th fact-dimension edge;
    ``group_by`` passes through to the Query (e.g. ``("F.g",)`` — the fact
    table always carries a low-cardinality ``g`` attribute to group on).
    """
    rng = np.random.default_rng(seed)
    dim_rows = list(dim_rows)
    selectivities = list(selectivities or [None] * len(dim_rows))
    join_kinds = list(join_kinds or ["inner"] * len(dim_rows))
    dims = list(dim_tables or [])
    for i in range(len(dims), len(dim_rows)):
        n = dim_rows[i]
        dims.append(Table(f"D{i}", {
            "id": rng.permutation(n).astype(np.int32),
            "a": rng.integers(0, 1000, size=n, dtype=np.int32)}))
    tables = {}
    fact_cols = {"m": rng.integers(0, 100, size=fact_rows, dtype=np.int32),
                 "g": rng.integers(0, 32, size=fact_rows, dtype=np.int32)}
    joins = []
    for i, d in enumerate(dims):
        sel = selectivities[i]
        if sel is not None:
            d = d.with_filters(Filter("a", 0, max(1, int(round(1000 * sel))),
                                      selectivity=sel))
        tables[d.name] = d
        fact_cols[f"fk{i}"] = rng.integers(0, dim_rows[i], size=fact_rows,
                                           dtype=np.int32)
        joins.append(Join("F", f"fk{i}", d.name, "id", kind=join_kinds[i]))
    tables["F"] = Table("F", fact_cols)
    return Query(tables=tables, joins=tuple(joins), aggregate=aggregate,
                 group_by=tuple(group_by))


def make_chain_query(sizes, *, seed: int = 0,
                     aggregate: tuple | None = ("count",)) -> Query:
    """A chain query T0 -> T1 -> ... : each table FK-references the next."""
    rng = np.random.default_rng(seed)
    sizes = list(sizes)
    tables = {}
    joins = []
    for i, n in enumerate(sizes):
        cols = {"id": rng.permutation(n).astype(np.int32),
                "v": rng.integers(0, 50, size=n, dtype=np.int32)}
        if i + 1 < len(sizes):
            cols["nxt"] = rng.integers(0, sizes[i + 1], size=n,
                                       dtype=np.int32)
            joins.append(Join(f"T{i}", "nxt", f"T{i+1}", "id"))
        tables[f"T{i}"] = Table(f"T{i}", cols)
    return Query(tables=tables, joins=tuple(joins), aggregate=aggregate)
