"""Logical-plan IR for multi-join queries (star / snowflake / chain shapes).

The engine executes one binary join per request; real analytical queries
chain several equi-joins over filtered base tables and end in an
aggregation.  This module is the *declarative* layer: named tables with
integer columns, selectivity-annotated range filters, a set of equi-join
edges, and an optional count/sum sink.  ``optimize.py`` turns a ``Query``
into a physical stage pipeline; ``executor.py`` runs it through the
engine.

Conventions:

  * columns are int32 NumPy arrays of equal length per table (the paper's
    4-byte-integer columnar layout, widened to many columns);
  * a row's identity is its position — join stages build core
    ``Relation``s with ``rid = arange(n)``, so match indices gather
    payload columns directly (``Relation.gather``'s convention);
  * qualified column names are ``"table.column"``; intermediates carry the
    union of their inputs' qualified columns.

A NumPy reference implementation (``reference_rows`` /
``reference_execute``) folds the joins in textual order; every physical
plan, whatever join order the optimizer picked, must reproduce exactly its
row multiset — that is the permutation-invariance contract the tests and
the ``query_pipeline`` benchmark enforce.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Filter:
    """Range predicate ``lo <= col < hi`` with a selectivity annotation.

    ``selectivity`` is the optimizer's estimate of the surviving fraction;
    when omitted it is estimated from the column's observed min/max under a
    uniformity assumption (the classic System-R default).
    """

    column: str
    lo: int
    hi: int
    selectivity: float | None = None

    def mask(self, col: np.ndarray) -> np.ndarray:
        return (col >= self.lo) & (col < self.hi)

    def estimate(self, col: np.ndarray) -> float:
        if self.selectivity is not None:
            return float(min(max(self.selectivity, 0.0), 1.0))
        if col.size == 0:
            return 1.0
        lo, hi = int(col.min()), int(col.max()) + 1
        width = max(1, hi - lo)
        covered = max(0, min(self.hi, hi) - max(self.lo, lo))
        return min(1.0, covered / width)


class Table:
    """A named base table: equal-length int32 columns plus scan filters."""

    def __init__(self, name: str, columns: dict, filters=()):
        self.name = name
        self.columns = {c: np.asarray(v, dtype=np.int32)
                        for c, v in columns.items()}
        sizes = {v.shape[0] for v in self.columns.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged columns in table {name!r}: {sizes}")
        self.filters = tuple(filters)
        self._filtered: "Table | None" = None
        self._ndv: dict[str, int] = {}

    @property
    def size(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def with_filters(self, *filters: Filter) -> "Table":
        return Table(self.name, self.columns, self.filters + tuple(filters))

    # -- executor side: actual data -----------------------------------------
    def filtered(self) -> "Table":
        """The table with its filters applied (memoized; no filters = self)."""
        if not self.filters:
            return self
        if self._filtered is None:
            mask = np.ones(self.size, dtype=bool)
            for f in self.filters:
                mask &= f.mask(self.columns[f.column])
            self._filtered = Table(
                self.name, {c: v[mask] for c, v in self.columns.items()})
        return self._filtered

    def qualified(self) -> dict:
        """Filtered columns under their qualified ``table.column`` names."""
        t = self.filtered()
        return {f"{self.name}.{c}": v for c, v in t.columns.items()}

    # -- optimizer side: estimates only -------------------------------------
    def est_rows(self) -> float:
        """Estimated post-filter cardinality (annotations, not data)."""
        est = float(self.size)
        for f in self.filters:
            est *= f.estimate(self.columns[f.column])
        return max(1.0, est)

    def ndv_est(self, column: str) -> float:
        """Estimated distinct values of ``column`` after filtering.

        Exact distinct count on the unfiltered column (cheap, memoized),
        capped by the estimated surviving rows — filtering a uniform
        fraction keeps at most that many distinct values.
        """
        if column not in self._ndv:
            self._ndv[column] = int(np.unique(self.columns[column]).size)
        return max(1.0, min(float(self._ndv[column]), self.est_rows()))


@dataclasses.dataclass(frozen=True)
class Join:
    """One equi-join edge: ``left.left_col == right.right_col``."""

    left: str
    left_col: str
    right: str
    right_col: str

    @property
    def left_q(self) -> str:
        return f"{self.left}.{self.left_col}"

    @property
    def right_q(self) -> str:
        return f"{self.right}.{self.right_col}"

    def __str__(self) -> str:
        return f"{self.left_q}={self.right_q}"


@dataclasses.dataclass
class Query:
    """A declarative multi-join query: tables, join edges, optional sink.

    ``joins`` in textual order is the naive left-deep baseline the
    optimizer must never price worse than.  ``aggregate`` is ``None`` (return
    the joined rows), ``("count",)``, or ``("sum", "table.column")``.
    """

    tables: dict
    joins: tuple
    aggregate: tuple | None = None

    def __post_init__(self):
        self.joins = tuple(self.joins)
        for j in self.joins:
            for side, col in ((j.left, j.left_col), (j.right, j.right_col)):
                if side not in self.tables:
                    raise ValueError(f"join {j} references unknown table "
                                     f"{side!r}")
                if col not in self.tables[side].columns:
                    raise ValueError(f"join {j}: no column {col!r} on "
                                     f"{side!r}")
        if self.aggregate is not None:
            kind = self.aggregate[0]
            if kind not in ("count", "sum"):
                raise ValueError(f"unknown aggregate {kind!r}")
            if kind == "sum":
                ref = self.aggregate[1]
                tbl, _, col = ref.partition(".")
                if (not col or tbl not in self.tables
                        or col not in self.tables[tbl].columns):
                    raise ValueError(f"sum over unknown column {ref!r}")
        # The join graph must connect every table: a disconnected query
        # would need a cross product no stage expresses (the NumPy oracle
        # rejects it too, but at execution time — fail at construction).
        if len(self.tables) > 1:
            reached = {next(iter(self.tables))}
            frontier = True
            while frontier:
                frontier = False
                for j in self.joins:
                    if (j.left in reached) != (j.right in reached):
                        reached.update((j.left, j.right))
                        frontier = True
            missing = set(self.tables) - reached
            if missing:
                raise ValueError(f"join graph is disconnected: "
                                 f"{sorted(missing)} unreachable")

    def describe(self) -> str:
        parts = [f"{n}({t.size}{'σ' if t.filters else ''})"
                 for n, t in self.tables.items()]
        joins = " ⋈ ".join(str(j) for j in self.joins)
        agg = f" -> {self.aggregate}" if self.aggregate else ""
        return f"[{', '.join(parts)}] {joins}{agg}"


# ---------------------------------------------------------------------------
# NumPy reference (textual join order) — the correctness oracle.
# ---------------------------------------------------------------------------

def _np_equijoin(left_cols: dict, right_cols: dict, left_q: str,
                 right_q: str) -> dict:
    """All matching row pairs of two qualified column sets (sort-merge)."""
    lk = left_cols[left_q].astype(np.int64)
    rk = right_cols[right_q].astype(np.int64)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    total = int(counts.sum())
    li = np.repeat(np.arange(lk.size), counts)
    # For row i of the left side, its matches are order[lo[i]:hi[i]]:
    # vectorized as lo repeated per match plus a within-group ramp.
    offsets = np.concatenate([[0], np.cumsum(counts)])
    within = np.arange(total) - np.repeat(offsets[:-1], counts)
    ri = order[np.repeat(lo, counts) + within]
    out = {q: v[li] for q, v in left_cols.items()}
    out.update({q: v[ri] for q, v in right_cols.items()})
    return out


def reference_rows(query: Query) -> dict:
    """Fold the joins in textual order over filtered tables (pure NumPy)."""
    joined: dict[str, dict] = {}   # table name -> its current component cols

    def component_of(name: str) -> dict:
        if name not in joined:
            joined[name] = query.tables[name].qualified()
        return joined[name]

    for j in query.joins:
        left = component_of(j.left)
        right = component_of(j.right)
        if left is right:
            # Cycle edge within one component: a residual filter.
            merged = {q: v[left[j.left_q] == left[j.right_q]]
                      for q, v in left.items()}
        else:
            merged = _np_equijoin(left, right, j.left_q, j.right_q)
        for name, comp in list(joined.items()):
            if comp is left or comp is right:
                joined[name] = merged
    if not joined:
        return {}
    final = joined[query.joins[-1].left]
    if any(comp is not final for comp in joined.values()):
        raise ValueError("query's join graph is disconnected")
    return final


def rows_array(columns: dict) -> np.ndarray:
    """Canonical sorted (n, k) int64 row array over sorted column names.

    Two executions are equivalent iff their ``rows_array`` outputs are
    identical — row order and column order are both normalized away.
    """
    names = sorted(columns)
    if not names:
        return np.empty((0, 0), dtype=np.int64)
    mat = np.stack([columns[c].astype(np.int64) for c in names], axis=1)
    return mat[np.lexsort(tuple(mat[:, k] for k in range(mat.shape[1] - 1,
                                                         -1, -1)))]


def apply_aggregate(columns: dict, aggregate: tuple | None):
    if aggregate is None:
        return None
    if aggregate[0] == "count":
        return int(next(iter(columns.values())).shape[0]) if columns else 0
    return int(columns[aggregate[1]].astype(np.int64).sum())


def reference_execute(query: Query):
    """(sorted rows array, aggregate value) — the oracle for any join order."""
    cols = reference_rows(query)
    return rows_array(cols), apply_aggregate(cols, query.aggregate)


# ---------------------------------------------------------------------------
# Query generators (star / chain shapes for benchmarks, tests, workloads).
# ---------------------------------------------------------------------------

def make_star_query(fact_rows: int, dim_rows, *, selectivities=None,
                    seed: int = 0, aggregate: tuple | None = ("count",),
                    dim_tables=None) -> Query:
    """A star query: fact table F with one FK per dimension D0..Dk-1.

    Each dimension has a unique ``id`` key plus an ``a`` attribute in
    [0, 1000); ``selectivities[i]`` (None = no filter) adds a
    selectivity-annotated range filter on ``Di.a``.  ``dim_tables`` lets a
    caller (the workload generator's hot pool) supply recurring dimension
    tables so build-side caching pays across queries.
    """
    rng = np.random.default_rng(seed)
    dim_rows = list(dim_rows)
    selectivities = list(selectivities or [None] * len(dim_rows))
    dims = list(dim_tables or [])
    for i in range(len(dims), len(dim_rows)):
        n = dim_rows[i]
        dims.append(Table(f"D{i}", {
            "id": rng.permutation(n).astype(np.int32),
            "a": rng.integers(0, 1000, size=n, dtype=np.int32)}))
    tables = {}
    fact_cols = {"m": rng.integers(0, 100, size=fact_rows, dtype=np.int32)}
    joins = []
    for i, d in enumerate(dims):
        sel = selectivities[i]
        if sel is not None:
            d = d.with_filters(Filter("a", 0, max(1, int(round(1000 * sel))),
                                      selectivity=sel))
        tables[d.name] = d
        fact_cols[f"fk{i}"] = rng.integers(0, dim_rows[i], size=fact_rows,
                                           dtype=np.int32)
        joins.append(Join("F", f"fk{i}", d.name, "id"))
    tables["F"] = Table("F", fact_cols)
    return Query(tables=tables, joins=tuple(joins), aggregate=aggregate)


def make_chain_query(sizes, *, seed: int = 0,
                     aggregate: tuple | None = ("count",)) -> Query:
    """A chain query T0 -> T1 -> ... : each table FK-references the next."""
    rng = np.random.default_rng(seed)
    sizes = list(sizes)
    tables = {}
    joins = []
    for i, n in enumerate(sizes):
        cols = {"id": rng.permutation(n).astype(np.int32),
                "v": rng.integers(0, 50, size=n, dtype=np.int32)}
        if i + 1 < len(sizes):
            cols["nxt"] = rng.integers(0, sizes[i + 1], size=n,
                                       dtype=np.int32)
            joins.append(Join(f"T{i}", "nxt", f"T{i+1}", "id"))
        tables[f"T{i}"] = Table(f"T{i}", cols)
    return Query(tables=tables, joins=tuple(joins), aggregate=aggregate)
