"""Pipelined execution of a physical plan over the join-query engine.

Each ``PipelineStage`` becomes one ``JoinQuery`` submitted through
``JoinQueryService.submit_deferred``: a stage waits only on the stages
whose outputs it consumes, so independent subtrees of a bushy plan sit in
the admission queue together and overlap on the two device groups exactly
like unrelated queries do (C-only/G-only concurrency).  Between stages the
match indices are materialized into qualified payload columns with the
``rid = arange(n)`` gather convention (Ozawa et al.'s point that
pipelining intermediates between operators, not re-scanning, is the
dominant win).

Reuse falls out of the engine untouched: a stage's build side is
fingerprinted like any other query, so a dimension table shared by many
queries hits the build-table cache (SHJ) or the partition-layout cache
(PHJ) after its first use.

Capacity planning: a stage's result buffer is sized from an exact
host-side match count (two ``searchsorted`` passes over the build keys) —
estimates drive *ordering*, but capacities must never truncate.  Deeper
stages get higher admission priority so in-flight pipelines drain before
fresh root stages are admitted.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.core.relation import Relation, next_pow2
from repro.engine.service import JoinQuery, JoinQueryService

from .optimize import JoinOrderOptimizer, PhysicalPlan
from .plan import Query, apply_aggregate, rows_array

# Filler keys for padding tiny/empty stage inputs up to a minimum size.
# Distinct negative values per side: they match neither real keys (>= 0)
# nor the engine's own pad sentinels (-2/-3) nor each other.
BUILD_FILL_KEY = -6
PROBE_FILL_KEY = -7
MIN_STAGE_ROWS = 64


def _as_relation(col: np.ndarray, fill_key: int) -> Relation:
    """A core Relation over a column, rid = row index (gather convention)."""
    n = col.shape[0]
    if n and int(col.min()) < 0:
        raise ValueError(
            "negative join-key values are unsupported: they collide with "
            "the executor's fill keys and the engine's pad sentinels")
    rid = np.arange(n, dtype=np.int32)
    if n < MIN_STAGE_ROWS:
        pad = MIN_STAGE_ROWS - n
        col = np.concatenate([col.astype(np.int32),
                              np.full(pad, fill_key, np.int32)])
        rid = np.concatenate([rid, np.full(pad, -1, np.int32)])
    return Relation(jnp.asarray(rid), jnp.asarray(col, dtype=jnp.int32))


def _apply_residual(cols: dict, left_q: str, right_q: str) -> dict:
    """Cycle-edge equality filter over one component's columns."""
    mask = cols[left_q] == cols[right_q]
    return {q: v[mask] for q, v in cols.items()}


def _match_count(build_keys: np.ndarray, probe_keys: np.ndarray) -> int:
    """Exact join cardinality (host-side sort + two searchsorted passes)."""
    bk = np.sort(build_keys.astype(np.int64), kind="stable")
    pk = probe_keys.astype(np.int64)
    return int((np.searchsorted(bk, pk, side="right")
                - np.searchsorted(bk, pk, side="left")).sum())


@dataclasses.dataclass
class PipelineResult:
    """Outcome of one pipelined query execution."""

    columns: dict                 # final qualified columns (NumPy)
    rows: int
    aggregate: object             # None | int
    outcomes: list                # QueryOutcome per stage, stage order
    wall_s: float
    physical: PhysicalPlan

    def rows_array(self) -> np.ndarray:
        return rows_array(self.columns)

    def to_dict(self) -> dict:
        return {"rows": self.rows, "aggregate": self.aggregate,
                "wall_s": self.wall_s,
                "est_total_s": self.physical.est_total_s,
                "stages": [o.to_dict() for o in self.outcomes]}


class PipelineExecutor:
    """Runs physical plans through a (possibly shared) JoinQueryService."""

    def __init__(self, service: JoinQueryService | None = None,
                 optimizer: JoinOrderOptimizer | None = None):
        self.service = service or JoinQueryService(num_workers=2)
        self.optimizer = optimizer or JoinOrderOptimizer(self.service.planner)
        self._qid = itertools.count(1)

    def close(self):
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- the pipeline --------------------------------------------------------
    def run(self, query: Query,
            physical: PhysicalPlan | None = None) -> PipelineResult:
        """Execute ``query`` under ``physical`` (optimized when omitted)."""
        if physical is None:
            physical = self.optimizer.optimize(query)
        base = {name: t.qualified() for name, t in query.tables.items()}
        # Residual (cycle-edge) filters on base tables apply at scan time;
        # the rest are grouped by the stage whose output they filter.
        stage_residuals: dict[int, list] = {}
        for ref, lq, rq in physical.residuals:
            if isinstance(ref, str):
                base[ref] = _apply_residual(base[ref], lq, rq)
            else:
                stage_residuals.setdefault(ref, []).append((lq, rq))
        if not physical.stages:
            if len(base) != 1:
                raise ValueError("plan has no stages but several tables")
            cols = next(iter(base.values()))
            return PipelineResult(
                columns=cols,
                rows=next(iter(cols.values())).shape[0] if cols else 0,
                aggregate=apply_aggregate(cols, query.aggregate),
                outcomes=[], wall_s=0.0, physical=physical)

        inter: dict[int, dict] = {}        # stage id -> qualified columns
        depth: dict[int, int] = {}
        handles: dict[int, object] = {}
        t0 = time.perf_counter()
        for stage in physical.stages:
            depth[stage.stage_id] = 1 + max(
                [depth[d] for d in stage.deps], default=0)
            handles[stage.stage_id] = self.service.submit_deferred(
                self._stage_query_fn(stage, base, inter),
                deps=[handles[d] for d in stage.deps],
                finalize=self._stage_finalize_fn(
                    stage, base, inter,
                    stage_residuals.get(stage.stage_id, ())),
                priority=depth[stage.stage_id])
        outcomes = [handles[s.stage_id]() for s in physical.stages]
        wall = time.perf_counter() - t0
        final = inter[physical.stages[-1].stage_id]
        return PipelineResult(
            columns=final,
            rows=next(iter(final.values())).shape[0] if final else 0,
            aggregate=apply_aggregate(final, query.aggregate),
            outcomes=outcomes, wall_s=wall, physical=physical)

    # -- per-stage plumbing --------------------------------------------------
    def _input_cols(self, ref, base, inter) -> dict:
        return base[ref] if isinstance(ref, str) else inter[ref]

    def _stage_query_fn(self, stage, base, inter):
        def make_query(_dep_outcomes) -> JoinQuery:
            bcols = self._input_cols(stage.build_input, base, inter)
            pcols = self._input_cols(stage.probe_input, base, inter)
            bkey = bcols[stage.build_col]
            pkey = pcols[stage.probe_col]
            matches = _match_count(bkey, pkey)
            # Power-of-two capacity: stable across repeats of the same
            # pipeline (compile-cache friendly) with headroom for the
            # executor's per-group split slack.
            max_out = next_pow2(max(4 * MIN_STAGE_ROWS,
                                    matches + matches // 4 + 256))
            return JoinQuery(
                build=_as_relation(bkey, BUILD_FILL_KEY),
                probe=_as_relation(pkey, PROBE_FILL_KEY),
                tag=f"stage{stage.stage_id}:{stage.join}",
                max_out=max_out, query_id=next(self._qid))
        return make_query

    def _stage_finalize_fn(self, stage, base, inter, residuals=()):
        def finalize(outcome) -> None:
            bcols = self._input_cols(stage.build_input, base, inter)
            pcols = self._input_cols(stage.probe_input, base, inter)
            c = int(outcome.result.count)
            pr = np.asarray(outcome.result.probe_rid[:c])
            br = np.asarray(outcome.result.build_rid[:c])
            cols = {q: v[pr] for q, v in pcols.items()}
            cols.update({q: v[br] for q, v in bcols.items()})
            for lq, rq in residuals:
                cols = _apply_residual(cols, lq, rq)
            inter[stage.stage_id] = cols
        return finalize

    # -- convenience ---------------------------------------------------------
    def run_optimized(self, query: Query):
        """(chosen physical plan, result) in one call."""
        physical = self.optimizer.optimize(query)
        return physical, self.run(query, physical)
