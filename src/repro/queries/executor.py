"""Pipelined execution of a physical plan over the join-query engine.

Each ``PipelineStage`` becomes one ``JoinQuery`` submitted through
``JoinQueryService.submit_deferred``: a stage waits only on the stages
whose outputs it consumes, so independent subtrees of a bushy plan sit in
the admission queue together and overlap on the two device groups exactly
like unrelated queries do (C-only/G-only concurrency).

Stage hand-off is **device-resident** by default (``handoff="device"``):
a stage's output is a lazy ``StageView`` — the join result's probe/build
rid vectors, still on device, plus back-pointers to the source views —
generalizing the fused-scan composition from base-table filters to *all*
intermediates.  A downstream stage's key (and, at the very end, payload)
gathers compose rid chains (``take(take(col, rid1), rid2)``) jitted on
device via ``core.relation.IndexChain``, so a 3-join star moves zero
intermediate column data through the host: only the exact-cardinality
match counts (and O(1) validation scalars) cross, because capacities must
be planned host-side.  The paper's core lesson applied between operators
— intermediates stop crossing the slow boundary (Ozawa et al.'s
data-path fusion).  ``handoff="host"`` keeps the legacy materialize path
(every stage gathers its qualified columns to NumPy and re-uploads the
next stage's inputs) as a measurable baseline; either path reports the
bytes it moved through ``host_bytes_moved``.

Scan fusion: filtered base tables are NOT materialized before their first
join.  A ``_ScanView`` computes the filter's surviving row index once and
composes it directly into whatever gather consumes the table — the stage's
key column, or the stage output's payload gather — so a 2%-selective
dimension never copies its full column set through the mask on the host.

Join variants ride the same pipeline: a semi/anti stage builds on its
filter table and emits only probe-side rows — the flag path is gather-free
and its rid vector composes directly into downstream chains; a left-outer
stage NULL-fills (``NULL_VALUE``) the build columns of unmatched rows,
carried as a device NULL mask that composes through later gathers.  A
``group_by`` query ends in one more engine submission — a ``GroupByQuery``
through the same admission queue — whose key/value inputs the fused path
hands over as device arrays (the sink consumes the view).

Reuse falls out of the engine untouched: a stage's build side is
fingerprinted like any other query, so a dimension table shared by many
queries hits the build-table cache (SHJ) or the partition-layout caches
(PHJ, both sides) after its first use.

Capacity planning: a stage's result buffer is sized from an exact match
count (two ``searchsorted`` passes over the build keys — on device for
the fused path, host-side NumPy for the materialize path); estimates
drive *ordering*, but capacities must never truncate.  Deeper stages get
higher admission priority so in-flight pipelines drain before fresh root
stages are admitted.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.relation import IndexChain, Relation, next_pow2
from repro.engine.service import GroupByQuery, JoinQuery, JoinQueryService
from repro.obs import q_error

from .optimize import JoinOrderOptimizer, PhysicalPlan
from .plan import (NULL_VALUE, Query, agg_output_name, apply_aggregate,
                   rows_array)

# Filler keys for padding tiny/empty stage inputs up to a minimum size.
# Distinct negative values per side: they match neither real keys (>= 0)
# nor the engine's own pad sentinels (-2/-3) nor each other.
BUILD_FILL_KEY = -6
PROBE_FILL_KEY = -7
MIN_STAGE_ROWS = 64

HANDOFF_MODES = ("device", "host")


class _ScanView:
    """Lazy filtered scan of a base table (fused filter pushdown).

    Holds the raw columns plus the surviving row index; columns are
    gathered on demand, and ``take`` composes the scan index with a
    consumer's row selection so the filtered table is never materialized
    as a whole intermediate.  ``raw_chain``/``col_dev`` are the
    device-resident face of the same idea: the scan index becomes the
    root link of a downstream ``IndexChain``.
    """

    def __init__(self, table):
        self._name = table.name
        self._cols = table.columns          # raw, unfiltered
        self._idx = table.scan_indices()    # None = no filters
        self._memo: dict = {}
        self._dev_memo: dict = {}
        self._chain: IndexChain | None = None
        self._fp_memo: dict = {}
        self._rows_tok: str | None = None

    @property
    def n(self) -> int:
        if self._idx is not None:
            return int(self._idx.shape[0])
        return next(iter(self._cols.values())).shape[0] if self._cols else 0

    def names(self):
        return [f"{self._name}.{c}" for c in self._cols]

    def _raw(self, q: str) -> np.ndarray:
        return self._cols[q.partition(".")[2]]

    def col(self, q: str) -> np.ndarray:
        """One filtered column (memoized — typically just the join key)."""
        if q not in self._memo:
            raw = self._raw(q)
            self._memo[q] = raw if self._idx is None else raw[self._idx]
        return self._memo[q]

    # -- device-resident protocol -------------------------------------------
    def raw_chain(self, q: str):
        """(raw host column, IndexChain into it, NULL mask) for ``q``.

        Base tables have no NULL mask; the chain is the scan index (or
        the identity when unfiltered).  The chain object is cached either
        way: downstream ``StageView._extend`` shares extensions per
        source-chain identity, so every column of this table must see the
        same object.
        """
        if self._chain is None:
            self._chain = (IndexChain() if self._idx is None else
                           IndexChain((jnp.asarray(self._idx,
                                                   dtype=jnp.int32),)))
        return self._raw(q), self._chain, None

    def col_dev(self, q: str) -> jax.Array:
        """One filtered column as a device array (memoized)."""
        if q not in self._dev_memo:
            raw, chain, _ = self.raw_chain(q)
            self._dev_memo[q] = chain.gather(raw)
        return self._dev_memo[q]

    def _rows_token(self) -> str:
        """Content token for the surviving-row selection."""
        if self._rows_tok is None:
            h = hashlib.sha1()
            if self._idx is None:
                h.update(b"all")
            else:
                h.update(np.asarray(self._idx).tobytes())
            h.update(f"|n={self.n}".encode())
            self._rows_tok = h.hexdigest()
        return self._rows_tok

    def col_fp(self, q: str) -> str:
        """Content fingerprint of one *filtered* column, computed entirely
        host-side (the raw columns live on host): sha1 over the raw bytes
        plus the scan-index token.  Equal content — even regenerated by a
        different ``Query`` object — hashes equal, which is what keeps the
        build-table cache hitting across repeated workloads without ever
        pulling a device column back to compute its key."""
        fp = self._fp_memo.get(q)
        if fp is None:
            h = hashlib.sha1()
            h.update(self._raw(q).tobytes())
            h.update(self._rows_token().encode())
            fp = self._fp_memo[q] = h.hexdigest()
        return fp

    def take(self, rows: np.ndarray) -> dict:
        """All columns at the given (filtered-space) row positions.

        The scan index composes into the gather: one indexed read of each
        raw column instead of filter-materialize + gather.
        """
        if self._idx is not None:
            rows = self._idx[rows]
        return {f"{self._name}.{c}": v[rows] for c, v in self._cols.items()}

    def materialize(self) -> dict:
        return self.take(np.arange(self.n)) if self._idx is not None else \
            {f"{self._name}.{c}": v for c, v in self._cols.items()}

    def narrow(self, keep: np.ndarray) -> None:
        """Restrict to a boolean mask over current (filtered) rows —
        residual cycle-edge filters applied at scan time."""
        cur = (self._idx if self._idx is not None
               else np.arange(self.n))
        self._idx = cur[keep]
        self._memo.clear()
        self._dev_memo.clear()
        self._chain = None
        self._fp_memo.clear()
        self._rows_tok = None


@functools.partial(jax.jit, static_argnames=("kind",))
def _match_stats_jit(bkey: jax.Array, pkey: jax.Array, kind: str):
    """Exact stage output cardinality, computed on device (two
    searchsorted passes over the sorted build keys — the fused analogue
    of the host-side NumPy count).  Only the build side is sorted —
    ``method="scan"`` is a vectorized binary search, O(log b) gathers
    over the probe column; the sort-based method would sort the large
    probe side and lose to the host path at scale."""
    bk = jnp.sort(bkey)
    lo = jnp.searchsorted(bk, pkey, side="left", method="scan")
    hi = jnp.searchsorted(bk, pkey, side="right", method="scan")
    counts = hi - lo
    if kind == "semi":
        return (counts > 0).sum()
    if kind == "anti":
        return (counts == 0).sum()
    if kind == "left_outer":
        return jnp.maximum(counts, 1).sum()
    return counts.sum()


@jax.jit
def _gather_mask(mask: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(mask, idx, axis=0)


@jax.jit
def _null_fill(col: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.where(mask, jnp.int32(NULL_VALUE), col)


class StageView:
    """Device-resident view of one join stage's output.

    Holds the engine's match-index vectors (``probe_rid``/``build_rid``,
    sliced to the valid count but still on device) plus back-pointers to
    the stage's input views.  Column access composes the source's index
    chain with the match vector — nothing is gathered until a key column
    is needed for the next stage, and payload columns are only gathered
    once, at final materialization, each via a single flattened-chain
    device gather.  Left-outer NULLs ride along as a device mask that
    composes through downstream gathers the same way.
    """

    def __init__(self, kind: str, psrc, bsrc, probe_rid, build_rid,
                 count: int, token: str | None = None):
        self.kind = kind
        self._psrc, self._bsrc = psrc, bsrc
        self._pr = probe_rid
        self._br = build_rid
        self.n = int(count)
        self._pset = set(psrc.names())
        self._rc_memo: dict = {}
        self._col_memo: dict = {}
        self._ext_memo: dict = {}
        # Structural execution token: sha1 over (stage kind, both input
        # column fingerprints, the *executed* QueryPlan's full knob set,
        # match count) — the engine is a deterministic function of those,
        # so equal tokens imply equal output content.  Downstream stages
        # derive their input fingerprints from it without a D2H pull;
        # ``None`` (no fingerprints available) falls back to the ledgered
        # content-hash path in the service.
        self._token = token

    def names(self):
        names = list(self._psrc.names())
        if self.kind not in ("semi", "anti"):
            names += self._bsrc.names()
        return names

    def _extend(self, chain: IndexChain, rid, tag: str) -> IndexChain:
        """Chain extension memoized per (source chain, side): columns of
        one table share the flattened index instead of re-folding it."""
        key = (id(chain), tag)
        ext = self._ext_memo.get(key)
        if ext is None:
            ext = chain.extend(rid)
            self._ext_memo[key] = (chain, ext)   # hold chain: id stability
        else:
            ext = ext[1]
        return ext

    def raw_chain(self, q: str):
        """(raw host column, IndexChain, NULL mask) — the composable form
        downstream stages extend (memoized per column)."""
        hit = self._rc_memo.get(q)
        if hit is not None:
            return hit
        if q in self._pset:
            raw, chain, mask = self._psrc.raw_chain(q)
            chain = self._extend(chain, self._pr, "p")
            if mask is not None:
                mask = _gather_mask(mask, self._pr)
            out = (raw, chain, mask)
        elif self.kind == "left_outer":
            if self._bsrc.n == 0:
                # Filtered-to-nothing build side: every row is NULL; the
                # chain gathers a 1-row zero stand-in nobody reads.
                out = (np.zeros(1, np.int32),
                       IndexChain((jnp.zeros(self.n, jnp.int32),)),
                       jnp.ones(self.n, bool))
            else:
                raw, chain, mask = self._bsrc.raw_chain(q)
                matched = self._br >= 0
                chain = self._extend(chain, jnp.maximum(self._br, 0), "b")
                null = ~matched
                if mask is not None:
                    null = null | _gather_mask(mask,
                                               jnp.maximum(self._br, 0))
                out = (raw, chain, null)
        else:
            raw, chain, mask = self._bsrc.raw_chain(q)
            chain = self._extend(chain, self._br, "b")
            if mask is not None:
                mask = _gather_mask(mask, self._br)
            out = (raw, chain, mask)
        self._rc_memo[q] = out
        return out

    def col_dev(self, q: str) -> jax.Array:
        """One output column as a device array (memoized): a single
        flattened-chain gather, NULL-masked when an outer edge applies."""
        if q not in self._col_memo:
            raw, chain, mask = self.raw_chain(q)
            col = chain.gather(raw)
            if mask is not None:
                col = _null_fill(col, mask)
            self._col_memo[q] = col
        return self._col_memo[q]

    def col_fp(self, q: str) -> str | None:
        """Structural fingerprint of one output column: the execution
        token qualified by the column name.  No array bytes are read —
        soundness comes from the token construction (deterministic engine
        over fingerprinted inputs)."""
        if self._token is None:
            return None
        return f"{self._token}|col={q}"

    def materialize(self) -> dict:
        """Host columns — final result delivery only (one D2H per
        column; intermediates never take this path on the fused route)."""
        return {q: np.asarray(self.col_dev(q)) for q in self.names()}

    def narrow(self, keep_idx) -> None:
        """Restrict to the given (device) row indices — residual
        cycle-edge filters applied to this stage's output."""
        self._pr = jnp.take(self._pr, keep_idx, axis=0)
        if self._br is not None:
            self._br = jnp.take(self._br, keep_idx, axis=0)
        self.n = int(keep_idx.shape[0])
        self._rc_memo.clear()
        self._col_memo.clear()
        self._ext_memo.clear()
        self._token = None      # content changed; caller re-derives

    def apply_residual(self, left_q: str, right_q: str) -> None:
        """Equality filter between two output columns, on device: the
        surviving index is computed with a sized nonzero (one scalar count
        crosses to the host, never the mask itself)."""
        mask = self.col_dev(left_q) == self.col_dev(right_q)
        k = int(mask.sum())
        tok = self._token
        self.narrow(jnp.nonzero(mask, size=k)[0] if k else
                    jnp.zeros(0, jnp.int32))
        if tok is not None:
            # The residual is a deterministic function of the pre-filter
            # content, so the token extends instead of dying.
            self._token = hashlib.sha1(
                f"{tok}|res:{left_q}={right_q}|k={k}".encode()).hexdigest()


def _src_n(src) -> int:
    if isinstance(src, (_ScanView, StageView)):
        return src.n
    return next(iter(src.values())).shape[0] if src else 0


def _src_names(src) -> list:
    if isinstance(src, (_ScanView, StageView)):
        return src.names()
    return list(src)


def _src_col(src, q: str) -> np.ndarray:
    return src.col(q) if isinstance(src, _ScanView) else src[q]


def _src_take(src, rows: np.ndarray) -> dict:
    if isinstance(src, _ScanView):
        return src.take(rows)
    return {q: v[rows] for q, v in src.items()}


def _as_relation(col: np.ndarray, fill_key: int) -> Relation:
    """A core Relation over a host column, rid = row index (gather
    convention) — the host-materialize path's H2D upload.

    The fingerprint hint is a content hash computed from the *host* copy
    before the upload, so the engine's cache keying never pulls the
    column back down — content-equal inputs still share a cache line.
    """
    n = col.shape[0]
    if n and int(col.min()) < 0:
        raise ValueError(
            "negative join-key values are unsupported: they collide with "
            "the executor's fill keys and the engine's pad sentinels")
    col = np.asarray(col, dtype=np.int32)
    rid = np.arange(n, dtype=np.int32)
    if n < MIN_STAGE_ROWS:
        pad = MIN_STAGE_ROWS - n
        col = np.concatenate([col, np.full(pad, fill_key, np.int32)])
        rid = np.concatenate([rid, np.full(pad, -1, np.int32)])
    h = hashlib.sha1(col.tobytes())
    h.update(rid.tobytes())
    return Relation(jnp.asarray(rid), jnp.asarray(col),
                    fp_hint=f"host:{h.hexdigest()}")


def _as_relation_dev(col: jax.Array, fill_key: int,
                     fp_hint: str | None = None) -> Relation:
    """Device twin of ``_as_relation``: the column never leaves the
    device (the caller has already validated keys non-negative).
    ``fp_hint`` is the source view's structural column fingerprint;
    the fill key and row count pin down the padding this function adds,
    making the hint content-complete for the padded relation."""
    n = int(col.shape[0])
    rid = jnp.arange(n, dtype=jnp.int32)
    col = col.astype(jnp.int32)
    if n < MIN_STAGE_ROWS:
        pad = MIN_STAGE_ROWS - n
        col = jnp.concatenate([col, jnp.full(pad, fill_key, jnp.int32)])
        rid = jnp.concatenate([rid, jnp.full(pad, -1, jnp.int32)])
    hint = (f"{fp_hint}|fill={fill_key}|n={n}"
            if fp_hint is not None else None)
    return Relation(rid, col, fp_hint=hint)


def _check_keys_nonneg(*keys) -> None:
    """Negative-key validation for the fused path: only O(1) scalars
    (the mins) cross the host boundary."""
    for k in keys:
        if k.shape[0] and int(k.min()) < 0:
            raise ValueError(
                "negative join-key values are unsupported: they collide "
                "with the executor's fill keys and the engine's pad "
                "sentinels")


def _apply_residual(cols: dict, left_q: str, right_q: str) -> dict:
    """Cycle-edge equality filter over one component's columns."""
    mask = cols[left_q] == cols[right_q]
    return {q: v[mask] for q, v in cols.items()}


def _match_count(build_keys: np.ndarray, probe_keys: np.ndarray,
                 kind: str = "inner") -> int:
    """Exact stage output cardinality (host-side searchsorted passes)."""
    bk = np.sort(build_keys.astype(np.int64), kind="stable")
    pk = probe_keys.astype(np.int64)
    counts = (np.searchsorted(bk, pk, side="right")
              - np.searchsorted(bk, pk, side="left"))
    if kind == "semi":
        return int((counts > 0).sum())
    if kind == "anti":
        return int((counts == 0).sum())
    if kind == "left_outer":
        return int(np.maximum(counts, 1).sum())
    return int(counts.sum())


def _mark_degraded(make_query):
    """Wrap a stage's query factory so the stage runs on the planner's
    cheapest plan — the whole-pipeline degrade admission promised."""
    def wrapped(dep_outcomes):
        q = make_query(dep_outcomes)
        q.degraded = True
        return q
    return wrapped


@dataclasses.dataclass
class PipelineResult:
    """Outcome of one pipelined query execution.

    ``columns`` materializes lazily: the fused path delivers the final
    intermediate as a device view, and a count-sink query never needs the
    payload gathered at all.  Accessing ``columns``/``rows_array`` pulls
    it to host once (result delivery — not counted as intermediate
    traffic).
    """

    rows: int
    aggregate: object             # None | int | float
    outcomes: list                # QueryOutcome per stage (+ group-by sink)
    wall_s: float
    physical: PhysicalPlan
    _source: object = None        # dict | _ScanView | StageView
    _columns: dict | None = None
    _ledger: object = None        # TransferLedger for result attribution
    # Structured record of every adaptive mid-pipeline re-ordering this
    # execution performed (empty for static runs).
    replans: list = dataclasses.field(default_factory=list)

    @property
    def columns(self) -> dict:
        """Final qualified columns (NumPy), materialized on first use."""
        if self._columns is None:
            src = self._source
            self._columns = src if isinstance(src, dict) else \
                src.materialize()
            if self._ledger is not None and isinstance(src, StageView):
                self._ledger.record(
                    sum(v.nbytes for v in self._columns.values()),
                    cause="result", stage="result", column="*",
                    direction="d2h")
        return self._columns

    @property
    def host_bytes_moved(self) -> int:
        """Intermediate hand-off bytes across all stages (+ sink)."""
        return sum(o.host_bytes_moved for o in self.outcomes)

    def rows_array(self) -> np.ndarray:
        return rows_array(self.columns)

    def to_dict(self) -> dict:
        return {"rows": self.rows, "aggregate": self.aggregate,
                "wall_s": self.wall_s,
                "est_total_s": self.physical.est_total_s,
                "host_bytes_moved": self.host_bytes_moved,
                "replans": list(self.replans),
                "stages": [o.to_dict() for o in self.outcomes]}


class PipelineExecutor:
    """Runs physical plans through a (possibly shared) JoinQueryService.

    ``handoff`` selects the stage hand-off data path: ``"device"`` (the
    fused default — intermediates stay resident as ``StageView``s) or
    ``"host"`` (materialize every stage's qualified columns to NumPy; the
    pre-fusion baseline the benchmark compares against).

    ``adaptive=True`` turns on mid-pipeline re-optimization (fused path
    only): stages execute in dependency waves, every completed stage's
    exact device-observed cardinality is compared against the optimizer's
    estimate, and when the worst q-error in a wave crosses
    ``qerror_threshold`` the not-yet-admitted tail is re-priced from the
    observed numbers (``JoinOrderOptimizer.reprice_remaining``) and
    re-ordered if the challenger clears the planner's replan margin.
    Cardinality *recording* is always on — adaptivity only changes
    whether the pipeline acts on it.
    """

    def __init__(self, service: JoinQueryService | None = None,
                 optimizer: JoinOrderOptimizer | None = None,
                 handoff: str = "device", *, adaptive: bool = False,
                 qerror_threshold: float = 2.0):
        if handoff not in HANDOFF_MODES:
            raise ValueError(f"unknown handoff mode {handoff!r}")
        self.service = service or JoinQueryService(num_workers=2)
        self.optimizer = optimizer or JoinOrderOptimizer(
            self.service.planner, handoff=handoff)
        self.handoff = handoff
        self.adaptive = bool(adaptive)
        self.qerror_threshold = float(qerror_threshold)
        self._qid = itertools.count(1)

    def close(self, drain: bool = True):
        self.service.close(drain=drain)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _degraded_total_s(self, physical: PhysicalPlan) -> float | None:
        """The pipeline's total estimate when every stage runs on the
        planner's cheapest plan — the degrade option admission weighs
        before shedding a whole pipeline."""
        try:
            total = 0.0
            for s in physical.stages:
                p = self.service.planner.choose_degraded(
                    max(s.est_build, 1), max(s.est_probe, 1),
                    max_out=self._stage_capacity(s.est_out),
                    cached=False, kind=s.kind, record=False)
                total += float(p.est_s)
            if physical.agg_plan is not None:
                total += float(physical.agg_plan.est_s)
            return total
        except Exception:
            return None

    # -- the pipeline --------------------------------------------------------
    def run(self, query: Query, physical: PhysicalPlan | None = None, *,
            tenant: str = "default",
            deadline_s: float | None = None) -> PipelineResult:
        """Execute ``query`` under ``physical`` (optimized when omitted).

        ``tenant``/``deadline_s`` bill the whole pipeline to one workload
        container: admission decides *once*, at the root, on the plan's
        total estimate (``est_total_s``) — the pipeline is admitted,
        degraded (every stage re-priced to the cheapest plan), or shed
        coherently with a structured ``Backpressure``, never half-run.
        Stages then carry the inherited tenant and absolute deadline
        through the queue pre-admitted.

        A pipeline failure lands in the service's flight recorder (which
        auto-dumps a post-mortem bundle); stage-level failures were
        already recorded where they happened and are not re-recorded.
        """
        from repro.engine.admission import QueueFull
        try:
            return self._run_pipeline(query, physical, tenant=tenant,
                                      deadline_s=deadline_s)
        except Exception as e:
            if (not isinstance(e, QueueFull)      # sheds are not failures
                    and not getattr(e, "_svc_failure_counted", False)):
                e._svc_failure_counted = True
                self.service.flight.record_failure(
                    tenant=tenant, where="pipeline", error=repr(e))
            raise

    def _run_pipeline(self, query: Query,
                      physical: PhysicalPlan | None = None, *,
                      tenant: str = "default",
                      deadline_s: float | None = None) -> PipelineResult:
        if physical is None:
            physical = self.optimizer.optimize(query)
        with self.service.tracer.span("pipeline", tenant=tenant,
                                      stages=len(physical.stages),
                                      handoff=self.handoff):
            deadline_at, degraded = self.service.admit_pipeline(
                tenant=tenant, est_s=physical.est_total_s,
                deadline_s=deadline_s, query_id=next(self._qid),
                degraded_est_s=self._degraded_total_s(physical))
            base = {name: _ScanView(t) for name, t in query.tables.items()}
            # Residual (cycle-edge) filters on base tables apply at scan
            # time; the rest are grouped by the stage whose output they
            # filter.
            stage_residuals: dict[int, list] = {}
            for ref, lq, rq in physical.residuals:
                if isinstance(ref, str):
                    base[ref].narrow(base[ref].col(lq) == base[ref].col(rq))
                else:
                    stage_residuals.setdefault(ref, []).append((lq, rq))
            t0 = time.perf_counter()
            if not physical.stages:
                if len(base) != 1:
                    raise ValueError("plan has no stages but several tables")
                view = next(iter(base.values()))
                return self._finish(query, physical, view, [], t0,
                                    from_stages=False, tenant=tenant,
                                    deadline_at=deadline_at)

            inter: dict[int, object] = {}  # stage id -> cols | StageView
            depth: dict[int, int] = {}
            handles: dict[int, object] = {}
            handoff_bytes: dict[int, int] = {}  # host-path H2D per stage
            fused = self.handoff == "device"
            # Adaptive mid-pipeline re-optimization needs the frontier-wave
            # schedule (observe a wave, then admit the next); it applies on
            # the fused path to plans whose edges all became stages (cycle
            # edges carry residual state a re-order would have to re-home).
            if (self.adaptive and fused and not physical.residuals
                    and len(physical.stages) == len(physical.order)):
                physical, outcomes, final, replans = self._run_adaptive(
                    query, physical, base, inter, depth, degraded=degraded,
                    tenant=tenant, deadline_at=deadline_at)
                return self._finish(query, physical, final, outcomes, t0,
                                    tenant=tenant, deadline_at=deadline_at,
                                    degraded=degraded, replans=replans)
            for stage in physical.stages:
                depth[stage.stage_id] = 1 + max(
                    [depth[d] for d in stage.deps], default=0)
                make_query = (self._stage_query_dev(stage, base, inter)
                              if fused else
                              self._stage_query_host(stage, base, inter,
                                                     handoff_bytes))
                if degraded:
                    make_query = _mark_degraded(make_query)
                finalize = (self._stage_finalize_dev(
                    stage, base, inter,
                    stage_residuals.get(stage.stage_id, ()),
                    depth=depth[stage.stage_id])
                    if fused else
                    self._stage_finalize_host(
                        stage, base, inter,
                        stage_residuals.get(stage.stage_id, ()),
                        handoff_bytes, depth=depth[stage.stage_id]))
                handles[stage.stage_id] = self.service.submit_deferred(
                    make_query,
                    deps=[handles[d] for d in stage.deps],
                    finalize=finalize,
                    priority=depth[stage.stage_id],
                    tenant=tenant, deadline_at=deadline_at)
            outcomes = [handles[s.stage_id]() for s in physical.stages]
            final = inter[physical.stages[-1].stage_id]
            return self._finish(query, physical, final, outcomes, t0,
                                tenant=tenant, deadline_at=deadline_at,
                                degraded=degraded)

    def _run_adaptive(self, query, physical, base, inter, depth, *,
                      degraded, tenant, deadline_at):
        """Frontier-wave execution with observed-cardinality replans.

        Dependency-free stages of the remaining tail are admitted as one
        concurrent wave; when the wave completes, each stage's exact
        device-observed cardinality is recorded against its estimate, and
        a wave whose worst q-error crosses the threshold triggers a
        re-pricing of the not-yet-admitted tail.  A re-ordering that
        clears the replan margin splices into the plan before the next
        wave is admitted.
        """
        pending = list(physical.stages)
        executed_joins: list = []
        exec_ids: list = []
        observed: dict = {}
        outcomes_by_id: dict = {}
        replans: list = []
        next_id = itertools.count(
            max(s.stage_id for s in physical.stages) + 1)
        while pending:
            # Wave boundary = the pipeline's preemption point: a blown
            # deadline aborts here with the same structured error the
            # kernels' pass boundaries raise, before the next wave burns
            # device time on a guaranteed miss.
            if (getattr(self.service, "preempt", False)
                    and deadline_at is not None
                    and self.service._clock() > deadline_at):
                from repro.engine.resilience import DeadlineExceeded
                raise DeadlineExceeded(
                    f"pipeline deadline passed with {len(pending)} "
                    f"stage(s) unexecuted", reason="deadline_exceeded",
                    tenant=tenant, deadline_s=0.0)
            wave = [s for s in pending if all(d in inter for d in s.deps)]
            handles = {}
            for stage in wave:
                depth[stage.stage_id] = 1 + max(
                    [depth[d] for d in stage.deps], default=0)
                make_query = self._stage_query_dev(stage, base, inter)
                if degraded:
                    make_query = _mark_degraded(make_query)
                handles[stage.stage_id] = self.service.submit_deferred(
                    make_query, deps=[],       # wave inputs are all ready
                    finalize=self._stage_finalize_dev(
                        stage, base, inter, (),
                        depth=depth[stage.stage_id]),
                    priority=depth[stage.stage_id],
                    tenant=tenant, deadline_at=deadline_at)
            worst_q = 1.0
            for stage in wave:
                outcomes_by_id[stage.stage_id] = handles[stage.stage_id]()
                executed_joins.append(stage.join)
                exec_ids.append(stage.stage_id)
                n_obs = inter[stage.stage_id].n
                observed[id(stage.join)] = n_obs
                worst_q = max(worst_q, q_error(stage.est_out, n_obs))
            pending = [s for s in pending if s.stage_id not in handles]
            if not pending or worst_q < self.qerror_threshold:
                continue
            replanned = self.optimizer.reprice_remaining(
                query, executed_joins, [s.join for s in pending], observed)
            if replanned is None:
                continue
            old_tail = [str(s.join) for s in pending]
            physical, pending = self._splice_replan(
                physical, replanned, exec_ids, next_id)
            rec = {"after_stages": len(exec_ids),
                   "worst_q_error": round(float(worst_q), 3),
                   "old_tail": old_tail,
                   "new_tail": [str(s.join) for s in pending],
                   "est_total_s": float(replanned.est_total_s)}
            replans.append(rec)
            self.service.metrics.inc("pipeline_replans")
            self.service.metrics.event("replan", tenant=tenant, **rec)
            self.service.tracer.instant(
                "replan", tenant=tenant,
                after_stages=rec["after_stages"],
                worst_q_error=rec["worst_q_error"])
        outcomes = [outcomes_by_id[s.stage_id] for s in physical.stages]
        final = inter[physical.stages[-1].stage_id]
        return physical, outcomes, final, replans

    def _splice_replan(self, physical, replanned, exec_ids, next_id):
        """Graft a re-priced plan onto the executed prefix.

        ``replanned`` re-states the executed joins as its first stages
        (same joins, same order — ``reprice_remaining`` permutes only the
        tail); those keep their original stage ids so the ``inter`` and
        outcome bookkeeping stands.  Tail stages get fresh never-reused
        ids, with input/dep references remapped.
        """
        n_exec = len(exec_ids)
        id_map = {s.stage_id: exec_ids[i]
                  for i, s in enumerate(replanned.stages[:n_exec])}
        new_tail = []
        for s in replanned.stages[n_exec:]:
            id_map[s.stage_id] = next(next_id)
            new_tail.append(dataclasses.replace(
                s, stage_id=id_map[s.stage_id],
                build_input=(id_map[s.build_input]
                             if isinstance(s.build_input, int)
                             else s.build_input),
                probe_input=(id_map[s.probe_input]
                             if isinstance(s.probe_input, int)
                             else s.probe_input),
                deps=tuple(sorted(id_map[d] for d in s.deps))))
        by_id = {st.stage_id: st for st in physical.stages}
        exec_stages = [by_id[sid] for sid in exec_ids]
        new_physical = dataclasses.replace(
            replanned, stages=exec_stages + new_tail,
            order=tuple(s.join for s in exec_stages + new_tail))
        return new_physical, new_tail

    def _finish(self, query, physical, cols, outcomes, t0, *,
                from_stages: bool = True, tenant: str = "default",
                deadline_at: float | None = None,
                degraded: bool = False,
                replans: list | None = None) -> PipelineResult:
        """Apply the sink (group-by through the engine, or a host scalar)."""
        if query.group_by:
            cols, sink_outcome = self._run_group_by(
                query, cols, count_handoff=from_stages, tenant=tenant,
                deadline_at=deadline_at, degraded=degraded)
            outcomes = outcomes + [sink_outcome]
            agg = None
            rows = next(iter(cols.values())).shape[0] if cols else 0
            source = cols
        else:
            agg = self._apply_scalar_sink(query, cols)
            rows = _src_n(cols) if not isinstance(cols, dict) else (
                next(iter(cols.values())).shape[0] if cols else 0)
            source = cols
        wall = time.perf_counter() - t0
        return PipelineResult(
            rows=rows, aggregate=agg, outcomes=outcomes, wall_s=wall,
            physical=physical, _source=source,
            _ledger=self.service.ledger, replans=replans or [])

    def _apply_scalar_sink(self, query: Query, cols):
        """Scalar aggregate without forcing full materialization: count
        needs only the (host-side) cardinality, sum/min/max/avg gather
        exactly one column from a device view."""
        if query.aggregate is None:
            return None
        if isinstance(cols, dict):
            return apply_aggregate(cols, query.aggregate)
        kind = query.aggregate[0]
        if kind == "count":
            return cols.n
        q = query.aggregate[1]
        if isinstance(cols, StageView):
            arr = np.asarray(cols.col_dev(q))
            self.service.note_host_bytes(
                arr.nbytes, cause="result", stage="sink", column=q,
                direction="d2h")
            return apply_aggregate({q: arr}, query.aggregate)
        return apply_aggregate({q: cols.col(q)}, query.aggregate)

    # -- group-by sink -------------------------------------------------------
    def _run_group_by(self, query: Query, cols, *,
                      count_handoff: bool = True, tenant: str = "default",
                      deadline_at: float | None = None,
                      degraded: bool = False):
        """One ``GroupByQuery`` through the service's admission queue.

        A device view hands the sink its key/value columns as device
        arrays (zero intermediate host bytes for single-column keys);
        multi-column keys still pack their dictionary host-side (the
        device-side composite-key path is an open item), which is counted
        as hand-off traffic honestly.
        """
        aggregate = query.aggregate or ("count",)
        moved = 0
        is_view = isinstance(cols, (StageView, _ScanView))
        if is_view and len(query.group_by) == 1:
            q = query.group_by[0]
            keys = cols.col_dev(q).astype(jnp.int32)
            decode = (lambda k: {q: k.astype(np.int32)})
            n = cols.n
            if aggregate[0] == "count":
                values = jnp.ones(n, jnp.int32)
            else:
                values = cols.col_dev(aggregate[1]).astype(jnp.int32)
            rid = jnp.arange(n, dtype=jnp.int32)
            if n < MIN_STAGE_ROWS:
                pad = MIN_STAGE_ROWS - n
                keys = jnp.concatenate([keys,
                                        jnp.full(pad, -4, jnp.int32)])
                rid = jnp.concatenate([rid, jnp.full(pad, -1, jnp.int32)])
            rel = Relation(rid, keys)
        else:
            if is_view:
                # Multi-column keys: host dictionary packing needs the key
                # columns (plus the value column) on host — counted.
                need = set(query.group_by)
                if aggregate[0] != "count":
                    need.add(aggregate[1])
                host_cols = {q: np.asarray(cols.col_dev(q))
                             if isinstance(cols, StageView)
                             else cols.col(q) for q in need}
                if isinstance(cols, StageView) and count_handoff:
                    pulled = sum(v.nbytes for v in host_cols.values())
                    moved += pulled
                    self.service.note_host_bytes(
                        pulled, cause="multicol_pack",
                        stage="groupby-sink", column="+".join(sorted(need)),
                        direction="d2h")
                cols = host_cols
            keys, decode = self._encode_group_keys(cols, query.group_by)
            n = keys.shape[0]
            if aggregate[0] == "count":
                values = np.ones(n, np.int32)
            else:
                values = np.asarray(cols[aggregate[1]], dtype=np.int32)
            rid = np.arange(n, dtype=np.int32)
            if n < MIN_STAGE_ROWS:                  # empty/tiny pipelines
                pad = MIN_STAGE_ROWS - n
                keys = np.concatenate([keys,
                                       np.full(pad, -4, np.int32)])
                rid = np.concatenate([rid, np.full(pad, -1, np.int32)])
            if count_handoff:
                # Host hand-off into the sink: keys + rid + values H2D.
                # Packed multi-column keys sourced from a device view are
                # packing traffic (``multicol_pack``), not a hand-off —
                # the fused path's ``handoff`` cause stays zero.
                upload = keys.nbytes + rid.nbytes + values.nbytes
                moved += upload
                self.service.note_host_bytes(
                    upload,
                    cause="multicol_pack" if is_view else "handoff",
                    stage="groupby-sink", column="keys+rid+values",
                    direction="h2d")
            rel = Relation(jnp.asarray(rid),
                           jnp.asarray(keys, dtype=jnp.int32))
        gq = GroupByQuery(keys=rel, values=values, tag="groupby-sink",
                          query_id=next(self._qid), wrap32=query.wrap32,
                          tenant=tenant, deadline_at=deadline_at,
                          degraded=degraded)
        if self.service.num_workers <= 0:
            outcome = self.service.execute(gq)
        else:
            # Pre-admitted: the pipeline-root decision already covered the
            # sink; re-deciding here could shed it after its stages ran.
            outcome = self.service.submit(gq, preadmitted=True)()
        outcome.host_bytes_moved += moved
        res = outcome.result
        out = decode(res.keys)
        name = agg_output_name(aggregate)
        kind = aggregate[0]
        if kind == "count":
            out[name] = res.counts.astype(np.int32)
        elif kind == "sum":
            out[name] = res.sums.astype(np.int32 if query.wrap32
                                        else np.int64)
        elif kind == "min":
            out[name] = res.mins.astype(np.int32)
        elif kind == "max":
            out[name] = res.maxs.astype(np.int32)
        else:                                   # avg: sum / count, float64
            out[name] = res.sums.astype(np.float64) / \
                np.maximum(res.counts, 1)
        return out, outcome

    def _encode_group_keys(self, cols: dict, group_by: tuple):
        """int32 key vector + a decoder back to the original key columns.

        A single group-by column passes through raw (any int32 values —
        the operator's pad handling tolerates negatives, including outer-
        join NULLs).  Multiple columns mixed-radix pack their per-column
        dictionary codes; the group-by itself still runs on the device,
        the host only builds the per-column dictionaries.
        """
        if len(group_by) == 1:
            q = group_by[0]
            return np.asarray(cols[q], dtype=np.int32), \
                lambda k: {q: k.astype(np.int32)}
        dicts, codes, radix = [], [], 1
        for q in group_by:
            uniq, inv = np.unique(np.asarray(cols[q]), return_inverse=True)
            dicts.append(uniq)
            codes.append(inv.astype(np.int64))
        packed = np.zeros(codes[0].shape[0] if codes else 0, np.int64)
        for uniq, inv in zip(dicts, codes):
            packed = packed * max(1, uniq.shape[0]) + inv
            radix *= max(1, uniq.shape[0])
        if radix >= 2**31:
            raise ValueError(
                f"group_by key space too large to pack into int32 "
                f"({radix} combinations)")

        def decode(k: np.ndarray) -> dict:
            k = k.astype(np.int64)
            out = {}
            for q, uniq in zip(reversed(group_by), reversed(dicts)):
                r = max(1, uniq.shape[0])
                out[q] = uniq[(k % r)].astype(np.int32) if uniq.size else \
                    np.zeros(k.shape[0], np.int32)
                k = k // r
            return out

        return packed.astype(np.int32), decode

    # -- per-stage plumbing --------------------------------------------------
    def _input(self, ref, base, inter):
        return base[ref] if isinstance(ref, str) else inter[ref]

    def _stage_capacity(self, matches: int) -> int:
        # Power-of-two capacity: stable across repeats of the same
        # pipeline (compile-cache friendly) with headroom for the
        # executor's per-group split slack.
        return next_pow2(max(4 * MIN_STAGE_ROWS,
                             matches + matches // 4 + 256))

    # -- fused (device-resident) hand-off ------------------------------------
    def _stage_query_dev(self, stage, base, inter):
        def make_query(_dep_outcomes) -> JoinQuery:
            bsrc = self._input(stage.build_input, base, inter)
            psrc = self._input(stage.probe_input, base, inter)
            bkey = bsrc.col_dev(stage.build_col)
            pkey = psrc.col_dev(stage.probe_col)
            _check_keys_nonneg(bkey, pkey)
            matches = int(_match_stats_jit(bkey, pkey, stage.kind))
            return JoinQuery(
                build=_as_relation_dev(
                    bkey, BUILD_FILL_KEY,
                    fp_hint=bsrc.col_fp(stage.build_col)),
                probe=_as_relation_dev(
                    pkey, PROBE_FILL_KEY,
                    fp_hint=psrc.col_fp(stage.probe_col)),
                tag=f"stage{stage.stage_id}:{stage.join}",
                max_out=self._stage_capacity(matches),
                query_id=next(self._qid), kind=stage.kind)
        return make_query

    def _stage_finalize_dev(self, stage, base, inter, residuals=(), *,
                            depth: int = 0):
        def finalize(outcome) -> None:
            # Runs on the deferred-stage thread: the gather/finalize leg
            # of the lifecycle, spanned per stage (the executed query's
            # own spans closed on a worker thread already).
            with self.service.tracer.span(
                    "finalize", stage=stage.stage_id,
                    query_id=outcome.query_id, tenant=outcome.tenant,
                    tag=outcome.tag):
                with self.service.tracer.span("gather",
                                              stage=stage.stage_id):
                    bsrc = self._input(stage.build_input, base, inter)
                    psrc = self._input(stage.probe_input, base, inter)
                    c = int(outcome.result.count)
                    token = self._stage_token(stage, bsrc, psrc,
                                              outcome.plan, c)
                    view = StageView(
                        stage.kind, psrc, bsrc,
                        outcome.result.probe_rid[:c],
                        None if stage.kind in ("semi", "anti")
                        else outcome.result.build_rid[:c], c, token=token)
                    for lq, rq in residuals:
                        view.apply_residual(lq, rq)
                inter[stage.stage_id] = view
                outcome.host_bytes_moved = 0  # the fused path's invariant
                self.service.cardinality.record(
                    stage_type=stage.kind, est_rows=stage.est_out,
                    observed_rows=c, depth=depth, tenant=outcome.tenant,
                    stage_id=stage.stage_id)
        return finalize

    @staticmethod
    def _stage_token(stage, bsrc, psrc, plan, count: int) -> str | None:
        """Execution token for a stage output: sha1 over the stage kind,
        both input column fingerprints, the *executed* plan's full knob
        set (estimate floats and the content-neutral ``cached`` bit
        excluded — they vary with calibration, not content), and the
        match count.  The engine is deterministic given those, so equal
        tokens imply byte-equal output; ``None`` when either input lacks
        a fingerprint, which sends downstream keying to the ledgered
        content-hash fallback."""
        bfp = bsrc.col_fp(stage.build_col)
        pfp = psrc.col_fp(stage.probe_col)
        if bfp is None or pfp is None:
            return None
        parts = (stage.kind, f"b:{bfp}", f"p:{pfp}", plan.algorithm,
                 plan.scheme, str(plan.build_ratios), str(plan.probe_ratios),
                 str(plan.num_buckets), str(plan.max_out),
                 str(plan.schedule), str(plan.shj_bits),
                 str(plan.partition_ratio), str(plan.join_ratio),
                 f"c={count}")
        return hashlib.sha1("|".join(parts).encode()).hexdigest()

    # -- host-materialize hand-off (the pre-fusion baseline) -----------------
    def _stage_query_host(self, stage, base, inter, handoff_bytes):
        def make_query(_dep_outcomes) -> JoinQuery:
            bsrc = self._input(stage.build_input, base, inter)
            psrc = self._input(stage.probe_input, base, inter)
            bkey = _src_col(bsrc, stage.build_col)
            pkey = _src_col(psrc, stage.probe_col)
            matches = _match_count(bkey, pkey, stage.kind)
            # H2D re-upload of intermediate-derived inputs: rid + key per
            # side whose source is a host-materialized stage output.
            moved = sum(
                2 * 4 * max(k.shape[0], MIN_STAGE_ROWS)
                for src, k in ((bsrc, bkey), (psrc, pkey))
                if isinstance(src, dict))
            if moved:
                handoff_bytes[stage.stage_id] = \
                    handoff_bytes.get(stage.stage_id, 0) + moved
                self.service.note_host_bytes(
                    moved, cause="handoff",
                    stage=f"stage{stage.stage_id}", column="rid+key",
                    direction="h2d")
            return JoinQuery(
                build=_as_relation(bkey, BUILD_FILL_KEY),
                probe=_as_relation(pkey, PROBE_FILL_KEY),
                tag=f"stage{stage.stage_id}:{stage.join}",
                max_out=self._stage_capacity(matches),
                query_id=next(self._qid), kind=stage.kind)
        return make_query

    def _stage_finalize_host(self, stage, base, inter, residuals=(),
                             handoff_bytes=None, *, depth: int = 0):
        def finalize(outcome) -> None:
            with self.service.tracer.span(
                    "finalize", stage=stage.stage_id,
                    query_id=outcome.query_id, tenant=outcome.tenant,
                    tag=outcome.tag):
                with self.service.tracer.span("gather",
                                              stage=stage.stage_id):
                    bsrc = self._input(stage.build_input, base, inter)
                    psrc = self._input(stage.probe_input, base, inter)
                    c = int(outcome.result.count)
                    pr = np.asarray(outcome.result.probe_rid[:c])
                    moved = pr.nbytes              # D2H: match indices
                    cols = _src_take(psrc, pr)
                    if stage.kind in ("semi", "anti"):
                        pass  # filter table consumed: probe columns only
                    elif stage.kind == "left_outer":
                        br = np.asarray(outcome.result.build_rid[:c])
                        moved += br.nbytes
                        # Unmatched rows carry NULL_VALUE on the build
                        # side.  An empty build side (filtered to nothing)
                        # has no rows to gather at all — everything is
                        # NULL.
                        matched = br >= 0
                        if _src_n(bsrc) == 0:
                            for q in _src_names(bsrc):
                                cols[q] = np.full(c, NULL_VALUE, np.int32)
                        else:
                            bcols = _src_take(bsrc,
                                              np.where(matched, br, 0))
                            for q, v in bcols.items():
                                cols[q] = np.where(matched, v,
                                                   v.dtype.type(NULL_VALUE))
                    else:
                        br = np.asarray(outcome.result.build_rid[:c])
                        moved += br.nbytes
                        cols.update(_src_take(bsrc, br))
                for lq, rq in residuals:
                    cols = _apply_residual(cols, lq, rq)
                inter[stage.stage_id] = cols
                self.service.note_host_bytes(
                    moved, cause="handoff",
                    stage=f"stage{stage.stage_id}", column="match_rids",
                    direction="d2h")
                outcome.host_bytes_moved = moved + \
                    (handoff_bytes or {}).get(stage.stage_id, 0)
                self.service.cardinality.record(
                    stage_type=stage.kind, est_rows=stage.est_out,
                    observed_rows=c, depth=depth, tenant=outcome.tenant,
                    stage_id=stage.stage_id)
        return finalize

    # -- convenience ---------------------------------------------------------
    def run_optimized(self, query: Query):
        """(chosen physical plan, result) in one call."""
        physical = self.optimizer.optimize(query)
        return physical, self.run(query, physical)
