"""Pipelined execution of a physical plan over the join-query engine.

Each ``PipelineStage`` becomes one ``JoinQuery`` submitted through
``JoinQueryService.submit_deferred``: a stage waits only on the stages
whose outputs it consumes, so independent subtrees of a bushy plan sit in
the admission queue together and overlap on the two device groups exactly
like unrelated queries do (C-only/G-only concurrency).  Between stages the
match indices are materialized into qualified payload columns with the
``rid = arange(n)`` gather convention (Ozawa et al.'s point that
pipelining intermediates between operators, not re-scanning, is the
dominant win).

Scan fusion: filtered base tables are NOT materialized before their first
join.  A ``_ScanView`` computes the filter's surviving row index once and
composes it directly into whatever gather consumes the table — the stage's
key column, or the stage output's payload gather — so a 2%-selective
dimension never copies its full column set through the mask on the host.

Join variants ride the same pipeline: a semi/anti stage builds on its
filter table and emits only probe-side rows; a left-outer stage NULL-fills
(``NULL_VALUE``) the build columns of unmatched rows.  A ``group_by``
query ends in one more engine submission — a ``GroupByQuery`` through the
same admission queue — whose result becomes the pipeline's output rows.

Reuse falls out of the engine untouched: a stage's build side is
fingerprinted like any other query, so a dimension table shared by many
queries hits the build-table cache (SHJ) or the partition-layout caches
(PHJ, both sides) after its first use.

Capacity planning: a stage's result buffer is sized from an exact
host-side match count (two ``searchsorted`` passes over the build keys) —
estimates drive *ordering*, but capacities must never truncate.  Deeper
stages get higher admission priority so in-flight pipelines drain before
fresh root stages are admitted.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.core.relation import Relation, next_pow2
from repro.engine.service import GroupByQuery, JoinQuery, JoinQueryService

from .optimize import JoinOrderOptimizer, PhysicalPlan
from .plan import (NULL_VALUE, Query, agg_output_name, apply_aggregate,
                   rows_array)

# Filler keys for padding tiny/empty stage inputs up to a minimum size.
# Distinct negative values per side: they match neither real keys (>= 0)
# nor the engine's own pad sentinels (-2/-3) nor each other.
BUILD_FILL_KEY = -6
PROBE_FILL_KEY = -7
MIN_STAGE_ROWS = 64


class _ScanView:
    """Lazy filtered scan of a base table (fused filter pushdown).

    Holds the raw columns plus the surviving row index; columns are
    gathered on demand, and ``take`` composes the scan index with a
    consumer's row selection so the filtered table is never materialized
    as a whole intermediate.
    """

    def __init__(self, table):
        self._name = table.name
        self._cols = table.columns          # raw, unfiltered
        self._idx = table.scan_indices()    # None = no filters
        self._memo: dict = {}

    @property
    def n(self) -> int:
        if self._idx is not None:
            return int(self._idx.shape[0])
        return next(iter(self._cols.values())).shape[0] if self._cols else 0

    def names(self):
        return [f"{self._name}.{c}" for c in self._cols]

    def _raw(self, q: str) -> np.ndarray:
        return self._cols[q.partition(".")[2]]

    def col(self, q: str) -> np.ndarray:
        """One filtered column (memoized — typically just the join key)."""
        if q not in self._memo:
            raw = self._raw(q)
            self._memo[q] = raw if self._idx is None else raw[self._idx]
        return self._memo[q]

    def take(self, rows: np.ndarray) -> dict:
        """All columns at the given (filtered-space) row positions.

        The scan index composes into the gather: one indexed read of each
        raw column instead of filter-materialize + gather.
        """
        if self._idx is not None:
            rows = self._idx[rows]
        return {f"{self._name}.{c}": v[rows] for c, v in self._cols.items()}

    def materialize(self) -> dict:
        return self.take(np.arange(self.n)) if self._idx is not None else \
            {f"{self._name}.{c}": v for c, v in self._cols.items()}

    def narrow(self, keep: np.ndarray) -> None:
        """Restrict to a boolean mask over current (filtered) rows —
        residual cycle-edge filters applied at scan time."""
        cur = (self._idx if self._idx is not None
               else np.arange(self.n))
        self._idx = cur[keep]
        self._memo.clear()


def _src_n(src) -> int:
    if isinstance(src, _ScanView):
        return src.n
    return next(iter(src.values())).shape[0] if src else 0


def _src_names(src) -> list:
    return src.names() if isinstance(src, _ScanView) else list(src)


def _src_col(src, q: str) -> np.ndarray:
    return src.col(q) if isinstance(src, _ScanView) else src[q]


def _src_take(src, rows: np.ndarray) -> dict:
    if isinstance(src, _ScanView):
        return src.take(rows)
    return {q: v[rows] for q, v in src.items()}


def _src_cols(src) -> dict:
    return src.materialize() if isinstance(src, _ScanView) else src


def _as_relation(col: np.ndarray, fill_key: int) -> Relation:
    """A core Relation over a column, rid = row index (gather convention)."""
    n = col.shape[0]
    if n and int(col.min()) < 0:
        raise ValueError(
            "negative join-key values are unsupported: they collide with "
            "the executor's fill keys and the engine's pad sentinels")
    rid = np.arange(n, dtype=np.int32)
    if n < MIN_STAGE_ROWS:
        pad = MIN_STAGE_ROWS - n
        col = np.concatenate([col.astype(np.int32),
                              np.full(pad, fill_key, np.int32)])
        rid = np.concatenate([rid, np.full(pad, -1, np.int32)])
    return Relation(jnp.asarray(rid), jnp.asarray(col, dtype=jnp.int32))


def _apply_residual(cols: dict, left_q: str, right_q: str) -> dict:
    """Cycle-edge equality filter over one component's columns."""
    mask = cols[left_q] == cols[right_q]
    return {q: v[mask] for q, v in cols.items()}


def _match_count(build_keys: np.ndarray, probe_keys: np.ndarray,
                 kind: str = "inner") -> int:
    """Exact stage output cardinality (host-side searchsorted passes)."""
    bk = np.sort(build_keys.astype(np.int64), kind="stable")
    pk = probe_keys.astype(np.int64)
    counts = (np.searchsorted(bk, pk, side="right")
              - np.searchsorted(bk, pk, side="left"))
    if kind == "semi":
        return int((counts > 0).sum())
    if kind == "anti":
        return int((counts == 0).sum())
    if kind == "left_outer":
        return int(np.maximum(counts, 1).sum())
    return int(counts.sum())


@dataclasses.dataclass
class PipelineResult:
    """Outcome of one pipelined query execution."""

    columns: dict                 # final qualified columns (NumPy)
    rows: int
    aggregate: object             # None | int | float
    outcomes: list                # QueryOutcome per stage (+ group-by sink)
    wall_s: float
    physical: PhysicalPlan

    def rows_array(self) -> np.ndarray:
        return rows_array(self.columns)

    def to_dict(self) -> dict:
        return {"rows": self.rows, "aggregate": self.aggregate,
                "wall_s": self.wall_s,
                "est_total_s": self.physical.est_total_s,
                "stages": [o.to_dict() for o in self.outcomes]}


class PipelineExecutor:
    """Runs physical plans through a (possibly shared) JoinQueryService."""

    def __init__(self, service: JoinQueryService | None = None,
                 optimizer: JoinOrderOptimizer | None = None):
        self.service = service or JoinQueryService(num_workers=2)
        self.optimizer = optimizer or JoinOrderOptimizer(self.service.planner)
        self._qid = itertools.count(1)

    def close(self):
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- the pipeline --------------------------------------------------------
    def run(self, query: Query,
            physical: PhysicalPlan | None = None) -> PipelineResult:
        """Execute ``query`` under ``physical`` (optimized when omitted)."""
        if physical is None:
            physical = self.optimizer.optimize(query)
        base = {name: _ScanView(t) for name, t in query.tables.items()}
        # Residual (cycle-edge) filters on base tables apply at scan time;
        # the rest are grouped by the stage whose output they filter.
        stage_residuals: dict[int, list] = {}
        for ref, lq, rq in physical.residuals:
            if isinstance(ref, str):
                base[ref].narrow(base[ref].col(lq) == base[ref].col(rq))
            else:
                stage_residuals.setdefault(ref, []).append((lq, rq))
        t0 = time.perf_counter()
        if not physical.stages:
            if len(base) != 1:
                raise ValueError("plan has no stages but several tables")
            cols = next(iter(base.values())).materialize()
            return self._finish(query, physical, cols, [], t0)

        inter: dict[int, dict] = {}        # stage id -> qualified columns
        depth: dict[int, int] = {}
        handles: dict[int, object] = {}
        for stage in physical.stages:
            depth[stage.stage_id] = 1 + max(
                [depth[d] for d in stage.deps], default=0)
            handles[stage.stage_id] = self.service.submit_deferred(
                self._stage_query_fn(stage, base, inter),
                deps=[handles[d] for d in stage.deps],
                finalize=self._stage_finalize_fn(
                    stage, base, inter,
                    stage_residuals.get(stage.stage_id, ())),
                priority=depth[stage.stage_id])
        outcomes = [handles[s.stage_id]() for s in physical.stages]
        final = inter[physical.stages[-1].stage_id]
        return self._finish(query, physical, final, outcomes, t0)

    def _finish(self, query, physical, cols, outcomes, t0) -> PipelineResult:
        """Apply the sink (group-by through the engine, or a host scalar)."""
        if query.group_by:
            cols, sink_outcome = self._run_group_by(query, cols)
            outcomes = outcomes + [sink_outcome]
            agg = None
        else:
            agg = apply_aggregate(cols, query.aggregate)
        wall = time.perf_counter() - t0
        return PipelineResult(
            columns=cols,
            rows=next(iter(cols.values())).shape[0] if cols else 0,
            aggregate=agg, outcomes=outcomes, wall_s=wall,
            physical=physical)

    # -- group-by sink -------------------------------------------------------
    def _run_group_by(self, query: Query, cols: dict):
        """One ``GroupByQuery`` through the service's admission queue."""
        aggregate = query.aggregate or ("count",)
        keys, decode = self._encode_group_keys(cols, query.group_by)
        n = keys.shape[0]
        if aggregate[0] == "count":
            values = np.ones(n, np.int32)
        else:
            values = np.asarray(cols[aggregate[1]], dtype=np.int32)
        rid = np.arange(n, dtype=np.int32)
        if n < MIN_STAGE_ROWS:                  # empty/tiny final pipelines
            pad = MIN_STAGE_ROWS - n
            keys = np.concatenate([keys,
                                   np.full(pad, -4, np.int32)])
            rid = np.concatenate([rid, np.full(pad, -1, np.int32)])
        gq = GroupByQuery(keys=Relation(jnp.asarray(rid),
                                        jnp.asarray(keys, dtype=jnp.int32)),
                          values=values, tag="groupby-sink",
                          query_id=next(self._qid))
        if self.service.num_workers <= 0:
            outcome = self.service.execute(gq)
        else:
            outcome = self.service.submit(gq)()
        res = outcome.result
        out = decode(res.keys)
        name = agg_output_name(aggregate)
        kind = aggregate[0]
        if kind == "count":
            out[name] = res.counts.astype(np.int32)
        elif kind == "sum":
            out[name] = res.sums.astype(np.int32)
        elif kind == "min":
            out[name] = res.mins.astype(np.int32)
        elif kind == "max":
            out[name] = res.maxs.astype(np.int32)
        else:                                   # avg: wrapped sum / count
            out[name] = res.sums.astype(np.float64) / \
                np.maximum(res.counts, 1)
        return out, outcome

    def _encode_group_keys(self, cols: dict, group_by: tuple):
        """int32 key vector + a decoder back to the original key columns.

        A single group-by column passes through raw (any int32 values —
        the operator's pad handling tolerates negatives, including outer-
        join NULLs).  Multiple columns mixed-radix pack their per-column
        dictionary codes; the group-by itself still runs on the device,
        the host only builds the per-column dictionaries.
        """
        if len(group_by) == 1:
            q = group_by[0]
            return np.asarray(cols[q], dtype=np.int32), \
                lambda k: {q: k.astype(np.int32)}
        dicts, codes, radix = [], [], 1
        for q in group_by:
            uniq, inv = np.unique(np.asarray(cols[q]), return_inverse=True)
            dicts.append(uniq)
            codes.append(inv.astype(np.int64))
        packed = np.zeros(codes[0].shape[0] if codes else 0, np.int64)
        for uniq, inv in zip(dicts, codes):
            packed = packed * max(1, uniq.shape[0]) + inv
            radix *= max(1, uniq.shape[0])
        if radix >= 2**31:
            raise ValueError(
                f"group_by key space too large to pack into int32 "
                f"({radix} combinations)")

        def decode(k: np.ndarray) -> dict:
            k = k.astype(np.int64)
            out = {}
            for q, uniq in zip(reversed(group_by), reversed(dicts)):
                r = max(1, uniq.shape[0])
                out[q] = uniq[(k % r)].astype(np.int32) if uniq.size else \
                    np.zeros(k.shape[0], np.int32)
                k = k // r
            return out

        return packed.astype(np.int32), decode

    # -- per-stage plumbing --------------------------------------------------
    def _input(self, ref, base, inter):
        return base[ref] if isinstance(ref, str) else inter[ref]

    def _stage_query_fn(self, stage, base, inter):
        def make_query(_dep_outcomes) -> JoinQuery:
            bsrc = self._input(stage.build_input, base, inter)
            psrc = self._input(stage.probe_input, base, inter)
            bkey = _src_col(bsrc, stage.build_col)
            pkey = _src_col(psrc, stage.probe_col)
            matches = _match_count(bkey, pkey, stage.kind)
            # Power-of-two capacity: stable across repeats of the same
            # pipeline (compile-cache friendly) with headroom for the
            # executor's per-group split slack.
            max_out = next_pow2(max(4 * MIN_STAGE_ROWS,
                                    matches + matches // 4 + 256))
            return JoinQuery(
                build=_as_relation(bkey, BUILD_FILL_KEY),
                probe=_as_relation(pkey, PROBE_FILL_KEY),
                tag=f"stage{stage.stage_id}:{stage.join}",
                max_out=max_out, query_id=next(self._qid),
                kind=stage.kind)
        return make_query

    def _stage_finalize_fn(self, stage, base, inter, residuals=()):
        def finalize(outcome) -> None:
            bsrc = self._input(stage.build_input, base, inter)
            psrc = self._input(stage.probe_input, base, inter)
            c = int(outcome.result.count)
            pr = np.asarray(outcome.result.probe_rid[:c])
            br = np.asarray(outcome.result.build_rid[:c])
            cols = _src_take(psrc, pr)
            if stage.kind in ("semi", "anti"):
                pass          # filter table consumed: probe columns only
            elif stage.kind == "left_outer":
                # Unmatched rows carry NULL_VALUE on the build side.  An
                # empty build side (filtered to nothing) has no rows to
                # gather at all — everything is NULL.
                matched = br >= 0
                if _src_n(bsrc) == 0:
                    for q in _src_names(bsrc):
                        cols[q] = np.full(c, NULL_VALUE, np.int32)
                else:
                    bcols = _src_take(bsrc, np.where(matched, br, 0))
                    for q, v in bcols.items():
                        cols[q] = np.where(matched, v,
                                           v.dtype.type(NULL_VALUE))
            else:
                cols.update(_src_take(bsrc, br))
            for lq, rq in residuals:
                cols = _apply_residual(cols, lq, rq)
            inter[stage.stage_id] = cols
        return finalize

    # -- convenience ---------------------------------------------------------
    def run_optimized(self, query: Query):
        """(chosen physical plan, result) in one call."""
        physical = self.optimizer.optimize(query)
        return physical, self.run(query, physical)
