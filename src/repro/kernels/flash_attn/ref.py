"""Pure-jnp oracle: exact GQA softmax attention."""
import jax.numpy as jnp

from repro.layers.attention import _sdpa


def flash_attention_ref(q, k, v, *, num_kv_heads: int, causal: bool = True):
    mask = None
    if causal:
        i = jnp.arange(q.shape[1])
        j = jnp.arange(k.shape[1])
        mask = (i[:, None] >= j[None, :])[None, None, None]
    return _sdpa(q, k, v, mask, num_kv_heads)
