"""Pallas TPU kernel: flash attention forward (causal, GQA).

Grid (batch, q_heads, q_blocks): each step owns one (q_block, head_dim)
query tile in VMEM and streams the K/V of its KV head (GQA mapping done in
the BlockSpec index_map: kv_head = q_head // group) through MXU-aligned
(128-multiple) tiles with online-softmax accumulation in f32.

This is the TPU-native adaptation of the paper's "fine-grained steps +
shared fast memory" idea applied to the LM substrate hotspot: the softmax
statistics (m, l) play the bucket-header role — small VMEM-resident state
reused across the streamed tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, causal: bool,
                  q_block: int, seq_k: int, scale: float):
    q = q_ref[...][0, 0].astype(jnp.float32) * scale    # (qb, d)
    iq = pl.program_id(2)
    d = q.shape[-1]
    nkv = seq_k // kv_block
    m = jnp.full((q_block,), NEG_INF, jnp.float32)
    l = jnp.zeros((q_block,), jnp.float32)
    acc = jnp.zeros((q_block, d), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...][0, 0], j * kv_block,
                                         kv_block, 0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...][0, 0], j * kv_block,
                                         kv_block, 0).astype(jnp.float32)
        s = q @ k.T                                      # (qb, kvb) on MXU
        if causal:
            rows = iq * q_block + jnp.arange(q_block)
            cols = j * kv_block + jnp.arange(kv_block)
            s = jnp.where(rows[:, None] >= cols[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v           # (qb, d) on MXU
        return m_new, l_new, acc_new

    if causal:
        # Skip fully-masked KV tiles: only j with j*kvb <= (iq+1)*qb - 1.
        upper = jnp.minimum(nkv, (iq + 1) * q_block // kv_block + 1)
    else:
        upper = nkv
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
        o_ref.dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("num_kv_heads", "q_block",
                                             "kv_block", "causal",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, num_kv_heads: int, q_block: int = 128,
                           kv_block: int = 128, causal: bool = True,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    g = h // num_kv_heads
    assert sq % q_block == 0 and sk % kv_block == 0
    qt = q.transpose(0, 2, 1, 3)         # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)         # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, h, sq // q_block)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_block=kv_block, causal=causal,
                          q_block=q_block, seq_k=sk,
                          scale=1.0 / (d ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(qt.reshape(b, h, sq, d), kt, vt)
    return out.transpose(0, 2, 1, 3)
