import jax

from .flash_attn import flash_attention_pallas
from .ref import flash_attention_ref


def flash_attention(q, k, v, *, num_kv_heads: int, causal: bool = True,
                    use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if (use_pallas or interpret) and q.shape[1] % 128 == 0 \
            and k.shape[1] % 128 == 0:
        return flash_attention_pallas(q, k, v, num_kv_heads=num_kv_heads,
                                      causal=causal, interpret=interpret)
    return flash_attention_ref(q, k, v, num_kv_heads=num_kv_heads,
                               causal=causal)
