"""Pallas TPU kernel: segmented aggregation (group-by's a2 step).

After the fused radix passes cluster group keys and a stable sort assigns
each tuple a dense group slot id, the remaining work is one streaming
reduction: per slot, accumulate count / sum / min / max of the value
column.  This kernel does all four in a single VMEM pass over the tuples —
the aggregation analogue of the fused n1+n2 histogram kernel: every grid
step adds its tile's one-hot contributions into the shared per-slot output
blocks (same output block for every step -> sequential accumulation, the
TPU-idiomatic replacement for atomic aggregation buckets).

Tuples with ``gid == -1`` (pad sentinels) match no slot and contribute
nothing.  The one-hot expansion is O(tile * num_slots) per tile, so this
kernel targets the VMEM-resident per-partition working sets the planner
produces; ``ops.py`` gates dispatch by size and falls back to the masked
``jax.ops.segment_*`` path otherwise.

Sum width: by default sums accumulate *wide* — exact int64 semantics
carried as several int32 channels, since the TPU VPU (and jax with x64
disabled) has no native int64.  The value column is reinterpreted as
uint32 and split into fixed-width bit chunks; each chunk's per-slot sum
must fit int32, so the chunk width adapts to the (static) input size —
8-bit chunks to ~8.4M tuples per call, 6-bit to ~34M, 4-bit to ~143M
(``wide_chunk_bits``) — and the signed total is recovered as
``sum_k chunk_k * 2**(bits*k) - negatives * 2**32``
(``wide_sums_to_int64``, which infers the width from the channel
count).  ``wrap32=True`` keeps the single wrapping-int32 accumulator —
the legacy device semantics, still used by oracle-parity tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Plain Python ints: jnp scalars would be captured as traced constants
# inside the Pallas kernel body, which pallas_call rejects.
INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)

# Wide sums: a b-bit chunk's per-slot sum stays exact while
# (2**b - 1) * tuples_per_slot < 2**31; narrower chunks trade more
# channels for more headroom.  The per-call row count bounds any slot.
WIDE_SUM_MAX_ROWS = (2**31 - 1) // 255        # 8-bit chunks


def wide_chunk_bits(n: int) -> int:
    """Chunk width whose per-slot sums cannot overflow at ``n`` rows."""
    for bits in (8, 6, 4):
        if n <= (2**31 - 1) // ((1 << bits) - 1):
            return bits
    raise ValueError(
        f"wide segmented sums support up to {(2**31 - 1) // 15} tuples "
        f"per call (got {n}); split the input or pass wrap32=True")


def _num_chunks(bits: int) -> int:
    return -(-32 // bits)


def wide_sums_to_int64(sm: np.ndarray) -> np.ndarray:
    """Fold the (chunks+1, slots) wide-sum channels into exact int64 sums.

    Leading channels are per-slot sums of the value's uint32 bit chunks
    (width inferred from the channel count), the last channel counts
    negative values (each negative's uint32 image is its value + 2**32,
    so the signed total subtracts that bias back out).
    """
    sm = np.asarray(sm).astype(np.int64)
    chunks = sm.shape[0] - 1
    bits = {4: 8, 6: 6, 8: 4}[chunks]
    total = np.zeros(sm.shape[1], np.int64)
    for k in range(chunks):
        total += sm[k] << (bits * k)
    return total - (sm[chunks] << 32)


def _seg_agg_kernel(gid_ref, val_ref, cnt_ref, sum_ref, mn_ref, mx_ref, *,
                    num_slots: int, wrap32: bool, chunk_bits: int = 8):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        mn_ref[...] = jnp.full_like(mn_ref, INT32_MAX)
        mx_ref[...] = jnp.full_like(mx_ref, INT32_MIN)

    gid = gid_ref[...].reshape(-1)                         # (tile,)
    val = val_ref[...].reshape(-1)
    onehot = (gid[:, None] == jnp.arange(num_slots,
                                         dtype=jnp.int32)[None, :])
    oh32 = onehot.astype(jnp.int32)                        # (tile, S)
    cnt_ref[...] += oh32.sum(axis=0)[None, :]
    if wrap32:
        sum_ref[...] += (val[:, None] * oh32).sum(axis=0)[None, :]
    else:
        u = val.astype(jnp.uint32)
        chunks = _num_chunks(chunk_bits)
        for k in range(chunks):
            chunk = ((u >> jnp.uint32(chunk_bits * k))
                     & jnp.uint32((1 << chunk_bits) - 1)).astype(jnp.int32)
            sum_ref[k, :] += (chunk[:, None] * oh32).sum(axis=0)
        neg = (val < 0).astype(jnp.int32)
        sum_ref[chunks, :] += (neg[:, None] * oh32).sum(axis=0)
    mn_ref[...] = jnp.minimum(
        mn_ref[...],
        jnp.where(onehot, val[:, None], INT32_MAX).min(axis=0)[None, :])
    mx_ref[...] = jnp.maximum(
        mx_ref[...],
        jnp.where(onehot, val[:, None], INT32_MIN).max(axis=0)[None, :])


@functools.partial(jax.jit,
                   static_argnames=("num_slots", "block_rows", "interpret",
                                    "wrap32"))
def seg_agg_pallas(gid: jax.Array, val: jax.Array, *, num_slots: int,
                   block_rows: int = 8, interpret: bool = False,
                   wrap32: bool = False):
    """gid/val: (n,) int32, n % (block_rows*128) == 0; gid in [-1, num_slots).

    Returns ``(count, sum, min, max)``: count/min/max are ``(num_slots,)``
    int32; sum is ``(chunks+1, num_slots)`` wide channels by default
    (chunk width adapted to ``n``; decode with ``wide_sums_to_int64``) or
    ``(num_slots,)`` wrapping int32 under ``wrap32=True``.  Empty slots
    report count 0, sum 0, min INT32_MAX, max INT32_MIN (neutral
    elements).
    """
    n = gid.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0 and n == rows * lanes, (n, block_rows)
    grid = (rows // block_rows,)
    chunk_bits = 8 if wrap32 else wide_chunk_bits(n)
    sum_rows = 1 if wrap32 else _num_chunks(chunk_bits) + 1
    out = pl.pallas_call(
        functools.partial(_seg_agg_kernel, num_slots=num_slots,
                          wrap32=wrap32, chunk_bits=chunk_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, num_slots), lambda i: (0, 0)),
                   pl.BlockSpec((sum_rows, num_slots), lambda i: (0, 0)),
                   pl.BlockSpec((1, num_slots), lambda i: (0, 0)),
                   pl.BlockSpec((1, num_slots), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, num_slots), jnp.int32),
                   jax.ShapeDtypeStruct((sum_rows, num_slots), jnp.int32),
                   jax.ShapeDtypeStruct((1, num_slots), jnp.int32),
                   jax.ShapeDtypeStruct((1, num_slots), jnp.int32)],
        interpret=interpret,
    )(gid.reshape(rows, lanes), val.reshape(rows, lanes))
    cnt, sm, mn, mx = out
    return cnt[0], (sm[0] if wrap32 else sm), mn[0], mx[0]
