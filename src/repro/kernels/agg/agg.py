"""Pallas TPU kernel: segmented aggregation (group-by's a2 step).

After the fused radix passes cluster group keys and a stable sort assigns
each tuple a dense group slot id, the remaining work is one streaming
reduction: per slot, accumulate count / sum / min / max of the value
column.  This kernel does all four in a single VMEM pass over the tuples —
the aggregation analogue of the fused n1+n2 histogram kernel: every grid
step adds its tile's one-hot contributions into the shared per-slot output
blocks (same output block for every step -> sequential accumulation, the
TPU-idiomatic replacement for atomic aggregation buckets).

Tuples with ``gid == -1`` (pad sentinels) match no slot and contribute
nothing.  The one-hot expansion is O(tile * num_slots) per tile, so this
kernel targets the VMEM-resident per-partition working sets the planner
produces; ``ops.py`` gates dispatch by size and falls back to the masked
``jax.ops.segment_*`` path otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain Python ints: jnp scalars would be captured as traced constants
# inside the Pallas kernel body, which pallas_call rejects.
INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


def _seg_agg_kernel(gid_ref, val_ref, cnt_ref, sum_ref, mn_ref, mx_ref, *,
                    num_slots: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        mn_ref[...] = jnp.full_like(mn_ref, INT32_MAX)
        mx_ref[...] = jnp.full_like(mx_ref, INT32_MIN)

    gid = gid_ref[...].reshape(-1)                         # (tile,)
    val = val_ref[...].reshape(-1)
    onehot = (gid[:, None] == jnp.arange(num_slots,
                                         dtype=jnp.int32)[None, :])
    oh32 = onehot.astype(jnp.int32)                        # (tile, S)
    cnt_ref[...] += oh32.sum(axis=0)[None, :]
    sum_ref[...] += (val[:, None] * oh32).sum(axis=0)[None, :]
    mn_ref[...] = jnp.minimum(
        mn_ref[...],
        jnp.where(onehot, val[:, None], INT32_MAX).min(axis=0)[None, :])
    mx_ref[...] = jnp.maximum(
        mx_ref[...],
        jnp.where(onehot, val[:, None], INT32_MIN).max(axis=0)[None, :])


@functools.partial(jax.jit,
                   static_argnames=("num_slots", "block_rows", "interpret"))
def seg_agg_pallas(gid: jax.Array, val: jax.Array, *, num_slots: int,
                   block_rows: int = 8, interpret: bool = False):
    """gid/val: (n,) int32, n % (block_rows*128) == 0; gid in [-1, num_slots).

    Returns ``(count, sum, min, max)``, each ``(num_slots,)`` int32.  Empty
    slots report count 0, sum 0, min INT32_MAX, max INT32_MIN (neutral
    elements); sums wrap in int32 like the device accumulation they mirror.
    """
    n = gid.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0 and n == rows * lanes, (n, block_rows)
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_seg_agg_kernel, num_slots=num_slots),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, num_slots), lambda i: (0, 0))
                   for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((1, num_slots), jnp.int32)
                   for _ in range(4)],
        interpret=interpret,
    )(gid.reshape(rows, lanes), val.reshape(rows, lanes))
    cnt, sm, mn, mx = (x[0] for x in out)
    return cnt, sm, mn, mx
