"""Segmented-aggregation kernels (hash group-by's inner loop)."""
from .ops import segmented_aggregate  # noqa: F401
