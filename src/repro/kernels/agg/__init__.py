"""Segmented-aggregation kernels (hash group-by's inner loop)."""
from .ops import segmented_aggregate, wide_sums_to_int64  # noqa: F401
