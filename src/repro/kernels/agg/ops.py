"""Dispatcher for the segmented-aggregation kernel.

``segmented_aggregate`` is the data path behind group-by's reduce step
(``repro.ops.groupby`` routes through it): the one-pass Pallas kernel on
TPU-shaped inputs, the masked ``segment_*`` jnp path elsewhere.
"""
import jax

from .agg import seg_agg_pallas
from .ref import seg_agg_ref

# The one-hot accumulation holds a (tile, num_slots) expansion in VMEM;
# beyond this many slots the jnp path wins (and always off-TPU).
_AGG_VMEM_SLOTS = 1 << 14


def segmented_aggregate(gid, val, *, num_slots: int,
                        use_pallas: bool | None = None,
                        interpret: bool = False):
    """Per-slot (count, sum, min, max) of ``val`` grouped by ``gid``.

    ``gid == -1`` marks pad tuples (contribute nothing).  Sums wrap in
    int32; empty slots report (0, 0, INT32_MAX, INT32_MIN).
    """
    if use_pallas is None:
        use_pallas = (jax.default_backend() == "tpu"
                      and num_slots <= _AGG_VMEM_SLOTS)
    if (use_pallas or interpret) and gid.shape[0] % 1024 == 0:
        return seg_agg_pallas(gid, val, num_slots=num_slots,
                              interpret=interpret)
    return seg_agg_ref(gid, val, num_slots=num_slots)
