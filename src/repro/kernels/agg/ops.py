"""Dispatcher for the segmented-aggregation kernel.

``segmented_aggregate`` is the data path behind group-by's reduce step
(``repro.ops.groupby`` routes through it): the one-pass Pallas kernel on
TPU-shaped inputs, the masked ``segment_*`` jnp path elsewhere.
"""
import jax

from .agg import seg_agg_pallas, wide_chunk_bits, wide_sums_to_int64
from .ref import seg_agg_ref

__all__ = ["segmented_aggregate", "wide_sums_to_int64"]

# The one-hot accumulation holds a (tile, num_slots) expansion in VMEM;
# beyond this many slots the jnp path wins (and always off-TPU).
_AGG_VMEM_SLOTS = 1 << 14


def segmented_aggregate(gid, val, *, num_slots: int,
                        use_pallas: bool | None = None,
                        interpret: bool = False, wrap32: bool = False):
    """Per-slot (count, sum, min, max) of ``val`` grouped by ``gid``.

    ``gid == -1`` marks pad tuples (contribute nothing).  Sums are wide by
    default — a (chunks+1, num_slots) int32 chunk layout with exact int64
    semantics, chunk width adapted to the input size (to ~143M rows per
    call) and decoded by ``wide_sums_to_int64`` — or a single wrapping
    int32 vector under ``wrap32=True`` (legacy accumulator, kept for
    oracle parity).  Empty slots report (0, 0, INT32_MAX, INT32_MIN).
    """
    if not wrap32:
        wide_chunk_bits(gid.shape[0])    # raise early past the hard cap
    if use_pallas is None:
        use_pallas = (jax.default_backend() == "tpu"
                      and num_slots <= _AGG_VMEM_SLOTS)
    if (use_pallas or interpret) and gid.shape[0] % 1024 == 0:
        return seg_agg_pallas(gid, val, num_slots=num_slots,
                              interpret=interpret, wrap32=wrap32)
    return seg_agg_ref(gid, val, num_slots=num_slots, wrap32=wrap32)
