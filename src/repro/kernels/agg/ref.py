"""Pure-jnp oracle for the segmented-aggregation kernel."""
import jax.numpy as jnp

from .agg import INT32_MAX, INT32_MIN, _num_chunks, wide_chunk_bits


def seg_agg_ref(gid, val, *, num_slots: int, wrap32: bool = False):
    """(count, sum, min, max) per slot; ``gid == -1`` tuples are ignored.

    Invalid tuples are redirected to slot 0 with neutral contributions
    (0 for count/sum, INT32_MAX/MIN for min/max), so every slot they touch
    is unchanged — identical semantics to the kernel's no-match one-hot.

    ``wrap32=False`` (the default) returns the kernel's wide-sum layout —
    ``(chunks+1, num_slots)`` int32 bit-chunk channels (width adapted to
    the input size), exact int64 semantics once decoded with
    ``wide_sums_to_int64`` — built from one int32 ``segment_sum`` pass
    per channel (jax with x64 disabled has no int64 path, so the
    fallback widens exactly the way the kernel does).  ``wrap32=True``
    keeps the single wrapping-int32 sum.
    """
    import jax
    valid = gid >= 0
    g = jnp.where(valid, gid, 0)
    ones = valid.astype(jnp.int32)
    cnt = jax.ops.segment_sum(ones, g, num_segments=num_slots)
    if wrap32:
        sm = jax.ops.segment_sum(val * ones, g, num_segments=num_slots)
    else:
        bits = wide_chunk_bits(gid.shape[0])
        u = val.astype(jnp.uint32)
        chunks = [jax.ops.segment_sum(
            (((u >> jnp.uint32(bits * k))
              & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)
             * ones), g, num_segments=num_slots)
            for k in range(_num_chunks(bits))]
        neg = jax.ops.segment_sum((val < 0).astype(jnp.int32) * ones, g,
                                  num_segments=num_slots)
        sm = jnp.stack(chunks + [neg]).astype(jnp.int32)
    mn = jax.ops.segment_min(jnp.where(valid, val, INT32_MAX), g,
                             num_segments=num_slots)
    mx = jax.ops.segment_max(jnp.where(valid, val, INT32_MIN), g,
                             num_segments=num_slots)
    # Untouched segments: segment_min/max report dtype-dependent identity;
    # normalize to the kernel's neutral elements.
    touched = jax.ops.segment_sum(jnp.ones_like(ones), g,
                                  num_segments=num_slots) > 0
    mn = jnp.where(touched, mn, INT32_MAX)
    mx = jnp.where(touched, mx, INT32_MIN)
    return (cnt.astype(jnp.int32), sm.astype(jnp.int32),
            mn.astype(jnp.int32), mx.astype(jnp.int32))
