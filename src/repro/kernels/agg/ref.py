"""Pure-jnp oracle for the segmented-aggregation kernel."""
import jax.numpy as jnp

from .agg import INT32_MAX, INT32_MIN


def seg_agg_ref(gid, val, *, num_slots: int):
    """(count, sum, min, max) per slot; ``gid == -1`` tuples are ignored.

    Invalid tuples are redirected to slot 0 with neutral contributions
    (0 for count/sum, INT32_MAX/MIN for min/max), so every slot they touch
    is unchanged — identical semantics to the kernel's no-match one-hot.
    """
    import jax
    valid = gid >= 0
    g = jnp.where(valid, gid, 0)
    ones = valid.astype(jnp.int32)
    cnt = jax.ops.segment_sum(ones, g, num_segments=num_slots)
    sm = jax.ops.segment_sum(val * ones, g, num_segments=num_slots)
    mn = jax.ops.segment_min(jnp.where(valid, val, INT32_MAX), g,
                             num_segments=num_slots)
    mx = jax.ops.segment_max(jnp.where(valid, val, INT32_MIN), g,
                             num_segments=num_slots)
    # Untouched segments: segment_min/max report dtype-dependent identity;
    # normalize to the kernel's neutral elements.
    touched = jax.ops.segment_sum(jnp.ones_like(ones), g,
                                  num_segments=num_slots) > 0
    mn = jnp.where(touched, mn, INT32_MAX)
    mx = jnp.where(touched, mx, INT32_MIN)
    return (cnt.astype(jnp.int32), sm.astype(jnp.int32),
            mn.astype(jnp.int32), mx.astype(jnp.int32))
