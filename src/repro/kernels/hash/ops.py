"""Jit'd wrapper: Pallas on TPU, interpret-mode Pallas or jnp elsewhere."""
import jax

from .hash import hash_bucket_pallas
from .ref import hash_bucket_ref


def hash_bucket(keys, *, num_buckets: int, use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n = keys.shape[0]
    if use_pallas and n % 1024 == 0:
        return hash_bucket_pallas(keys, num_buckets=num_buckets)
    return hash_bucket_ref(keys, num_buckets=num_buckets)
