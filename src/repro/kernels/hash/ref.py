"""Pure-jnp oracle for the hash kernel."""
import jax.numpy as jnp

from repro.core.relation import bucket_of


def hash_bucket_ref(keys, *, num_buckets: int):
    return bucket_of(keys, num_buckets).astype(jnp.int32)
