"""Pallas TPU kernel: murmur3 finalizer hash + bucket number (steps
n1/b1/p1 — the paper's ">15x GPU-accelerated" hash computation, Fig. 4).

Pure VPU integer ALU work: inputs are tiled (rows, 128) so every lane of
the 8x128 VPU is busy; one block = (block_rows, 128) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C1 = 0x85EBCA6B
C2 = 0xC2B2AE35


def _hash_kernel(keys_ref, out_ref, *, mask: int):
    h = keys_ref[...].astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(C2)
    h = h ^ (h >> 16)
    out_ref[...] = (h & jnp.uint32(mask)).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("num_buckets", "block_rows", "interpret"))
def hash_bucket_pallas(keys: jax.Array, *, num_buckets: int,
                       block_rows: int = 8, interpret: bool = False):
    """keys: (n,) int32, n % (block_rows*128) == 0.  Returns bucket ids."""
    n = keys.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0, (n, block_rows)
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_hash_kernel, mask=num_buckets - 1),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(keys.reshape(rows, lanes))
    return out.reshape(n)
