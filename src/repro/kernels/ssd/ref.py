"""Pure-jnp oracle: intra-chunk SSD term (from repro.layers.ssd math)."""
import jax.numpy as jnp

from repro.layers.ssd import _segsum


def ssd_intra_chunk_ref(x, dt, b, c, a):
    """x: (B, NC, Q, H, P); dt: (B, NC, Q, H); b/c: (B, NC, Q, N); a: (H,)."""
    dtf = dt.astype(jnp.float32)
    da = dtf * a.astype(jnp.float32)
    l_mat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))      # (B,NC,H,Q,Q)
    l_mat = jnp.where(jnp.isfinite(l_mat), l_mat, 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    m = scores[:, :, None] * l_mat
    y = jnp.einsum("bchqk,bckh,bckhp->bcqhp", m, dtf,
                   x.astype(jnp.float32))
    return y.astype(x.dtype)
