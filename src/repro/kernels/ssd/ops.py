import jax

from .ssd import ssd_intra_chunk_pallas
from .ref import ssd_intra_chunk_ref


def ssd_intra_chunk(x, dt, b, c, a, *, use_pallas: bool | None = None,
                    interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return ssd_intra_chunk_pallas(x, dt, b, c, a, interpret=interpret)
    return ssd_intra_chunk_ref(x, dt, b, c, a)
