"""Pallas TPU kernel: Mamba2 SSD intra-chunk pass.

One grid step = one (batch, chunk, head): computes

    Y = (L ∘ (C B^T)) diag(dt) X,   L[i,j] = exp(sum_{j<k<=i} dt_k A)

entirely in VMEM with two MXU matmuls ((q,n)@(n,q) and (q,q)@(q,p)).
The inter-chunk recurrence (tiny (h,p,n) state) stays in jnp — it is
sequential by nature and negligible FLOPs (DESIGN.md §4: the two SSD
"steps" with a barrier, chunk length = the cost-model tiling knob).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, *, chunk: int):
    x = x_ref[...][0, 0].astype(jnp.float32)        # (q, p)
    dt = dt_ref[...][0, 0].astype(jnp.float32)      # (q,)
    bmat = b_ref[...][0, 0].astype(jnp.float32)     # (q, n)
    cmat = c_ref[...][0, 0].astype(jnp.float32)     # (q, n)
    a = a_ref[0, 0]                                  # scalar A (per head)
    da = dt * a                                      # (q,)
    cs = jnp.cumsum(da)
    seg = cs[:, None] - cs[None, :]                  # (q, q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    scores = cmat @ bmat.T                           # (q, q) MXU
    m = scores * l_mat * dt[None, :]
    y = m @ x                                        # (q, p) MXU
    o_ref[...] = y.astype(o_ref.dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_pallas(x, dt, b, c, a, *, interpret: bool = False):
    """x: (B, NC, Q, H, P); dt: (B, NC, Q, H); b/c: (B, NC, Q, N);
    a: (H,).  Returns Y_intra: (B, NC, Q, H, P)."""
    bs, nc, q, h, p = x.shape
    n = b.shape[-1]
    xt = x.transpose(0, 1, 3, 2, 4).reshape(bs * nc, h, q, p)
    dtt = dt.transpose(0, 1, 3, 2).reshape(bs * nc, h, q)
    bt = jnp.broadcast_to(b.reshape(bs * nc, 1, q, n), (bs * nc, 1, q, n))
    ct = jnp.broadcast_to(c.reshape(bs * nc, 1, q, n), (bs * nc, 1, q, n))
    a2 = jnp.broadcast_to(a.astype(jnp.float32)[None, :], (1, h))
    grid = (bs * nc, h)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs * nc, h, q, p), x.dtype),
        interpret=interpret,
    )(xt.reshape(bs * nc, h, q, p), dtt, bt, ct, a2)
    return out.reshape(bs, nc, h, q, p).transpose(0, 1, 3, 2, 4)
