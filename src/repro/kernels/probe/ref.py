"""Pure-jnp oracle for the partitioned probe kernel."""
import jax
import jax.numpy as jnp


def probe_ref(table_keys, table_rids, probe_keys):
    """Vectorized per-partition sorted lookup (first match or -1)."""
    def one(tk, tr, pk):
        pos = jnp.searchsorted(tk.astype(jnp.uint32),
                               pk.astype(jnp.uint32)).astype(jnp.int32)
        pos = jnp.clip(pos, 0, tk.shape[0] - 1)
        found = (tk[pos] == pk) & (pk >= 0)
        return jnp.where(found, tr[pos], -1)
    return jax.vmap(one)(table_keys, table_rids, probe_keys)
