"""Wrapper + host-side packing of a partitioned probe problem."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.relation import Relation, radix_of
from .probe import probe_pallas, PAD_KEY
from .ref import probe_ref


def probe(table_keys, table_rids, probe_keys, *,
          use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return probe_pallas(table_keys, table_rids, probe_keys,
                            interpret=interpret)
    return probe_ref(table_keys, table_rids, probe_keys)


def build_partitioned_table(build: Relation, probe_rel: Relation,
                            *, total_bits: int):
    """Host-side packing: (P, K) sorted build keys + (P, M) probe keys.

    (numpy; test/bench helper — the distributed path keeps data on device.)
    """
    p = 1 << total_bits
    bk, br = np.asarray(build.key), np.asarray(build.rid)
    pk, pr = np.asarray(probe_rel.key), np.asarray(probe_rel.rid)
    bpid = np.asarray(radix_of(build.key, shift=0, bits=total_bits))
    ppid = np.asarray(radix_of(probe_rel.key, shift=0, bits=total_bits))
    k_cap = max(8, int(np.bincount(bpid, minlength=p).max()))
    m_cap = max(8, int(np.bincount(ppid, minlength=p).max()))
    k_cap = ((k_cap + 127) // 128) * 128
    m_cap = ((m_cap + 127) // 128) * 128
    tk = np.full((p, k_cap), int(PAD_KEY), np.int32)
    tr = np.full((p, k_cap), -1, np.int32)
    qk = np.full((p, m_cap), -1, np.int32)
    qr = np.full((p, m_cap), -1, np.int32)
    for part in range(p):
        sel = bpid == part
        keys, rids = bk[sel], br[sel]
        order = np.argsort(keys.astype(np.uint32), kind="stable")
        tk[part, :sel.sum()] = keys[order]
        tr[part, :sel.sum()] = rids[order]
        sel = ppid == part
        qk[part, :sel.sum()] = pk[sel]
        qr[part, :sel.sum()] = pr[sel]
    return (jnp.asarray(tk), jnp.asarray(tr), jnp.asarray(qk),
            jnp.asarray(qr))
