"""Pallas TPU kernel: partitioned hash probe (steps p2/p3 fused).

After radix partitioning, each partition's key table fits VMEM — the
kernel processes one partition per grid step: the partition's sorted key
array is the VMEM-resident "shared hash table" (the paper's shared-L2
reuse, DESIGN.md §2), and each probe lane binary-searches it
(log2(K) VMEM gathers instead of the paper's pointer walk).

Layout (built by ops.build_partitioned_table):
  table_keys (P, K) int32 — per-partition keys, sorted, padded with INT_MAX
  table_rids (P, K) int32 — matching build rids
  probe_keys (P, M) int32 — per-partition probe keys, padded with -1
Output:
  match_rid  (P, M) int32 — first matching build rid, or -1

Multi-match fanout (p4) is expanded outside with the scan allocator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD_KEY = jnp.int32(2**31 - 1)


def _probe_kernel(tkeys_ref, trids_ref, pkeys_ref, out_ref, *, k_cap: int):
    tkeys = tkeys_ref[...][0]          # (K,)
    trids = trids_ref[...][0]          # (K,)
    pk = pkeys_ref[...][0]             # (M,)
    iters = max(1, k_cap.bit_length() + 1)
    lo = jnp.zeros_like(pk)
    hi = jnp.full_like(pk, k_cap)
    target = pk.astype(jnp.uint32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        midc = jnp.clip(mid, 0, k_cap - 1)
        mk = tkeys[midc].astype(jnp.uint32)   # VMEM vector gather
        go = (mk < target) & (lo < hi)
        return (jnp.where(go, mid + 1, lo),
                jnp.where(go | (lo >= hi), hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    pos = jnp.clip(lo, 0, k_cap - 1)
    found = (tkeys[pos] == pk) & (pk >= 0)
    out_ref[...] = jnp.where(found, trids[pos], -1)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_pallas(table_keys, table_rids, probe_keys, *,
                 interpret: bool = False):
    p, k_cap = table_keys.shape
    m = probe_keys.shape[1]
    return pl.pallas_call(
        functools.partial(_probe_kernel, k_cap=k_cap),
        grid=(p,),
        in_specs=[pl.BlockSpec((1, k_cap), lambda i: (i, 0)),
                  pl.BlockSpec((1, k_cap), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, m), jnp.int32),
        interpret=interpret,
    )(table_keys, table_rids, probe_keys)
