"""Pure-jnp oracle for the radix histogram kernel."""
import jax
import jax.numpy as jnp


def radix_hist_ref(pid, *, num_parts: int):
    return jax.ops.segment_sum(jnp.ones_like(pid), pid,
                               num_segments=num_parts).astype(jnp.int32)
