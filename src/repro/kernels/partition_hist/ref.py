"""Pure-jnp oracles for the radix-partition kernels."""
import jax
import jax.numpy as jnp


def radix_hist_ref(pid, *, num_parts: int):
    return jax.ops.segment_sum(jnp.ones_like(pid), pid,
                               num_segments=num_parts).astype(jnp.int32)


def partition_hist_fused_ref(keys, *, shift: int, bits: int):
    """Oracle for the fused n1+n2 kernel: (pid, hist)."""
    from repro.core.relation import radix_of
    pid = radix_of(keys, shift=shift, bits=bits)
    return pid, radix_hist_ref(pid, num_parts=1 << bits)


def radix_scatter_ref(rid, key, pid, starts=None, *, num_parts: int = 0):
    """Oracle for the fused n3 kernel: stable reorder of tuples by pid.

    ``dest[i] = starts[pid[i]] + rank_of_i_within_its_partition`` is exactly
    the inverse of the stable argsort permutation, so the oracle is the
    stable sort itself (``starts`` is accepted for signature parity).
    """
    order = jnp.argsort(pid, stable=True)
    return rid[order], key[order]
