"""Pallas TPU kernel: FUSED radix-partition step n3 (scan + scatter).

Stable reorder of ``<rid, key>`` tuples into their partitions.  The seed
path materialized a full argsort; here the exclusive scan over partition
headers and the scatter are fused into one streaming kernel:

  * a VMEM scratch holds the running per-partition fill count — the scan
    state carried across grid steps (deterministic sequential accumulation,
    no atomics: DESIGN §2);
  * each tile computes, per tuple, its stable within-tile rank via a
    one-hot cumulative sum, adds the global partition start plus the
    running offset, and scatters the tuple into the full VMEM-resident
    output block via one-hot accumulation (every destination is written
    exactly once, so `+=` over zero-initialized output is a scatter).

The one-hot scatter is O(tile * n) per tile, so this kernel is for
VMEM-resident relations (the per-partition working sets the planner
produces); ops.py gates dispatch by size and falls back to the fused jnp
path otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(pid_ref, rid_ref, key_ref, starts_ref,
                    out_rid_ref, out_key_ref, offs_ref, *, num_parts: int,
                    n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        offs_ref[...] = jnp.zeros_like(offs_ref)
        out_rid_ref[...] = jnp.zeros_like(out_rid_ref)
        out_key_ref[...] = jnp.zeros_like(out_key_ref)

    pid = pid_ref[...].reshape(-1)                        # (tile,)
    onehot = (pid[:, None] == jnp.arange(num_parts, dtype=jnp.int32)[None, :]
              ).astype(jnp.int32)                         # (tile, P)
    # Stable within-tile rank: #earlier tuples of the same partition
    # (exclusive one-hot cumsum along the tile axis).
    rank = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(axis=1)
    starts = starts_ref[...].reshape(-1)                  # (P,)
    offs = offs_ref[...].reshape(-1)                      # (P,) scan state
    dest = starts[pid] + offs[pid] + rank                 # (tile,) in [0,n)

    # Scatter: one-hot over the full output; each dest hit exactly once.
    scat = (dest[:, None] == jnp.arange(n, dtype=jnp.int32)[None, :]
            ).astype(jnp.int32)                           # (tile, n)
    rid = rid_ref[...].reshape(-1)
    key = key_ref[...].reshape(-1)
    out_rid_ref[...] += (rid[:, None] * scat).sum(axis=0).reshape(
        out_rid_ref.shape)
    out_key_ref[...] += (key[:, None] * scat).sum(axis=0).reshape(
        out_key_ref.shape)
    # Advance the scan state by this tile's histogram.
    offs_ref[...] += onehot.sum(axis=0).reshape(offs_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("num_parts", "block_rows", "interpret"))
def radix_scatter_pallas(rid: jax.Array, key: jax.Array, pid: jax.Array,
                         starts: jax.Array, *, num_parts: int,
                         block_rows: int = 8, interpret: bool = False):
    """Stable scatter of tuples to ``starts[pid] + running offset``.

    ``rid``/``key``/``pid``: (n,) int32 with n % (block_rows*128) == 0;
    ``starts``: (num_parts,) exclusive-scanned global histogram of ``pid``.
    Returns the reordered ``(rid, key)`` — bit-identical to a stable sort
    of the tuples by ``pid``.
    """
    n = pid.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0 and n == rows * lanes, (n, block_rows)
    grid = (rows // block_rows,)
    out_rid, out_key = pl.pallas_call(
        functools.partial(_scatter_kernel, num_parts=num_parts, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((1, num_parts), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((rows, lanes), lambda i: (0, 0)),
                   pl.BlockSpec((rows, lanes), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
                   jax.ShapeDtypeStruct((rows, lanes), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, num_parts), jnp.int32)],
        interpret=interpret,
    )(pid.reshape(rows, lanes), rid.reshape(rows, lanes),
      key.reshape(rows, lanes), starts.reshape(1, num_parts))
    return out_rid.reshape(n), out_key.reshape(n)
