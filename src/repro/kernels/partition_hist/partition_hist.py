"""Pallas TPU kernel: radix-partition histogram (step n2).

Grid tiles stream the partition-id vector through VMEM; each tile adds its
one-hot counts into the shared (num_parts,) output block (same output
block for every grid step -> sequential accumulation, the TPU-idiomatic
replacement for the paper's atomic counters — DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(pid_ref, out_ref, *, num_parts: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pid = pid_ref[...].reshape(-1)                       # (tile,)
    onehot = (pid[:, None] == jnp.arange(num_parts,
                                         dtype=jnp.int32)[None, :])
    out_ref[...] += onehot.astype(jnp.int32).sum(axis=0)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("num_parts", "block_rows", "interpret"))
def radix_hist_pallas(pid: jax.Array, *, num_parts: int,
                      block_rows: int = 8, interpret: bool = False):
    """pid: (n,) int32 in [0, num_parts).  Returns (num_parts,) counts."""
    n = pid.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0 and n == rows * lanes
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, num_parts=num_parts),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_parts), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_parts), jnp.int32),
        interpret=interpret,
    )(pid.reshape(rows, lanes))
    return out[0]
