import jax

from .partition_hist import radix_hist_pallas
from .ref import radix_hist_ref


def radix_hist(pid, *, num_parts: int, use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas and pid.shape[0] % 1024 == 0:
        return radix_hist_pallas(pid, num_parts=num_parts)
    return radix_hist_ref(pid, num_parts=num_parts)
