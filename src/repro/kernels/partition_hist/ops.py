"""Dispatchers for the radix-partition kernels.

``fused_partition_pass`` is the data path behind one radix pass everywhere
(``repro.core.partition`` routes through it): the fused n1+n2 kernel plus
the fused scan+scatter n3 kernel on TPU-shaped inputs, and an equivalent
single-dispatch jnp path (one pid computation feeding histogram, scan and
stable reorder — no re-materialization between steps) elsewhere.
"""
import jax
import jax.numpy as jnp

from .fused import partition_hist_fused_pallas
from .partition_hist import radix_hist_pallas
from .ref import partition_hist_fused_ref, radix_hist_ref
from .reorder import radix_scatter_pallas

# The one-hot scatter kernel keeps the whole output in VMEM; beyond this
# many tuples the fused jnp path wins (and always on non-TPU backends).
_SCATTER_VMEM_LIMIT = 1 << 17


def radix_hist(pid, *, num_parts: int, use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas and pid.shape[0] % 1024 == 0:
        return radix_hist_pallas(pid, num_parts=num_parts)
    return radix_hist_ref(pid, num_parts=num_parts)


def fused_partition_pass(rel, *, shift: int, bits: int,
                         use_pallas: bool | None = None,
                         interpret: bool = False):
    """One full radix pass (n1+n2+n3 fused).

    Returns ``(reordered Relation, starts, counts)`` for the ``bits``-wide
    digit at ``shift``; the reorder is a stable clustering by that digit.
    """
    from repro.core.relation import Relation

    n = rel.key.shape[0]
    num_parts = 1 << bits
    if use_pallas is None:
        use_pallas = (jax.default_backend() == "tpu"
                      and n <= _SCATTER_VMEM_LIMIT)
    if (use_pallas or interpret) and n % 1024 == 0:
        pid, counts = partition_hist_fused_pallas(
            rel.key, shift=shift, bits=bits, interpret=interpret)
        starts = jnp.cumsum(counts) - counts
        rid, key = radix_scatter_pallas(rel.rid, rel.key, pid,
                                        starts.astype(jnp.int32),
                                        num_parts=num_parts,
                                        interpret=interpret)
        return Relation(rid, key), starts, counts
    # Fused jnp path: pid computed once, shared by histogram and reorder.
    pid, counts = partition_hist_fused_ref(rel.key, shift=shift, bits=bits)
    starts = jnp.cumsum(counts) - counts
    order = jnp.argsort(pid, stable=True)
    return Relation(rel.rid[order], rel.key[order]), starts, counts
