"""Pallas TPU kernel: FUSED radix-partition steps n1+n2.

The seed pipeline materialized the partition-id vector between n1 (compute
partition number) and n2 (histogram): one full HBM round trip of 4 bytes per
tuple.  This kernel computes the murmur3 radix digit AND accumulates the
histogram in the same VMEM pass — the pid tile never leaves VMEM before it
is consumed (the data-path-fusion argument of Ozawa et al.; DESIGN §2).

Grid tiles stream the key vector; each tile writes its pid block and adds
its one-hot counts into the shared (num_parts,) output block (same output
block for every grid step -> sequential accumulation, the TPU-idiomatic
replacement for the paper's atomic counters).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single source of truth for the hash: the same constants radix_of and the
# ref oracle use (the mix steps are written out because nested jit does not
# lower inside a compiled Pallas body).
from repro.core.relation import MURMUR_C1, MURMUR_C2


def _fused_kernel(keys_ref, pid_ref, hist_ref, *, shift: int, bits: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    # n1: murmur3 fmix32 + radix digit, entirely in VMEM registers.
    h = keys_ref[...].astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * MURMUR_C1
    h = h ^ (h >> 13)
    h = h * MURMUR_C2
    h = h ^ (h >> 16)
    pid = ((h >> jnp.uint32(shift))
           & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)
    pid_ref[...] = pid

    # n2: histogram of the SAME tile, before pid ever reaches HBM.
    num_parts = 1 << bits
    flat = pid.reshape(-1)
    onehot = (flat[:, None] == jnp.arange(num_parts,
                                          dtype=jnp.int32)[None, :])
    hist_ref[...] += onehot.astype(jnp.int32).sum(axis=0)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("shift", "bits", "block_rows",
                                    "interpret"))
def partition_hist_fused_pallas(keys: jax.Array, *, shift: int, bits: int,
                                block_rows: int = 8,
                                interpret: bool = False):
    """keys: (n,) int32/uint32, n % (block_rows*128) == 0.

    Returns ``(pid, hist)``: the per-tuple partition ids for hash bits
    ``[shift, shift+bits)`` and the (2**bits,) partition histogram.
    """
    assert shift + bits <= 32, (shift, bits)
    n = keys.shape[0]
    lanes = 128
    rows = n // lanes
    assert rows % block_rows == 0 and n == rows * lanes, (n, block_rows)
    num_parts = 1 << bits
    grid = (rows // block_rows,)
    pid, hist = pl.pallas_call(
        functools.partial(_fused_kernel, shift=shift, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
                   pl.BlockSpec((1, num_parts), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
                   jax.ShapeDtypeStruct((1, num_parts), jnp.int32)],
        interpret=interpret,
    )(keys.reshape(rows, lanes))
    return pid.reshape(n), hist[0]
