"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU against pure-jnp oracles; selected on TPU via ops.py wrappers).

  hash/            murmur3 bucket hash            (paper steps n1/b1/p1)
  partition_hist/  radix-partition histogram      (paper step n2)
  probe/           partitioned bucketed probe     (paper steps p2/p3)
  flash_attn/      flash attention forward        (LM substrate)
  ssd/             Mamba2 SSD intra-chunk         (LM substrate)
"""
