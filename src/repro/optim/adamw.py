"""AdamW with dtype-configurable state (bf16 moments for the 400B config),
global-norm clipping and a cosine schedule.  Pure pytree functions — states
inherit the parameter shardings (ZeRO: optimizer shards with the weights).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"    # "bfloat16" for memory-tight configs
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    sdt = jnp.dtype(cfg.state_dtype)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu1 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu1 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu1 / bc1
        nhat = nu1 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu1.astype(sdt), nu1.astype(sdt))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
