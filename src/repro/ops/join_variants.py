"""Semi / anti / left-outer joins over the existing probe series.

The probe steps p1–p3 already compute, per probe tuple, its matching key
entry and match count; the variants differ only in what p4 emits:

  * ``semi``       — probe rows with ≥ 1 match, emitted once each.  No
    payload gather at all: the p4 expansion (2 random accesses/tuple in
    the cost model) is replaced by a flag compaction — which is why the
    planner prices semi/anti probes cheaper than inner.
  * ``anti``       — probe rows with 0 matches (pad rows excluded).
  * ``left_outer`` — the inner expansion plus an unmatched-row emission
    pass: probe rows with 0 matches appear once with ``build_rid ==
    NULL_RID`` (-1, the padded-result sentinel doubling as SQL NULL).

All three run under the same C/G ratio splits as the inner probe
(``CoProcessor.probe_table_variant`` mirrors ``probe_table``), against the
same (possibly cached) build table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hash_table as ht
from repro.core.coprocess import CoProcessor, Timing
from repro.core.relation import Relation

JOIN_KINDS = ("inner", "semi", "anti", "left_outer")
NULL_RID = int(ht.INVALID)   # -1: build side of an unmatched outer row


def _emit_flagged(probe_rid: jax.Array, flags: jax.Array,
                  max_out: int) -> ht.JoinResult:
    """Compact flagged probe rows to the front (semi/anti emission)."""
    n = probe_rid.shape[0]
    total = flags.astype(jnp.int32).sum()
    rank = jnp.arange(max_out, dtype=jnp.int32)
    valid = rank < jnp.minimum(total, max_out)
    if n == 0:
        return ht.JoinResult(jnp.full((max_out,), ht.INVALID),
                             jnp.full((max_out,), ht.INVALID),
                             jnp.int32(0))
    order = jnp.argsort(~flags, stable=True)
    src = order[jnp.clip(rank, 0, n - 1)]
    out_probe = jnp.where(valid, probe_rid[src], ht.INVALID)
    return ht.JoinResult(out_probe, jnp.full((max_out,), ht.INVALID),
                         jnp.minimum(total, max_out).astype(jnp.int32))


def _probe_p4_outer(table: ht.HashTable, probe_rid: jax.Array,
                    entry: jax.Array, nmatch: jax.Array, valid_row,
                    max_out: int) -> ht.JoinResult:
    """p4 with unmatched-row emission: fanout ``max(nmatch, 1)`` per row."""
    n = probe_rid.shape[0]
    nm_eff = jnp.where(valid_row, jnp.maximum(nmatch, 1), 0)
    offs = jnp.cumsum(nm_eff)
    total = offs[-1] if n > 0 else jnp.int32(0)
    starts = offs - nm_eff
    out_idx = jnp.arange(max_out, dtype=jnp.int32)
    src = jnp.searchsorted(offs, out_idx, side="right").astype(jnp.int32)
    valid = out_idx < jnp.minimum(total, max_out)
    src_c = jnp.clip(src, 0, max(n - 1, 0))
    j = out_idx - starts[src_c]
    cap = table.rids.shape[0]
    bpos = jnp.clip(
        table.key_rid_start[jnp.clip(entry[src_c], 0, cap - 1)] + j,
        0, cap - 1)
    matched = nmatch[src_c] > 0
    out_build = jnp.where(valid & matched, table.rids[bpos], ht.INVALID)
    out_probe = jnp.where(valid, probe_rid[src_c], ht.INVALID)
    return ht.JoinResult(out_probe, out_build,
                         jnp.minimum(total, max_out).astype(jnp.int32))


@partial(jax.jit, static_argnames=("max_out", "kind"))
def probe_hash_table_variant(rel: Relation, table: ht.HashTable,
                             max_out: int, kind: str) -> ht.JoinResult:
    """Full probe phase under variant semantics (p1 -> p2 -> p3 -> emit).

    Pad tuples (``rid == INVALID``) are never emitted — in particular they
    do not count as "unmatched" for anti/left_outer.
    """
    assert kind in JOIN_KINDS, kind
    if kind == "inner":
        return ht.probe_hash_table(rel, table, max_out)
    bkt = ht.probe_p1(rel.key, table.num_buckets)
    kstart, kcount = ht.probe_p2(table, bkt)
    entry, nmatch = ht.probe_p3(table, rel.key, kstart, kcount)
    valid_row = rel.rid != ht.INVALID
    if kind == "semi":
        return _emit_flagged(rel.rid, (nmatch > 0) & valid_row, max_out)
    if kind == "anti":
        return _emit_flagged(rel.rid, (nmatch == 0) & valid_row, max_out)
    return _probe_p4_outer(table, rel.rid, entry, nmatch, valid_row,
                           max_out)


def probe_table_variant(cp: CoProcessor, probe_rel: Relation,
                        table: ht.HashTable, *, kind: str, max_out: int,
                        ratios, timing: Timing | None = None
                        ) -> tuple[ht.JoinResult, Timing]:
    """Variant probe against an existing (possibly cached) table.

    Delegates to ``CoProcessor.probe_table`` — same ratio cut, table
    replication, per-group capacity slack, and concat — with the variant
    emission kernel swapped in per group.
    """
    if kind == "inner":
        return cp.probe_table(probe_rel, table, max_out=max_out,
                              ratios=ratios, timing=timing)

    def fn(mo):
        return lambda r, t: probe_hash_table_variant(r, t, mo, kind)

    return cp.probe_table(probe_rel, table, max_out=max_out, ratios=ratios,
                          timing=timing, probe_fn=fn,
                          tag=f"probe_v:{kind}")


# ---------------------------------------------------------------------------
# NumPy oracle (testing/verification only).
# ---------------------------------------------------------------------------

def join_variant_oracle(build: Relation, probe: Relation,
                        kind: str) -> np.ndarray:
    """Sorted (probe_rid, build_rid) pairs under variant semantics."""
    inner = ht.join_oracle(build, probe)
    if kind == "inner":
        return inner
    pr = np.asarray(probe.rid)
    matched = np.unique(inner[:, 0])
    if kind == "semi":
        out = np.stack([np.sort(matched),
                        np.full(matched.size, NULL_RID)], axis=1)
        return out.astype(np.int64)
    unmatched = np.setdiff1d(pr, matched)
    miss = np.stack([unmatched, np.full(unmatched.size, NULL_RID)], axis=1)
    if kind == "anti":
        return miss.astype(np.int64)
    out = np.concatenate([inner, miss.astype(np.int64)])
    return out[np.lexsort((out[:, 1], out[:, 0]))]
