"""Co-processed hash group-by aggregation (the join's sibling operator).

Group-by shares the join's partition/probe cost structure (Shanbhag et
al.): cluster the group keys with the SAME fused radix passes PHJ uses
(n1+n2 one-VMEM-pass, scan+scatter n3), then reduce each partition's
VMEM-resident tuples.  The co-processing skeleton mirrors ``CoProcessor.
phj`` one-to-one:

  * **partition phase** — the key relation is ratio-split between the C
    and G groups (``partition_ratio``), each side runs the planner-chosen
    pass schedule through the fused data path;
  * **aggregate phase** — partitions are ownership-split
    (``agg_ratio``: C owns partition ids ``[0, own)``), each group sorts
    its owned tuples by key (the b2 idiom), derives dense group slots from
    boundary flags (b3), and reduces count/sum/min/max in one pass through
    ``repro.kernels.agg`` — the aggregation analogue of the per-partition
    SHJ.  Identical keys land in one partition, so the two groups' group
    lists are disjoint and concatenate without a merge.

``schedule=None`` skips partitioning entirely (small inputs: the sort *is*
the hash table).  ``agg_ratio`` 0 or 1 then runs the whole relation on one
group (the CPU_ONLY / GPU_ONLY schemes); a fractional ratio row-splits the
relation — each group builds a *partial* group list on its share
concurrently (async dispatch overlaps the two programs) and the partials
merge on the host, the paper's separate-tables-plus-merge mode (Fig. 3)
applied to aggregation (local/global two-phase aggregation; the merge is
O(groups), cheap whenever groups ≪ tuples).  The planner prices all three
against the partitioned DD split.

Semantics: sums (and avg numerators) accumulate *wide* by default — exact
int64, carried through the device path as the segmented-agg kernel's
five-channel int32 layout since TPUs (and jax with x64 disabled) have no
native int64 — so large-value workloads no longer silently wrap.
``wrap32=True`` restores the legacy wrapping-int32 accumulator; the NumPy
oracle (``groupby_ref``) reproduces either mode exactly.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coprocess import CoProcessor, Timing, _round_up
from repro.core.hash_table import INVALID
from repro.core.relation import Relation, radix_of
from repro.kernels.agg import segmented_aggregate, wide_sums_to_int64

# Pad sentinel for group-key relations: never collides with the join-side
# sentinels (-2/-3) or the executor fill keys (-6/-7); pads carry
# rid == INVALID, which is what actually excludes them from aggregation.
GROUP_PAD_KEY = -4

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


@dataclasses.dataclass
class GroupByResult:
    """Host-side group list: one row per distinct key, unordered."""

    keys: np.ndarray       # (g,) int32 distinct group keys
    counts: np.ndarray     # (g,) int32 tuples per group
    sums: np.ndarray       # (g,) int64 exact sums (int32 wrap under wrap32)
    mins: np.ndarray       # (g,) int32
    maxs: np.ndarray       # (g,) int32

    @property
    def num_groups(self) -> int:
        return int(self.keys.shape[0])

    def sorted(self) -> "GroupByResult":
        """Key-ascending copy (canonical order for comparisons)."""
        o = np.argsort(self.keys, kind="stable")
        return GroupByResult(self.keys[o], self.counts[o], self.sums[o],
                             self.mins[o], self.maxs[o])

    def avgs(self) -> np.ndarray:
        """float64 means from the sums (exact by default, wrapped under
        ``wrap32``) — matches the oracle's mode."""
        return self.sums.astype(np.float64) / np.maximum(self.counts, 1)


@partial(jax.jit, static_argnames=("num_slots", "use_pallas", "interpret",
                                   "wrap32"))
def grouped_agg(rel: Relation, values: jax.Array, *, num_slots: int,
                use_pallas: bool | None = None, interpret: bool = False,
                wrap32: bool = False):
    """One group's aggregation: sort by key, flag boundaries, reduce.

    ``values[i]`` belongs to tuple ``i`` of ``rel``; pad tuples are marked
    by ``rid == INVALID`` and contribute nothing.  Returns padded
    ``(ukeys, count, sum, min, max, num_groups)`` — slot ``g`` holds the
    ``g``-th distinct key in (uint32) sorted order; slots past
    ``num_groups`` report count 0.  ``sum`` is the kernel's (5, slots)
    wide-channel layout by default (``wide_sums_to_int64`` decodes) or a
    wrapping int32 vector under ``wrap32=True``.
    """
    n = rel.key.shape[0]
    order = jnp.argsort(rel.key.astype(jnp.uint32), stable=True)
    skey = rel.key[order]
    svals = values[order]
    valid = rel.rid[order] != INVALID
    first = (jnp.concatenate([jnp.ones((1,), jnp.bool_),
                              skey[1:] != skey[:-1]])
             if n > 0 else jnp.zeros((0,), jnp.bool_))
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1
    ukeys = jnp.full((num_slots,), GROUP_PAD_KEY,
                     jnp.int32).at[jnp.clip(gid, 0, num_slots - 1)].set(skey)
    cnt, sm, mn, mx = segmented_aggregate(
        jnp.where(valid, gid, -1), svals, num_slots=num_slots,
        use_pallas=use_pallas, interpret=interpret, wrap32=wrap32)
    num_groups = (first & valid).astype(jnp.int32).sum()
    return ukeys, cnt, sm, mn, mx, num_groups


def _gather_values(values, rid) -> np.ndarray:
    """values[rid] with pad rows (rid == -1) mapped to 0.

    ``values`` may be a device array (the query pipeline's fused hand-off
    passes the sink's value column device-resident): the gather then runs
    on device instead of forcing a host round trip.
    """
    if isinstance(values, jax.Array):
        r = jnp.asarray(rid)
        safe = jnp.clip(r, 0, max(values.shape[0] - 1, 0))
        out = (jnp.take(values, safe, axis=0) if values.shape[0]
               else jnp.zeros_like(r))
        return jnp.where(r >= 0, out, 0).astype(jnp.int32)
    r = np.asarray(rid)
    safe = np.clip(r, 0, max(values.shape[0] - 1, 0))
    out = values[safe] if values.shape[0] else np.zeros_like(r)
    return np.where(r >= 0, out, 0).astype(np.int32)


def _merge_partials(a: GroupByResult, b: GroupByResult) -> GroupByResult:
    """Global aggregation of two partial group lists (separate + merge).

    Row-split partials may share keys; counts/sums add (wide int64 sums
    add exactly; wrap32 partials add in int32 modular arithmetic,
    associative with the per-group wrap), mins/maxs fold.  O(total
    partial groups) on the host.
    """
    keys = np.concatenate([a.keys, b.keys])
    uk, inv = np.unique(keys, return_inverse=True)
    g = uk.shape[0]
    cnt = np.zeros(g, np.int64)
    np.add.at(cnt, inv, np.concatenate([a.counts, b.counts]).astype(np.int64))
    sm = np.zeros(g, np.int64)
    np.add.at(sm, inv, np.concatenate([a.sums, b.sums]).astype(np.int64))
    mn = np.full(g, INT32_MAX, np.int64)
    np.minimum.at(mn, inv, np.concatenate([a.mins, b.mins]).astype(np.int64))
    mx = np.full(g, INT32_MIN, np.int64)
    np.maximum.at(mx, inv, np.concatenate([a.maxs, b.maxs]).astype(np.int64))
    sum_dtype = (np.int64 if a.sums.dtype == np.int64
                 or b.sums.dtype == np.int64 else np.int32)
    return GroupByResult(uk.astype(np.int32), cnt.astype(np.int32),
                         sm.astype(sum_dtype), mn.astype(np.int32),
                         mx.astype(np.int32))


def _collect(pieces, wrap32: bool = True) -> GroupByResult:
    """Concatenate per-group device results, dropping empty slots.

    Wide pieces carry sums as (5, slots) chunk channels; they decode to
    exact int64 here (host side, O(groups)).
    """
    keys, cnts, sms, mns, mxs = [], [], [], [], []
    for ukeys, cnt, sm, mn, mx, _ in pieces:
        cnt = np.asarray(cnt)
        live = cnt > 0
        sm = np.asarray(sm)
        sm = sm[live] if sm.ndim == 1 else wide_sums_to_int64(sm)[live]
        keys.append(np.asarray(ukeys)[live])
        cnts.append(cnt[live])
        sms.append(sm)
        mns.append(np.asarray(mn)[live])
        mxs.append(np.asarray(mx)[live])
    sum_dtype = np.int32 if wrap32 else np.int64
    cat = lambda xs, dt=np.int32: (np.concatenate(xs) if xs
                                   else np.zeros(0, dt)).astype(dt)
    return GroupByResult(cat(keys), cat(cnts), cat(sms, sum_dtype),
                         cat(mns), cat(mxs))


def groupby_coprocessed(cp: CoProcessor, rel: Relation, values, *,
                        schedule: tuple[int, ...] | None = None,
                        partition_ratio: float = 1.0, agg_ratio: float = 1.0,
                        interpret: bool = False, wrap32: bool = False,
                        ctx=None) -> tuple[GroupByResult, Timing]:
    """Hash group-by of ``values`` by ``rel.key`` across the two groups.

    ``rel.rid`` must index rows of ``values`` (the arange gather
    convention); rid ``INVALID`` marks pad tuples.  ``values`` may be a
    host array or a device array (the fused pipeline hands the sink its
    value column device-resident).  Sums are exact int64 unless
    ``wrap32=True`` requests the legacy int32 wrap.  ``ctx`` (a
    ``QueryContext``) makes the partition phase preemptible —
    pass-at-a-time with ``ctx.check`` at every boundary and once more
    before each aggregate phase.  See module docstring for the phase
    structure.
    """
    from repro.core.partition import radix_partition_scheduled

    timing = Timing(tracer=cp.tracer)
    if isinstance(values, jax.Array):
        values = values.astype(jnp.int32)
    else:
        values = np.ascontiguousarray(np.asarray(values, dtype=np.int32))
    if rel.size == 0:
        timing.phase_s["partition"] = 0.0
        timing.phase_s["agg"] = 0.0
        return _collect([], wrap32=wrap32), timing
    rel = cp.pad_relation(rel, GROUP_PAD_KEY)
    if schedule:
        timing.notes["schedule"] = list(schedule)
        total_bits = sum(schedule)

        def part_fn(r):
            return radix_partition_scheduled(r, schedule=schedule,
                                             interpret=interpret).rel

        with timing.phase("partition", passes=len(schedule)):
            if ctx is not None:
                rel = cp._partition_side_cooperative(
                    "GB", rel, tuple(schedule), partition_ratio, ctx, 0,
                    timing, interpret=interpret)
            else:
                n = rel.size
                cut = cp._cut(n, partition_ratio)
                if cp.discrete and 0 < cut < n:
                    cp._bus_delay((n - cut) * 8, timing)
                pieces = []
                if cut > 0:
                    f = cp.c.jit(("gb_part", cut, schedule), part_fn)
                    pieces.append(f(cp.c.put_items(rel.take(0, cut))))
                if cut < n:
                    f = cp.g.jit(("gb_part", n - cut, schedule), part_fn)
                    pieces.append(f(cp.g.put_items(rel.take(cut, n))))
                pieces = [jax.tree.map(jax.device_get, x) for x in pieces]
                rel = Relation(jnp.concatenate([x.rid for x in pieces]),
                               jnp.concatenate([x.key for x in pieces]))
        if ctx is not None:
            ctx.check("agg")
        with timing.phase("agg"):
            # Ownership exchange: partitions [0, own) -> C, rest -> G
            # (phj's join-phase split, applied to the reduce).
            num_parts = 1 << total_bits
            own = cp._cut(num_parts, agg_ratio)
            pid = radix_of(rel.key, shift=0, bits=total_bits)
            pid_host = np.asarray(pid)
            outs = []
            for grp, mask in ((cp.c, pid_host < own),
                              (cp.g, pid_host >= own)):
                if (own == 0 and grp is cp.c) or (own == num_parts
                                                  and grp is cp.g):
                    continue
                idx = np.nonzero(mask)[0]
                m = _round_up(max(len(idx), 1), cp.lcm)
                rid = np.full(m, int(INVALID), np.int32)
                key = np.full(m, GROUP_PAD_KEY, np.int32)
                rid[:len(idx)] = np.asarray(rel.rid)[idx]
                key[:len(idx)] = np.asarray(rel.key)[idx]
                if cp.discrete:
                    cp._bus_delay(len(idx) * 8 // 2, timing)
                vals = _gather_values(values, rid)
                f = grp.jit(("gb_agg", m, interpret, wrap32),
                            partial(grouped_agg, num_slots=m,
                                    interpret=interpret, wrap32=wrap32))
                outs.append(f(grp.put_items(Relation(jnp.asarray(rid),
                                                     jnp.asarray(key))),
                              grp.put_items(jnp.asarray(vals))))
            outs = [jax.tree.map(jax.device_get, o) for o in outs]
            result = _collect(outs, wrap32=wrap32)
    else:
        timing.phase_s["partition"] = 0.0
        if ctx is not None:
            ctx.check("agg")
        with timing.phase("agg"):
            n = rel.size
            cut = cp._cut(n, agg_ratio)
            if 0 < cut < n:
                # Separate partial aggregation + host merge: each group
                # builds a partial group list on its row share (both
                # programs in flight at once — async dispatch), merged
                # below.
                if cp.discrete:
                    cp._bus_delay((n - cut) * 8, timing)
                vals = _gather_values(values, np.asarray(rel.rid))
                outs = []
                for grp, lo, hi in ((cp.c, 0, cut), (cp.g, cut, n)):
                    f = grp.jit(("gb_agg", hi - lo, interpret, wrap32),
                                partial(grouped_agg, num_slots=hi - lo,
                                        interpret=interpret, wrap32=wrap32))
                    outs.append(f(grp.put_items(rel.take(lo, hi)),
                                  grp.put_items(jnp.asarray(vals[lo:hi]))))
            else:
                grp = cp.c if cut == n else cp.g
                if cp.discrete and grp is cp.g:
                    cp._bus_delay(n * 8, timing)
                vals = _gather_values(values, np.asarray(rel.rid))
                f = grp.jit(("gb_agg", n, interpret, wrap32),
                            partial(grouped_agg, num_slots=n,
                                    interpret=interpret, wrap32=wrap32))
                outs = [f(grp.put_items(rel),
                          grp.put_items(jnp.asarray(vals)))]
            outs = [jax.tree.map(jax.device_get, o) for o in outs]
            if len(outs) == 2:
                tm = time.perf_counter()
                result = _merge_partials(_collect(outs[:1], wrap32=wrap32),
                                         _collect(outs[1:], wrap32=wrap32))
                timing.merge_s = time.perf_counter() - tm
            else:
                result = _collect(outs, wrap32=wrap32)
    timing.wall_s = timing.phase_s["partition"] + timing.phase_s["agg"]
    timing.notes["num_groups"] = result.num_groups
    return result, timing


# ---------------------------------------------------------------------------
# NumPy oracle (testing/verification only).
# ---------------------------------------------------------------------------

def groupby_ref(keys, values, *, wrap32: bool = False) -> GroupByResult:
    """Exact group-by oracle: key-sorted groups.

    Sums are exact int64 by default; ``wrap32=True`` reproduces the legacy
    int32-wrapping device accumulator exactly.
    """
    keys = np.asarray(keys)
    values = np.asarray(values, dtype=np.int64)
    uk, inv = np.unique(keys, return_inverse=True)
    g = uk.shape[0]
    cnt = np.bincount(inv, minlength=g).astype(np.int32)
    sm = np.zeros(g, np.int64)
    np.add.at(sm, inv, values)
    mn = np.full(g, INT32_MAX, np.int64)
    np.minimum.at(mn, inv, values)
    mx = np.full(g, INT32_MIN, np.int64)
    np.maximum.at(mx, inv, values)
    return GroupByResult(uk.astype(np.int32), cnt,
                         sm.astype(np.int32) if wrap32 else sm,
                         mn.astype(np.int32), mx.astype(np.int32))


CoProcessor.groupby = groupby_coprocessed
