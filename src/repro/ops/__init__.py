"""Co-processed relational operators beyond the inner equi-join.

The paper's fine-grained C/G work splits apply to every partitioned hash
operator; this package generalizes the join-only execution path:

  * ``groupby`` — hash group-by aggregation over the fused radix-partition
    data path (count/sum/min/max/avg), C/G ratio-split like PHJ.
  * ``join_variants`` — semi / anti / left-outer joins over the existing
    probe series via match-flag semantics plus an unmatched-row emission
    pass.

Importing this package attaches ``CoProcessor.groupby`` and
``CoProcessor.probe_table_variant``.
"""
from .groupby import (GroupByResult, grouped_agg, groupby_coprocessed,
                      groupby_ref)
from .join_variants import (JOIN_KINDS, join_variant_oracle,
                            probe_hash_table_variant, probe_table_variant)

__all__ = [n for n in dir() if not n.startswith("_")]
