"""Cost-model-guided radix pass planner (paper §3.1's tuning knob).

The paper tunes the partition phase's two knobs — radix bits per pass and
number of passes — "according to the memory hierarchy".  The seed hard-coded
them at every call site; this module chooses them from the same machinery
the co-processing schemes already use: per-step unit costs (analytic
``DeviceSpec`` seeds or measurements from ``calibrate``) priced through
``SeriesCostModel``.

Model: one pass over ``n`` tuples with a ``b``-bit digit runs the series
(n1, n2, n3) where n1/n2 are fanout-independent but n3's random scatter
degrades once the ``2**b`` open partition streams exceed what the memory
hierarchy tracks (TLB entries / cache sets on the paper's APU, VMEM-resident
offset state on TPU).  We price that as a multiplicative penalty on n3's
random-access unit cost above a calibrated ``capacity_bits`` knee:

    u_n3(b) = u_n3 * (1 + penalty * max(0, b - capacity_bits))

A plan for ``total_bits`` is a schedule ``(b_1, .., b_p)`` with
``sum b_i = total_bits``; the planner enumerates pass counts, splits the
bits as evenly as possible (the paper's equal-width passes), sums per-pass
series costs, and returns the argmin.  With a small fanout (or a flat
hierarchy) one wide pass wins — fewer passes means fewer full relation
rewrites; with a large fanout the penalty pushes the plan to multiple
narrow passes, reproducing the paper's multi-pass regime.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .cost_model import (DeviceSpec, LinkSpec, ZEROCOPY_LINK,
                         series_model_from_costs)

# Average tuples per final partition the planner targets: small enough that
# a partition pair's working set stays cache/VMEM-resident for the join
# phase (the probe kernel's per-partition table), large enough to amortize
# headers.
DEFAULT_PART_TUPLES = 2048
# Fanout knee and per-extra-bit penalty; overridable from calibration.
DEFAULT_CAPACITY_BITS = 8
DEFAULT_FANOUT_PENALTY = 0.6
MAX_TOTAL_BITS = 16


@dataclasses.dataclass(frozen=True)
class PassPlan:
    """A chosen radix partitioning schedule (low digit first)."""

    schedule: tuple[int, ...]
    est_s: float

    @property
    def total_bits(self) -> int:
        return sum(self.schedule)

    @property
    def num_passes(self) -> int:
        return len(self.schedule)

    @property
    def bits_per_pass(self) -> int:
        """Widest pass — the knob the paper sweeps."""
        return max(self.schedule)


def even_schedule(total_bits: int, num_passes: int) -> tuple[int, ...]:
    """``total_bits`` split into ``num_passes`` near-equal digits."""
    base, rem = divmod(total_bits, num_passes)
    return tuple(base + 1 if i < rem else base for i in range(num_passes))


class PassPlanner:
    """Chooses ``bits_per_pass``/``num_passes`` from calibrated unit costs.

    ``u_n1``/``u_n2``/``u_n3`` are seconds/item at fanout 1; they come from
    a ``DeviceSpec`` (analytic) or from ``calibrate_partition_unit_costs``
    (measured).  ``capacity_bits``/``fanout_penalty`` encode the memory
    hierarchy's scatter knee.
    """

    def __init__(self, u_n1: float, u_n2: float, u_n3: float, *,
                 capacity_bits: int = DEFAULT_CAPACITY_BITS,
                 fanout_penalty: float = DEFAULT_FANOUT_PENALTY,
                 part_tuples: int = DEFAULT_PART_TUPLES):
        self.u_n1 = float(u_n1)
        self.u_n2 = float(u_n2)
        self.u_n3 = float(u_n3)
        self.capacity_bits = int(capacity_bits)
        self.fanout_penalty = float(fanout_penalty)
        self.part_tuples = int(part_tuples)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_device_spec(cls, spec: DeviceSpec, **kw) -> "PassPlanner":
        from .phj import PARTITION_COSTS
        return cls(spec.unit_cost(PARTITION_COSTS["n1"]),
                   spec.unit_cost(PARTITION_COSTS["n2"]),
                   spec.unit_cost(PARTITION_COSTS["n3"]), **kw)

    @classmethod
    def from_measurements(cls, unit_costs: dict[str, float], **kw
                          ) -> "PassPlanner":
        """From ``calibrate.measure_unit_costs`` output for one pass."""
        return cls(unit_costs["n1"], unit_costs["n2"], unit_costs["n3"],
                   **kw)

    # -- the model -----------------------------------------------------------
    def scatter_factor(self, bits: int) -> float:
        return 1.0 + self.fanout_penalty * max(0, bits - self.capacity_bits)

    def pass_cost(self, n: int, bits: int) -> float:
        """Modeled seconds for one ``bits``-wide pass over ``n`` tuples,
        priced through the co-processing cost model (single-group run)."""
        return float(self.pass_model(n, bits).estimate_batch(
            np.ones((1, 3)))[0])

    def pass_model(self, n: int, bits: int, *,
                   device_g: DeviceSpec | None = None,
                   link: LinkSpec = ZEROCOPY_LINK):
        """A ``SeriesCostModel`` for one pass (n1, n2, n3) at this fanout.

        The C-group runs at this planner's calibrated unit costs with n3
        scaled by the fanout penalty; schemes can re-optimize ratios over
        it (``optimize_pl``/``optimize_dd``) exactly as for SHJ series.
        """
        from .phj import PARTITION_COSTS, partition_series
        series = partition_series(0)
        fac = self.scatter_factor(bits)
        u_c = {"n1": self.u_n1, "n2": self.u_n2, "n3": self.u_n3 * fac}
        dev_c = DeviceSpec("planner_c", 1.0, 1.0, 1.0)
        dev_g = device_g or dev_c
        if device_g is None:
            u_g = dict(u_c)  # single-group planner: G mirrors C
        else:
            u_g = {nm: device_g.unit_cost(PARTITION_COSTS[nm]) for nm in u_c}
            u_g["n3"] *= fac
        overrides = {nm: (u_c[nm], u_g[nm]) for nm in u_c}
        return series_model_from_costs(series.steps, [n] * 3, dev_c, dev_g,
                                       link, u_overrides=overrides)

    def schedule_cost(self, n: int, schedule: tuple[int, ...]) -> float:
        return sum(self.pass_cost(n, b) for b in schedule)

    # -- planning ------------------------------------------------------------
    def choose_total_bits(self, n: int) -> int:
        """Radix width so the average final partition holds
        ``part_tuples`` tuples (clamped to a sane range)."""
        want = max(1, round(math.log2(max(2, n / self.part_tuples))))
        return min(MAX_TOTAL_BITS, want)

    def plan(self, n: int, total_bits: int | None = None) -> PassPlan:
        """Best schedule for an ``n``-tuple relation (ties -> fewer
        passes: each extra pass is a full relation rewrite)."""
        total_bits = total_bits or self.choose_total_bits(n)
        best: PassPlan | None = None
        for p in range(1, total_bits + 1):
            sched = even_schedule(total_bits, p)
            est = self.schedule_cost(n, sched)
            if best is None or est < best.est_s - 1e-18:
                best = PassPlan(sched, est)
        return best


def calibrate_partition_unit_costs(group, n: int = 65536, *, bits: int = 6,
                                   reps: int = 3) -> dict[str, float]:
    """Measured n1/n2/n3 seconds/item on a device group (paper §4.2)."""
    from .calibrate import measure_unit_costs
    from .phj import partition_series
    from .relation import uniform_relation
    rel = uniform_relation(n, seed=0)
    return measure_unit_costs(partition_series(0),
                              {"shift": 0, "bits": bits},
                              {"rid": rel.rid, "key": rel.key}, group,
                              reps=reps)


def default_planner(device: DeviceSpec | None = None, **kw) -> PassPlanner:
    """Analytic planner for this host (APU CPU seeds when unspecified)."""
    if device is None:
        from .calibrate import APU_CPU
        device = APU_CPU
    return PassPlanner.from_device_spec(device, **kw)
