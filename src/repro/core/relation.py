"""Columnar relations and synthetic data generators.

The paper (§5.1) uses two-column relations ``(rid, key)`` of 4-byte integers:
16M tuples by default, uniform keys, plus two skewed sets (``low-skew``:
s=10% duplicated keys, ``high-skew``: s=25%) and a selectivity knob for the
probe side.  We reproduce those generators exactly so the benchmark harness
can regenerate every figure's dataset.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

TUPLE_BYTES = 8  # (rid, key) 4-byte ints, as in the paper.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Relation:
    """A columnar relation of ``(rid, key)`` pairs.

    ``rid`` and ``key`` are int32 arrays of identical shape ``(n,)``.
    A relation is a pytree so it can flow through jit/shard_map unchanged.
    """

    rid: jax.Array
    key: jax.Array
    # Optional structural fingerprint hint (not a pytree leaf — dropped
    # through jit, which is fine: hints only matter on the host path into
    # the engine's cache keying).  When set, ``JoinQueryService`` keys the
    # BuildTableCache off this string instead of pulling the key column to
    # host for a content hash — the ledger's ``fingerprint`` cause tracks
    # any relation that still arrives without one.
    fp_hint: str | None = None

    @property
    def size(self) -> int:
        return int(self.rid.shape[0])

    @property
    def nbytes(self) -> int:
        return self.size * TUPLE_BYTES

    def take(self, lo: int, hi: int) -> "Relation":
        return Relation(self.rid[lo:hi], self.key[lo:hi])

    def gather(self, idx) -> "Relation":
        """Rows selected by index — the semijoin/materialization primitive.

        A join result's ``(probe_rid, build_rid)`` pairs index back into the
        originating relations when ``rid == arange(n)`` (the generator
        convention); gathering by those indices materializes the matched
        tuples, which is how the query pipeline carries intermediates
        between stages.
        """
        idx = jnp.asarray(idx)
        return Relation(jnp.take(self.rid, idx, axis=0),
                        jnp.take(self.key, idx, axis=0))

    def tree_flatten(self):
        return (self.rid, self.key), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def uniform_relation(n: int, *, key_range: int | None = None,
                     seed: int = 0) -> Relation:
    """Uniform-distributed key values (paper default dataset)."""
    rng = np.random.default_rng(seed)
    key_range = key_range or n
    keys = rng.integers(0, key_range, size=n, dtype=np.int32)
    return Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))


def unique_relation(n: int, *, seed: int = 0) -> Relation:
    """A build relation with unique keys (primary-key side)."""
    rng = np.random.default_rng(seed)
    keys = rng.permutation(n).astype(np.int32)
    return Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))


def skewed_relation(n: int, *, s_percent: int, seed: int = 0) -> Relation:
    """Paper §5.1: ``s%`` of tuples share one duplicate key value.

    ``low-skew``: s=10, ``high-skew``: s=25.
    """
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n, size=n, dtype=np.int32)
    n_dup = (n * s_percent) // 100
    dup_positions = rng.choice(n, size=n_dup, replace=False)
    hot_key = np.int32(rng.integers(0, n))
    keys[dup_positions] = hot_key
    return Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))


def probe_with_selectivity(build: Relation, n: int, *, selectivity: float,
                           seed: int = 0) -> Relation:
    """Probe relation where a ``selectivity`` fraction of tuples match build keys.

    Paper §5.5 varies join selectivity in {12.5%, 50%, 100%}.  Non-matching
    tuples draw keys from a disjoint range.
    """
    rng = np.random.default_rng(seed)
    build_keys = np.asarray(build.key)
    n_match = int(round(n * selectivity))
    match_keys = rng.choice(build_keys, size=n_match, replace=True)
    # Non-matching keys live above every build key.
    miss_lo = int(build_keys.max()) + 1 if build_keys.size else 1
    miss_keys = rng.integers(miss_lo, miss_lo + max(n, 2),
                             size=n - n_match, dtype=np.int64)
    keys = np.concatenate([match_keys.astype(np.int64), miss_keys])
    rng.shuffle(keys)
    return Relation(jnp.arange(n, dtype=jnp.int32),
                    jnp.asarray(keys, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Composable row-index chains (device-resident stage hand-off).
# ---------------------------------------------------------------------------

# Beyond this many links a chain is eagerly flattened to one device index
# vector: evaluation cost stays O(1) gathers per column however deep the
# pipeline gets, at the price of materializing one int32 index array.
CHAIN_DEPTH_CAP = 4


@jax.jit
def _compose_idx(outer: jax.Array, inner: jax.Array) -> jax.Array:
    """One fold step of a chain: ``outer[inner]`` (out-of-range clips)."""
    return jnp.take(outer, inner, axis=0)


@jax.jit
def _gather_col(col: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(col, idx, axis=0)


class IndexChain:
    """A composition of row-index gathers, kept on device.

    ``IndexChain((i0, i1, i2)).gather(col)`` computes
    ``col[i0][i1][i2]`` — equivalently ``col[i0[i1][i2]]`` — without ever
    materializing the intermediate gathers of ``col``: the chain folds its
    *indices* (``flat``) once, then every column of the same source pays a
    single device gather at the final cardinality.  This is how the query
    pipeline hands intermediates between join stages without a host round
    trip: a stage's output is its match-index vector composed onto its
    inputs' chains (``take(take(col, rid1), rid2)``), all jitted.

    Chains deeper than ``cap`` flatten eagerly on device (the depth cap's
    fallback), so arbitrarily deep pipelines stay O(1) gathers per column.
    An empty chain is the identity.
    """

    __slots__ = ("links", "_flat")

    def __init__(self, links=()):
        self.links = tuple(links)
        self._flat = self.links[0] if len(self.links) == 1 else None

    @property
    def depth(self) -> int:
        return len(self.links)

    @property
    def size(self) -> int | None:
        """Rows of the chain's output space (None for the identity)."""
        return int(self.links[-1].shape[0]) if self.links else None

    def extend(self, idx, *, cap: int = CHAIN_DEPTH_CAP) -> "IndexChain":
        """The chain followed by one more gather (flattens past ``cap``)."""
        idx = jnp.asarray(idx)
        child = IndexChain(self.links + (idx,))
        if child.depth > cap:
            return IndexChain((child.flat(),))
        return child

    def flat(self) -> jax.Array:
        """The chain folded to one device index vector (memoized)."""
        if self._flat is None:
            f = self.links[0]
            for link in self.links[1:]:
                f = _compose_idx(f, link)
            self._flat = f
        return self._flat

    def gather(self, col) -> jax.Array:
        """``col`` gathered through the chain — one device gather."""
        if not self.links:
            return jnp.asarray(col)
        return _gather_col(jnp.asarray(col), self.flat())


# ---------------------------------------------------------------------------
# Hash functions.
# ---------------------------------------------------------------------------

MURMUR_C1 = np.uint32(0x85EBCA6B)
MURMUR_C2 = np.uint32(0xC2B2AE35)


@partial(jax.jit, inline=True)
def murmur3_fmix32(x: jax.Array) -> jax.Array:
    """MurmurHash3 32-bit finalizer (avalanche mix).

    The paper uses MurmurHash 2.0 ([4]); we use the Murmur3 finalizer which
    has the same collision quality, vectorizes to pure VPU ALU ops, and is
    the common choice in later hash-join literature.  Computed in uint32.
    """
    h = x.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * MURMUR_C1
    h = h ^ (h >> 13)
    h = h * MURMUR_C2
    h = h ^ (h >> 16)
    return h


def bucket_of(key: jax.Array, num_buckets: int) -> jax.Array:
    """Step b1/p1/n1: compute hash bucket number (num_buckets must be 2**k)."""
    return (murmur3_fmix32(key) & jnp.uint32(num_buckets - 1)).astype(jnp.int32)


def radix_of(key: jax.Array, *, shift: int, bits: int) -> jax.Array:
    """Partition number for one radix pass: low bits of the integer hash.

    Paper §3.1: "radix partitioning is performed by multiple passes based on
    a number of lower bits of the integer hash values."
    """
    h = murmur3_fmix32(key)
    return ((h >> jnp.uint32(shift)) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())
