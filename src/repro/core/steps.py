"""Fine-grained step framework (paper §3.1).

A *step* is a data-parallel map over input items (tuples or larger units)
with optional shared read-only state and optional reduction-style partial
outputs.  A *step series* is a list of steps separated by data dependencies;
series are separated by barriers (build | probe, or per-pass partitioning).

Co-processing schemes (OL / DD / PL, §3.2) assign each step a workload ratio
``r_i``: the first ``round(r_i * x_i)`` items run on the C-group and the rest
on the G-group.  The framework carries per-step cost metadata (paper Table 2)
so the cost model can price any ratio assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

Env = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Per-item cost coefficients for one step (paper §4, Table 2).

    ``ops_per_item``        — ALU ops per item (the paper's #I, profiled).
    ``seq_bytes_per_item``  — streaming bytes per item.
    ``rand_accesses_per_item`` — random-gather/scatter count per item (the
                              dominant memory-stall driver for hash joins).
    ``out_bytes_per_item``  — bytes of intermediate result per item that flow
                              to the next step (prices the PL link term).
    """

    ops_per_item: float
    seq_bytes_per_item: float
    rand_accesses_per_item: float
    out_bytes_per_item: float = 8.0
    workload_dependent: bool = False  # e.g. b3/p3 scale with key-list length


@dataclasses.dataclass(frozen=True)
class Step:
    """One fine-grained step.

    ``apply(shared, items) -> (items_out, shared_out)``:
      * ``items``  — dict of equal-length per-item arrays (ratio-splittable).
      * ``shared`` — dict of broadcast state (hash table, headers, ...).
      * ``items_out``  — per-item outputs (same leading dim as ``items``).
      * ``shared_out`` — partial reductions; merged across groups per
        ``combine[key]`` ("add" for histograms, "concat", or "replace").
    """

    name: str
    apply: Callable[[Env, Env], tuple[Env, Env]]
    cost: StepCost
    combine: dict[str, str] = dataclasses.field(default_factory=dict)
    splittable: bool = True


@dataclasses.dataclass(frozen=True)
class StepSeries:
    """Steps between two barriers; a tuple flows through all of them."""

    name: str
    steps: tuple[Step, ...]

    @property
    def names(self) -> list[str]:
        return [s.name for s in self.steps]


def run_series(series: StepSeries, shared: Env, items: Env) -> tuple[Env, Env]:
    """Single-processor reference execution (no co-processing)."""
    for step in series.steps:
        items_out, shared_out = step.apply(shared, items)
        items = items_out
        shared = {**shared, **shared_out}
    return items, shared


def split_items(items: Env, cut: int) -> tuple[Env, Env]:
    """Split every per-item array at ``cut`` (C-group gets [:cut])."""
    head = {k: v[:cut] for k, v in items.items()}
    tail = {k: v[cut:] for k, v in items.items()}
    return head, tail


def item_count(items: Env) -> int:
    for v in items.values():
        return int(v.shape[0])
    return 0
