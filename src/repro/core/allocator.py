"""Scan-based dynamic memory allocator (paper §3.3 "Memory allocator").

OpenCL 1.2 has no in-kernel malloc, so the paper pre-allocates an array and
serves requests by advancing a pointer with atomics; their optimized version
allocates *blocks* per work group to cut atomic contention.

TPU/Pallas has no global atomics at all, so the allocator is a deterministic
two-level exclusive scan over the request sizes:

  level 1 (per tile)  — requests within a tile (≙ work group) are packed by
                         a local exclusive scan;
  level 2 (global)    — each tile claims one *block-rounded* extent from the
                         global buffer via a scan over per-tile totals.

The block size plays exactly the paper's role: bigger blocks mean fewer
global allocation units (their "atomics") at the price of internal
fragmentation.  ``AllocStats.global_units`` is the contention proxy the
Fig. 11 reproduction sweeps.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AllocStats:
    global_units: int        # number of block claims (≙ global atomics)
    allocated_bytes: int     # buffer actually claimed incl. fragmentation
    requested_bytes: int
    fragmentation: float


@partial(jax.jit, static_argnames=("tile", "block_items"))
def scan_alloc(sizes: jax.Array, *, tile: int = 256, block_items: int = 256):
    """Offsets for per-item allocation requests.

    Returns (offsets, total_items_allocated).  Offsets honor the two-level
    structure: items within a tile are contiguous; tiles start at
    block-rounded boundaries.
    """
    n = sizes.shape[0]
    pad = (-n) % tile
    s = jnp.pad(sizes.astype(jnp.int32), (0, pad)).reshape(-1, tile)
    local = jnp.cumsum(s, axis=1) - s                     # level-1 scan
    tile_need = s.sum(axis=1)
    tile_alloc = ((tile_need + block_items - 1) // block_items) * block_items
    tile_base = jnp.cumsum(tile_alloc) - tile_alloc       # level-2 scan
    offs = (tile_base[:, None] + local).reshape(-1)[:n]
    return offs, tile_alloc.sum()


def alloc_stats(sizes, *, tile: int = 256, block_items: int = 256,
                item_bytes: int = 8) -> AllocStats:
    sizes = jnp.asarray(sizes)
    _, total = scan_alloc(sizes, tile=tile, block_items=block_items)
    n_tiles = -(-sizes.shape[0] // tile)
    req = int(sizes.sum()) * item_bytes
    alloc = int(total) * item_bytes
    return AllocStats(global_units=n_tiles, allocated_bytes=alloc,
                      requested_bytes=req,
                      fragmentation=0.0 if alloc == 0 else 1 - req / alloc)


def basic_alloc_units(sizes) -> int:
    """The paper's basic allocator: one global claim per request."""
    return int((jnp.asarray(sizes) > 0).sum())
