"""Per-step unit-cost calibration (paper §4.2).

The paper instantiates its abstract model by (a) profiling #instructions per
tuple per step and (b) calibrating per-item memory stall costs on each
processor.  We do the same at the granularity the model consumes directly:
*seconds per item per step per group*, measured by running each step's
``apply`` standalone on the target device group and timing it.

Two calibration sources:
  * ``measure_unit_costs``   — real measurements on this host's devices
    (used by every measured benchmark figure).
  * ``APU_*`` / ``TPU_*``    — analytic DeviceSpecs reproducing the paper's
    hardware (Table 1) and the v5e target, used for model-only projections
    (Figs. 4–6 shapes, and the TPU-scale design decisions in
    ``repro.distributed.sharding``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .cost_model import DeviceSpec

# --- Paper Table 1: AMD A8-3870K APU --------------------------------------
# Constants are calibrated to the paper's own per-step measurements
# (§4.2 instantiates the model by profiling; we instantiate it to Fig. 4's
# reported asymmetry: hash steps >15x faster on the GPU, list-walk steps
# ~1x).  CPU: 4 cores @ 3.0 GHz, scalar dependent-chain hashing -> ~12
# Gops/s; ~10 GB/s streaming; ~85M random accesses/s.
APU_CPU = DeviceSpec("apu_cpu", ops_per_s=12e9, seq_bw_bytes_per_s=10e9,
                     rand_access_per_s=85e6)
# GPU: 400 VLIW5 lanes @ 0.6 GHz -> 1.2 Tops/s ALU; GPU-path streaming
# ~40 GB/s (Radeon memory path, read streams); latency hiding lifts random
# throughput modestly above the CPU for massive access streams.
APU_GPU = DeviceSpec("apu_gpu", ops_per_s=1200e9, seq_bw_bytes_per_s=40e9,
                     rand_access_per_s=120e6)

# --- TPU v5e groups (per chip: 197 bf16 TFLOP/s, 819 GB/s HBM) ------------
# Integer/VPU path ~4 Tops/s per chip; random gather effectiveness ~3 G/s
# per chip (32B granules at ~100 GB/s effective random bandwidth).
def tpu_group(name: str, chips: int) -> DeviceSpec:
    return DeviceSpec(name, ops_per_s=4e12 * chips,
                      seq_bw_bytes_per_s=819e9 * chips,
                      rand_access_per_s=3e9 * chips)


TPU_C_GROUP = tpu_group("tpu_c(32 chips)", 32)
TPU_G_GROUP = tpu_group("tpu_g(224 chips)", 224)


def _time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_unit_costs(series, shared, items, group, *, reps: int = 5,
                       workload_scale: dict | None = None) -> dict[str, float]:
    """Measured seconds/item for each step of ``series`` on ``group``.

    Steps run in order (each consumes the previous step's real output, so
    workload-dependent steps like p3 see realistic key-list lengths —
    paper §4.2's "number of instructions per key search * average keys").
    """
    out: dict[str, float] = {}
    import jax.numpy as jnp
    n0 = next(iter(items.values())).shape[0]
    # Pad (by wrapping) to a multiple of the group size so the leading axis
    # shards evenly; unit costs divide by the padded count.
    n = ((n0 + group.size - 1) // group.size) * group.size
    if n != n0:
        items = {k: jnp.concatenate([v, v[: n - n0]]) for k, v in
                 items.items()}
    # Static config scalars stay Python (closure); pytrees go on device.
    shared_d = {k: (v if isinstance(v, (int, float, str, bool))
                    else group.put_shared(v))
                for k, v in shared.items()}
    items_d = group.put_items(items)
    for step in series.steps:
        f = group.jit((series.name, step.name, "cal", group.name,
                       tuple(v.shape for v in items_d.values())),
                      lambda it, _apply=step.apply: _apply(shared_d, it))
        dt = _time_fn(f, items_d, reps=reps)
        out[step.name] = dt / max(n, 1)
        items_d, extra = f(items_d)
        if not items_d:  # terminal step (b4/p4) consumed the items
            break
    return out


def calibrated_overrides(series, shared, items, group_c, group_g,
                         **kw) -> dict[str, tuple[float, float]]:
    """(u_c, u_g) per step name — feed to series_model_from_costs."""
    uc = measure_unit_costs(series, shared, items, group_c, **kw)
    ug = measure_unit_costs(series, shared, items, group_g, **kw)
    return {k: (uc[k], ug[k]) for k in uc if k in ug}


class OnlineUnitCosts:
    """Closes the §4.2 calibration loop *online*, per phase.

    The offline path measures unit costs once (``measure_unit_costs``); the
    engine instead observes every served query's measured phase time against
    the model's estimate and folds the ratio back into a multiplicative
    scale on that phase's unit costs.  Updates are EWMA in log space
    (``scale *= ratio ** alpha``), so one outlier query cannot capsize the
    model, and the scale converges geometrically to the measured/estimated
    ratio as traffic flows.
    """

    def __init__(self, alpha: float = 0.5,
                 scale_bounds: tuple[float, float] = (1e-3, 1e3),
                 version_threshold: float = 1.2):
        self.alpha = float(alpha)
        self.scale_bounds = scale_bounds
        # ``version`` ticks when a scale moves materially (by more than
        # ``version_threshold``) away from its value at the last tick —
        # consumers cache decisions against it (the engine's sticky query
        # plans).  Comparing against the last-tick snapshot (not the
        # previous observation) means gradual drift still invalidates.
        self.version = 0
        self.version_threshold = float(version_threshold)
        self._scale: dict[str, float] = {}
        self._samples: dict[str, int] = {}
        self._scale_at_tick: dict[str, float] = {}

    def scale_for(self, phase: str) -> float:
        return self._scale.get(phase, 1.0)

    def observe(self, phase: str, est_s: float, measured_s: float) -> float:
        """Fold one (estimate, measurement) pair in; returns the new scale.

        ``est_s`` must be the estimate *as priced with the current scale*
        (the engine re-prices each query), so ratio==1 is a fixed point.
        The first observation of a phase corrects the scale fully (the
        analytic seed carries no information worth averaging against);
        later ones smooth with ``alpha``.
        """
        if est_s <= 0.0 or measured_s <= 0.0:
            return self.scale_for(phase)
        if self.alpha == 0.0:
            # Hard freeze: no updates at all — including the first-sample
            # full correction, which would otherwise tick the version and
            # invalidate consumers' cached (sticky) decisions.
            return self.scale_for(phase)
        ratio = min(max(measured_s / est_s, 1e-3), 1e3)
        a = 1.0 if self._samples.get(phase, 0) == 0 else self.alpha
        prev = self.scale_for(phase)
        s = prev * ratio ** a
        lo, hi = self.scale_bounds
        s = min(max(s, lo), hi)
        self._scale[phase] = s
        self._samples[phase] = self._samples.get(phase, 0) + 1
        anchor = self._scale_at_tick.get(phase, 1.0)
        if max(s, anchor) / max(min(s, anchor), 1e-30) > \
                self.version_threshold:
            self.version += 1
            self._scale_at_tick[phase] = s
        return s

    def to_dict(self) -> dict:
        return {p: {"scale": self._scale[p],
                    "samples": self._samples.get(p, 0)}
                for p in sorted(self._scale)}
