"""Per-step unit-cost calibration (paper §4.2).

The paper instantiates its abstract model by (a) profiling #instructions per
tuple per step and (b) calibrating per-item memory stall costs on each
processor.  We do the same at the granularity the model consumes directly:
*seconds per item per step per group*, measured by running each step's
``apply`` standalone on the target device group and timing it.

Two calibration sources:
  * ``measure_unit_costs``   — real measurements on this host's devices
    (used by every measured benchmark figure).
  * ``APU_*`` / ``TPU_*``    — analytic DeviceSpecs reproducing the paper's
    hardware (Table 1) and the v5e target, used for model-only projections
    (Figs. 4–6 shapes, and the TPU-scale design decisions in
    ``repro.distributed.sharding``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .cost_model import DeviceSpec

# --- Paper Table 1: AMD A8-3870K APU --------------------------------------
# Constants are calibrated to the paper's own per-step measurements
# (§4.2 instantiates the model by profiling; we instantiate it to Fig. 4's
# reported asymmetry: hash steps >15x faster on the GPU, list-walk steps
# ~1x).  CPU: 4 cores @ 3.0 GHz, scalar dependent-chain hashing -> ~12
# Gops/s; ~10 GB/s streaming; ~85M random accesses/s.
APU_CPU = DeviceSpec("apu_cpu", ops_per_s=12e9, seq_bw_bytes_per_s=10e9,
                     rand_access_per_s=85e6)
# GPU: 400 VLIW5 lanes @ 0.6 GHz -> 1.2 Tops/s ALU; GPU-path streaming
# ~40 GB/s (Radeon memory path, read streams); latency hiding lifts random
# throughput modestly above the CPU for massive access streams.
APU_GPU = DeviceSpec("apu_gpu", ops_per_s=1200e9, seq_bw_bytes_per_s=40e9,
                     rand_access_per_s=120e6)

# --- TPU v5e groups (per chip: 197 bf16 TFLOP/s, 819 GB/s HBM) ------------
# Integer/VPU path ~4 Tops/s per chip; random gather effectiveness ~3 G/s
# per chip (32B granules at ~100 GB/s effective random bandwidth).
def tpu_group(name: str, chips: int) -> DeviceSpec:
    return DeviceSpec(name, ops_per_s=4e12 * chips,
                      seq_bw_bytes_per_s=819e9 * chips,
                      rand_access_per_s=3e9 * chips)


TPU_C_GROUP = tpu_group("tpu_c(32 chips)", 32)
TPU_G_GROUP = tpu_group("tpu_g(224 chips)", 224)


def _time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_unit_costs(series, shared, items, group, *, reps: int = 5,
                       workload_scale: dict | None = None) -> dict[str, float]:
    """Measured seconds/item for each step of ``series`` on ``group``.

    Steps run in order (each consumes the previous step's real output, so
    workload-dependent steps like p3 see realistic key-list lengths —
    paper §4.2's "number of instructions per key search * average keys").
    """
    out: dict[str, float] = {}
    import jax.numpy as jnp
    n0 = next(iter(items.values())).shape[0]
    # Pad (by wrapping) to a multiple of the group size so the leading axis
    # shards evenly; unit costs divide by the padded count.
    n = ((n0 + group.size - 1) // group.size) * group.size
    if n != n0:
        items = {k: jnp.concatenate([v, v[: n - n0]]) for k, v in
                 items.items()}
    # Static config scalars stay Python (closure); pytrees go on device.
    shared_d = {k: (v if isinstance(v, (int, float, str, bool))
                    else group.put_shared(v))
                for k, v in shared.items()}
    items_d = group.put_items(items)
    for step in series.steps:
        f = group.jit((series.name, step.name, "cal", group.name,
                       tuple(v.shape for v in items_d.values())),
                      lambda it, _apply=step.apply: _apply(shared_d, it))
        dt = _time_fn(f, items_d, reps=reps)
        out[step.name] = dt / max(n, 1)
        items_d, extra = f(items_d)
        if not items_d:  # terminal step (b4/p4) consumed the items
            break
    return out


def calibrated_overrides(series, shared, items, group_c, group_g,
                         **kw) -> dict[str, tuple[float, float]]:
    """(u_c, u_g) per step name — feed to series_model_from_costs."""
    uc = measure_unit_costs(series, shared, items, group_c, **kw)
    ug = measure_unit_costs(series, shared, items, group_g, **kw)
    return {k: (uc[k], ug[k]) for k in uc if k in ug}
