"""Simple hash join (SHJ) as two fine-grained step series (paper Alg. 1).

Build series  b1..b4 and probe series p1..p4, with a barrier in between.
Each step's ``apply`` runs on an arbitrary contiguous slice of items, which
is what lets OL/DD/PL ratio-split them across processor groups.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import hash_table as ht
from .relation import Relation
from .steps import Step, StepCost, StepSeries

# Default per-item cost coefficients (paper Table 2's profiled #I and the
# calibrated memory unit costs; these are analytic seeds — calibrate.py
# replaces them with measured values on the benchmark host).
COSTS = {
    "b1": StepCost(ops_per_item=60, seq_bytes_per_item=12,
                   rand_accesses_per_item=0.0, out_bytes_per_item=12),
    "b2": StepCost(ops_per_item=48, seq_bytes_per_item=24,
                   rand_accesses_per_item=0.0, out_bytes_per_item=12,
                   workload_dependent=True),
    "b3": StepCost(ops_per_item=12, seq_bytes_per_item=20,
                   rand_accesses_per_item=0.5, out_bytes_per_item=16,
                   workload_dependent=True),
    "b4": StepCost(ops_per_item=4, seq_bytes_per_item=8,
                   rand_accesses_per_item=1.0, out_bytes_per_item=8),
    "p1": StepCost(ops_per_item=60, seq_bytes_per_item=12,
                   rand_accesses_per_item=0.0, out_bytes_per_item=12),
    "p2": StepCost(ops_per_item=4, seq_bytes_per_item=8,
                   rand_accesses_per_item=1.0, out_bytes_per_item=20),
    "p3": StepCost(ops_per_item=24, seq_bytes_per_item=4,
                   rand_accesses_per_item=3.0, out_bytes_per_item=12,
                   workload_dependent=True),
    "p4": StepCost(ops_per_item=8, seq_bytes_per_item=16,
                   rand_accesses_per_item=2.0, out_bytes_per_item=8),
}


# --------------------------------------------------------------------------
# Build steps.
# --------------------------------------------------------------------------

def _b1(shared, items):
    bkt = ht.build_b1(items["key"], shared["num_buckets"])
    return {**items, "bkt": bkt}, {}


def _b2(shared, items):
    """Claim hash-table slots: stable (bucket, key) order over the slice,
    plus the bucket histogram partial (combined by "add" across groups)."""
    order = ht.build_b2_order(items["bkt"], items["key"])
    out = {k: v[order] for k, v in items.items()}
    hist = jax.ops.segment_sum(jnp.ones_like(items["bkt"]), items["bkt"],
                               num_segments=shared["num_buckets"])
    return out, {"hist": hist}


def _b3(shared, items):
    first = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (items["bkt"][1:] != items["bkt"][:-1])
        | (items["key"][1:] != items["key"][:-1]),
    ]) if items["key"].shape[0] > 0 else jnp.zeros((0,), jnp.bool_)
    return {**items, "first": first}, {}


def _b4(shared, items):
    """Finalize the slice's partial CSR table (b4: insert rids)."""
    n = items["key"].shape[0]
    nb = shared["num_buckets"]
    if n == 0:
        empty = ht.build_hash_table(
            Relation(jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32)), nb)
        return {}, {"partial_tables": [empty]}
    (ukeys, krs, krc, bks, bkc, num_keys) = ht.build_b3_keylists(
        items["bkt"], items["key"], nb)
    table = ht.HashTable(bks, bkc, ukeys, krs, krc, items["rid"],
                         items["key"], num_keys.astype(jnp.int32))
    return {}, {"partial_tables": [table]}


# --------------------------------------------------------------------------
# Probe steps.
# --------------------------------------------------------------------------

def _p1(shared, items):
    bkt = ht.probe_p1(items["key"], shared["table"].num_buckets)
    return {**items, "bkt": bkt}, {}


def _p2(shared, items):
    kstart, kcount = ht.probe_p2(shared["table"], items["bkt"])
    return {**items, "kstart": kstart, "kcount": kcount}, {}


def _p3(shared, items):
    entry, nmatch = ht.probe_p3(shared["table"], items["key"],
                                items["kstart"], items["kcount"])
    return {**items, "entry": entry, "nmatch": nmatch}, {}


def _p4(shared, items):
    res = ht.probe_p4(shared["table"], items["rid"], items["entry"],
                      items["nmatch"], shared["max_out"])
    return {}, {"results": [res]}


BUILD_SERIES = StepSeries("shj_build", (
    Step("b1", _b1, COSTS["b1"]),
    Step("b2", _b2, COSTS["b2"], combine={"hist": "add"}),
    Step("b3", _b3, COSTS["b3"]),
    Step("b4", _b4, COSTS["b4"], combine={"partial_tables": "list"}),
))

PROBE_SERIES = StepSeries("shj_probe", (
    Step("p1", _p1, COSTS["p1"]),
    Step("p2", _p2, COSTS["p2"]),
    Step("p3", _p3, COSTS["p3"]),
    Step("p4", _p4, COSTS["p4"], combine={"results": "list"}),
))


# --------------------------------------------------------------------------
# Single-device reference SHJ (the oracle path used by tests/benches).
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_buckets", "max_out"))
def shj_join(build_rel: Relation, probe_rel: Relation, *, num_buckets: int,
             max_out: int) -> ht.JoinResult:
    table = ht.build_hash_table(build_rel, num_buckets)
    return ht.probe_hash_table(probe_rel, table, max_out)


def concat_results(parts: list[ht.JoinResult], max_out: int) -> ht.JoinResult:
    """Combine per-group probe outputs (order: C-group first)."""
    probe = jnp.concatenate([p.probe_rid[: p.probe_rid.shape[0]] for p in parts])
    build = jnp.concatenate([p.build_rid for p in parts])
    count = sum(p.count for p in parts)
    # Compact valid pairs to the front.
    valid = probe != ht.INVALID
    order = jnp.argsort(~valid, stable=True)
    probe, build = probe[order][:max_out], build[order][:max_out]
    return ht.JoinResult(probe, build, jnp.minimum(count, max_out))
