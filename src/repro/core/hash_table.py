"""Dense bucketed hash table — the paper's two-level hash table, TPU-native.

Paper §3.1: "A hash table consists of an array of bucket headers ... the
pointer to a key list.  The key list contains all the unique keys with the
same hash value, each of which links a *rid* list storing the IDs for all
tuples with the same key."

Pointer chasing is hostile to TPU vector units, so we materialize the exact
same three-level structure (bucket header -> key list -> rid list) as dense
CSR-style arrays, built with sorts + scans instead of latched inserts (see
DESIGN.md §2: the scan is the TPU-idiomatic replacement for the paper's
atomic-based allocator).  The logical structure, and the per-step access
pattern of build (b1..b4) and probe (p1..p4), are preserved one-to-one:

  build   b1: compute hash bucket number          (VPU ALU map)
          b2: visit the hash bucket header         (histogram + scan = "allocator")
          b3: visit key lists / create key headers (stable sort + boundary flags)
          b4: insert record id into the rid list   (scatter in sorted order)
  probe   p1: compute hash bucket number          (VPU ALU map)
          p2: visit the hash bucket header         (1 random gather / tuple)
          p3: visit the hash key lists             (log2(bucket keys) gathers / tuple)
          p4: visit matching build tuple, emit     (expand via scan + gathers)

Every function is shape-static and jit-compatible; data-dependent sizes
(number of unique keys, number of matches) are carried as scalars next to
padded arrays.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .relation import Relation, bucket_of, next_pow2

INVALID = jnp.int32(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HashTable:
    """CSR form of the paper's bucket-header -> key-list -> rid-list table."""

    # -- bucket headers (paper: "array of bucket headers") ------------------
    bucket_key_start: jax.Array  # (B,) index of the bucket's first key entry
    bucket_key_count: jax.Array  # (B,) number of unique keys in the bucket
    # -- key list (paper: "all the unique keys with the same hash value") ---
    ukeys: jax.Array             # (n,) unique keys, sorted by (bucket, key); padded
    key_rid_start: jax.Array     # (n,) index of the key's first rid
    key_rid_count: jax.Array     # (n,) number of rids under the key
    # -- rid list ------------------------------------------------------------
    rids: jax.Array              # (n,) rids, grouped by (bucket, key)
    skeys: jax.Array             # (n,) key value per rid slot (sorted order)
    num_keys: jax.Array          # scalar int32: number of valid key entries

    @property
    def num_buckets(self) -> int:
        return int(self.bucket_key_start.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.rids.shape[0])

    def tree_flatten(self):
        fields = (self.bucket_key_start, self.bucket_key_count, self.ukeys,
                  self.key_rid_start, self.key_rid_count, self.rids,
                  self.skeys, self.num_keys)
        return fields, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JoinResult:
    """Matching ``(probe_rid, build_rid)`` pairs, padded with -1."""

    probe_rid: jax.Array
    build_rid: jax.Array
    count: jax.Array  # scalar int32: number of valid pairs

    def tree_flatten(self):
        return (self.probe_rid, self.build_rid, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def valid_pairs(self) -> np.ndarray:
        """Host-side (count, 2) array of valid pairs, sorted — for testing."""
        c = int(self.count)
        pairs = np.stack([np.asarray(self.probe_rid[:c]),
                          np.asarray(self.build_rid[:c])], axis=1)
        return pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]


def default_num_buckets(n: int, *, avg_bucket: int = 4) -> int:
    """Paper-style sizing: a few tuples per bucket on average, power of two."""
    return max(4, next_pow2(max(1, n // avg_bucket)))


# ---------------------------------------------------------------------------
# Build phase, as the fine-grained steps b1..b4.
# ---------------------------------------------------------------------------

def build_b1(key: jax.Array, num_buckets: int) -> jax.Array:
    """(b1) compute hash bucket number."""
    return bucket_of(key, num_buckets)


def build_b2_order(bkt: jax.Array, key: jax.Array) -> jax.Array:
    """(b2) bucket-header placement: stable (bucket, key) order.

    Two stable argsorts give lexicographic (bucket, key) order — this is the
    scan-based equivalent of walking each tuple to its bucket header and
    claiming a slot with the paper's block allocator.
    """
    order = jnp.argsort(key.astype(jnp.uint32), stable=True)
    order = order[jnp.argsort(bkt[order], stable=True)]
    return order


def build_b3_keylists(sbkt: jax.Array, skey: jax.Array, num_buckets: int):
    """(b3) create key headers: boundary flags over the sorted tuples."""
    first = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (sbkt[1:] != sbkt[:-1]) | (skey[1:] != skey[:-1]),
    ])
    key_id = jnp.cumsum(first.astype(jnp.int32)) - 1          # per-tuple key entry
    num_keys = first.astype(jnp.int32).sum()
    n = skey.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    ukeys = jnp.full((n,), INVALID).at[key_id].set(skey)
    key_rid_start = jnp.full((n,), n, jnp.int32).at[key_id].min(iota)
    key_rid_count = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), key_id,
                                        num_segments=n)
    # Bucket headers count unique keys (= first flags) per bucket.
    bucket_key_count = jax.ops.segment_sum(first.astype(jnp.int32), sbkt,
                                           num_segments=num_buckets)
    bucket_key_start = jnp.cumsum(bucket_key_count) - bucket_key_count
    return (ukeys, key_rid_start, key_rid_count, bucket_key_start,
            bucket_key_count, num_keys)


def build_b4_ridlists(rid: jax.Array, order: jax.Array) -> jax.Array:
    """(b4) insert record ids into the rid lists (gather in sorted order)."""
    return rid[order]


@partial(jax.jit, static_argnames=("num_buckets",))
def build_hash_table(rel: Relation, num_buckets: int) -> HashTable:
    """Full build phase: b1 -> b2 -> b3 -> b4."""
    bkt = build_b1(rel.key, num_buckets)
    order = build_b2_order(bkt, rel.key)
    sbkt, skey = bkt[order], rel.key[order]
    (ukeys, key_rid_start, key_rid_count, bucket_key_start, bucket_key_count,
     num_keys) = build_b3_keylists(sbkt, skey, num_buckets)
    rids = build_b4_ridlists(rel.rid, order)
    return HashTable(bucket_key_start, bucket_key_count, ukeys, key_rid_start,
                     key_rid_count, rids, skey, num_keys.astype(jnp.int32))


def merge_hash_tables(parts: list[HashTable], num_buckets: int) -> HashTable:
    """Merge partial hash tables (the paper's DD merge step, Fig. 3).

    Separate-table co-processing builds one partial table per processor
    group; merging concatenates the underlying sorted tuple streams and
    rebuilds the CSR structure (a k-way merge; implemented as concat +
    rebuild, which XLA lowers to a single sort — the measured merge cost the
    paper reports as 14–18% of DD time on discrete architectures).
    """
    rid = jnp.concatenate([p.rids for p in parts])
    key = jnp.concatenate([p.skeys for p in parts])
    return build_hash_table(Relation(rid, key), num_buckets)


# ---------------------------------------------------------------------------
# Probe phase, as the fine-grained steps p1..p4.
# ---------------------------------------------------------------------------

def probe_p1(key: jax.Array, num_buckets: int) -> jax.Array:
    """(p1) compute hash bucket number."""
    return bucket_of(key, num_buckets)


def probe_p2(table: HashTable, bkt: jax.Array):
    """(p2) visit the hash bucket header: one random gather per tuple."""
    return table.bucket_key_start[bkt], table.bucket_key_count[bkt]


def probe_p3(table: HashTable, key: jax.Array, kstart: jax.Array,
             kcount: jax.Array):
    """(p3) search the bucket's key list: bounded binary search.

    The key list of a bucket is a sorted contiguous segment of ``ukeys``,
    so the paper's list walk becomes a binary search with log2(|keys in
    bucket|) random gathers per tuple (vs. the list walk's O(|keys|)).
    Returns the matching key-entry index (or -1) and its rid count.
    """
    n = table.ukeys.shape[0]
    iters = max(1, int(n).bit_length() + 1)
    lo = kstart
    hi = kstart + kcount
    target = key.astype(jnp.uint32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        mid_c = jnp.clip(mid, 0, n - 1)
        mid_key = table.ukeys[mid_c].astype(jnp.uint32)
        go_right = (mid_key < target) & (lo < hi)
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right | (lo >= hi), hi, mid)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    pos = jnp.clip(lo, 0, n - 1)
    found = (lo < kstart + kcount) & (table.ukeys[pos] == key)
    entry = jnp.where(found, pos, -1)
    nmatch = jnp.where(found, table.key_rid_count[pos], 0)
    return entry, nmatch


def probe_p4(table: HashTable, probe_rid: jax.Array, entry: jax.Array,
             nmatch: jax.Array, max_out: int) -> JoinResult:
    """(p4) visit matching build tuples and produce output pairs.

    Variable-fanout output is materialized with the scan allocator:
    per-tuple match counts -> exclusive scan -> gather-based expansion.
    ``max_out`` is the static output capacity (the paper's pre-allocated
    result buffer); overflow is truncated and reported via ``count``.
    """
    n = probe_rid.shape[0]
    offs = jnp.cumsum(nmatch)
    total = offs[-1] if n > 0 else jnp.int32(0)
    starts = offs - nmatch
    out_idx = jnp.arange(max_out, dtype=jnp.int32)
    src = jnp.searchsorted(offs, out_idx, side="right").astype(jnp.int32)
    valid = out_idx < jnp.minimum(total, max_out)
    src_c = jnp.clip(src, 0, n - 1)
    j = out_idx - starts[src_c]
    cap = table.rids.shape[0]
    bpos = jnp.clip(table.key_rid_start[jnp.clip(entry[src_c], 0, cap - 1)] + j,
                    0, cap - 1)
    out_build = jnp.where(valid, table.rids[bpos], INVALID)
    out_probe = jnp.where(valid, probe_rid[src_c], INVALID)
    return JoinResult(out_probe, out_build,
                      jnp.minimum(total, max_out).astype(jnp.int32))


@partial(jax.jit, static_argnames=("max_out",))
def probe_hash_table(rel: Relation, table: HashTable, max_out: int) -> JoinResult:
    """Full probe phase: p1 -> p2 -> p3 -> p4."""
    bkt = probe_p1(rel.key, table.num_buckets)
    kstart, kcount = probe_p2(table, bkt)
    entry, nmatch = probe_p3(table, rel.key, kstart, kcount)
    return probe_p4(table, rel.rid, entry, nmatch, max_out)


# ---------------------------------------------------------------------------
# Oracles (testing only; numpy, not jitted).
# ---------------------------------------------------------------------------

def join_oracle(build: Relation, probe: Relation) -> np.ndarray:
    """Sort-merge oracle: all matching (probe_rid, build_rid) pairs, sorted."""
    bk = np.asarray(build.key)
    br = np.asarray(build.rid)
    pk = np.asarray(probe.key)
    pr = np.asarray(probe.rid)
    order_b = np.argsort(bk, kind="stable")
    bk, br = bk[order_b], br[order_b]
    lo = np.searchsorted(bk, pk, side="left")
    hi = np.searchsorted(bk, pk, side="right")
    counts = hi - lo
    out = np.empty((counts.sum(), 2), dtype=np.int64)
    w = 0
    for i in np.nonzero(counts)[0]:
        c = counts[i]
        out[w:w + c, 0] = pr[i]
        out[w:w + c, 1] = br[lo[i]:hi[i]]
        w += c
    return out[np.lexsort((out[:, 1], out[:, 0]))]
