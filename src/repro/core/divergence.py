"""Workload-divergence grouping (paper §3.3).

All lanes of a TPU VPU tile (≙ OpenCL wavefront) retire together, so a tile
whose items carry very different work (skewed key lists) runs at the worst
lane's speed.  The paper groups input items by workload so each work group
has uniform work; we do the same: sort probe tuples by their bucket's key
count (known after p2) before running p3/p4, and restore the original order
afterwards.  The number of groups (= sort granularity) trades grouping
overhead vs. divergence reduction — we expose it as quantized sort keys.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("num_groups",))
def divergence_order(workload: jax.Array, num_groups: int = 64) -> jax.Array:
    """Permutation grouping items of similar workload (stable within group).

    ``workload`` — per-item work estimate (e.g. kcount from p2).
    ``num_groups`` — quantization of the sort key (paper: "the number of
    groups is tuned for the tradeoff between the grouping overhead and the
    gain of reduced workload divergence").
    """
    if num_groups <= 1:
        return jnp.arange(workload.shape[0], dtype=jnp.int32)
    wmax = jnp.maximum(workload.max(), 1)
    g = jnp.minimum((workload * num_groups) // (wmax + 1),
                    num_groups - 1).astype(jnp.int32)
    return jnp.argsort(g, stable=True).astype(jnp.int32)


def inverse_permutation(order: jax.Array) -> jax.Array:
    inv = jnp.zeros_like(order)
    return inv.at[order].set(jnp.arange(order.shape[0], dtype=order.dtype))


def tile_divergence_waste(workload: jax.Array, tile: int = 256) -> jax.Array:
    """Fraction of lane-cycles wasted to divergence at a given tile size.

    waste = 1 - sum(w) / sum(tile * max_per_tile).  The benchmark for the
    paper's 5–10% claim evaluates this metric before/after grouping.
    """
    n = workload.shape[0]
    pad = (-n) % tile
    w = jnp.pad(workload.astype(jnp.float32), (0, pad))
    w = w.reshape(-1, tile)
    per_tile_cost = w.max(axis=1) * tile
    total_cost = jnp.maximum(per_tile_cost.sum(), 1e-9)
    return 1.0 - w.sum() / total_cost
