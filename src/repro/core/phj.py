"""Partitioned (radix) hash join — paper Algorithm 2.

PHJ = g passes of radix partitioning on R and S (steps n1..n3 per pass),
then SHJ per partition pair.  Because both relations are clustered by the
same radix bits, the per-partition SHJ is realized as one global CSR hash
join whose bucket id is ``(radix_value << shj_bits) | shj_hash_bits`` —
buckets never span partitions, so probes stay within their partition pair
(identical join semantics, with the paper's locality benefit: after
partitioning, each bucket's working set is contiguous).

Two step granularities are provided (paper §3.3 "Step definitions"):
  * fine-grained  — per-tuple steps (n1..n3, b1..b4, p1..p4) — PHJ-PL;
  * coarse-grained — one step whose input item is a whole partition pair,
    each joined with its own private table — PHJ-PL' (Table 3 baseline).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import hash_table as ht
from .partition import Partitions, partition_n1, partition_n2, partition_n3, \
    radix_partition_scheduled
from .relation import Relation, radix_of
from .steps import Step, StepCost, StepSeries

# Buckets sized for this many tuples each (paper §5.2's bucket-load knob).
DEFAULT_AVG_BUCKET = 4

PARTITION_COSTS = {
    "n1": StepCost(ops_per_item=60, seq_bytes_per_item=12,
                   rand_accesses_per_item=0.0, out_bytes_per_item=12),
    "n2": StepCost(ops_per_item=4, seq_bytes_per_item=4,
                   rand_accesses_per_item=0.5, out_bytes_per_item=12),
    "n3": StepCost(ops_per_item=40, seq_bytes_per_item=16,
                   rand_accesses_per_item=1.0, out_bytes_per_item=8,
                   workload_dependent=True),
}


def _n1(shared, items):
    pid = partition_n1(items["key"], shift=shared["shift"],
                       bits=shared["bits"])
    return {**items, "pid": pid}, {}


def _n2(shared, items):
    starts, counts = partition_n2(items["pid"], 1 << shared["bits"])
    return items, {"part_hist": counts}


def _n3(shared, items):
    rel = partition_n3(Relation(items["rid"], items["key"]), items["pid"])
    return {"rid": rel.rid, "key": rel.key}, {}


def partition_series(pass_idx: int) -> StepSeries:
    return StepSeries(f"phj_partition_pass{pass_idx}", (
        Step("n1", _n1, PARTITION_COSTS["n1"]),
        Step("n2", _n2, PARTITION_COSTS["n2"], combine={"part_hist": "add"}),
        Step("n3", _n3, PARTITION_COSTS["n3"]),
    ))


def phj_bucket_count(n: int, total_radix_bits: int, *,
                     avg_bucket: int = DEFAULT_AVG_BUCKET):
    """Buckets per partition (power of two)."""
    from .relation import next_pow2
    per_part = max(1, n >> total_radix_bits)
    return max(1, next_pow2(max(1, per_part // avg_bucket)))


def default_shj_bits(n: int, total_radix_bits: int, *,
                     avg_bucket: int = DEFAULT_AVG_BUCKET) -> int:
    """Sub-bucket bits per partition, from the bucket-count heuristic.

    The engine's planner derives ``shj_bits`` for planner-chosen schedules
    from this instead of a hard-coded constant."""
    return max(0, phj_bucket_count(n, total_radix_bits,
                                   avg_bucket=avg_bucket).bit_length() - 1)


def resolve_schedule(n: int, *, bits_per_pass: int | None = None,
                     num_passes: int | None = None,
                     schedule: tuple[int, ...] | None = None,
                     planner=None) -> tuple[int, ...]:
    """The ONE place pass knobs are decided (no hard-coded constants).

    Priority: explicit ``schedule`` > explicit ``bits_per_pass`` x
    ``num_passes`` > the cost-model-guided ``PassPlanner`` for ``n``.
    """
    if schedule is not None:
        sched = tuple(int(b) for b in schedule)
    elif bits_per_pass is not None:
        sched = (int(bits_per_pass),) * int(num_passes or 1)
    else:
        if planner is None:
            from .pass_planner import default_planner
            planner = default_planner()
        if num_passes is not None:
            # Honor the requested pass count: split the planner's total
            # radix width into that many near-even digits.
            from .pass_planner import even_schedule
            total = max(int(num_passes), planner.choose_total_bits(n))
            sched = even_schedule(total, int(num_passes))
        else:
            sched = planner.plan(n).schedule
    if not sched or any(b < 1 for b in sched):
        raise ValueError(f"each pass needs >= 1 radix bit: {sched}")
    return sched


def schedule_prefixes(schedule: tuple[int, ...]):
    """Proper prefixes of a pass schedule, longest first.

    The engine's checkpoint/resume path stores a preempted query's
    partially-partitioned layout under its completed-pass prefix key and
    probes these prefixes (longest first — most work salvaged) when the
    full-schedule layout misses.
    """
    sched = tuple(int(b) for b in schedule)
    return [sched[:k] for k in range(len(sched) - 1, 0, -1)]


def phj_join(build_rel: Relation, probe_rel: Relation, *,
             bits_per_pass: int | None = None, num_passes: int | None = None,
             schedule: tuple[int, ...] | None = None, planner=None,
             buckets_per_part: int | None = None,
             max_out: int) -> ht.JoinResult:
    """Full PHJ: partition R and S, then SHJ per partition pair (fused).

    Pass knobs may be given explicitly or left to the planner (the paper's
    "tuned according to the memory hierarchy"); ``buckets_per_part``
    defaults from the planned radix width."""
    sched = resolve_schedule(build_rel.size, bits_per_pass=bits_per_pass,
                             num_passes=num_passes, schedule=schedule,
                             planner=planner)
    if buckets_per_part is None:
        buckets_per_part = phj_bucket_count(build_rel.size, sum(sched))
    return _phj_join_scheduled(build_rel, probe_rel, schedule=sched,
                               buckets_per_part=buckets_per_part,
                               max_out=max_out)


@partial(jax.jit, static_argnames=("schedule", "max_out", "buckets_per_part"))
def _phj_join_scheduled(build_rel: Relation, probe_rel: Relation, *,
                        schedule: tuple[int, ...], buckets_per_part: int,
                        max_out: int) -> ht.JoinResult:
    total_bits = sum(schedule)
    pr = radix_partition_scheduled(build_rel, schedule=schedule)
    ps = radix_partition_scheduled(probe_rel, schedule=schedule)
    # Partition-aligned bucket ids: buckets never cross partitions.
    shj_bits = max(0, buckets_per_part.bit_length() - 1)
    num_buckets = 1 << (total_bits + shj_bits)

    def bucket_fn(key):
        part = radix_of(key, shift=0, bits=total_bits).astype(jnp.uint32)
        sub = (jnp.uint32(0) if shj_bits == 0 else
               (radix_of(key, shift=total_bits, bits=shj_bits).astype(jnp.uint32)))
        return ((part << jnp.uint32(shj_bits)) | sub).astype(jnp.int32)

    # Build on partitioned R: tuples are already clustered, so the (bucket,
    # key) sort inside build is near-sorted (the paper's locality win).
    bkt = bucket_fn(pr.rel.key)
    order = ht.build_b2_order(bkt, pr.rel.key)
    sbkt, skey = bkt[order], pr.rel.key[order]
    (ukeys, krs, krc, bks, bkc, num_keys) = ht.build_b3_keylists(
        sbkt, skey, num_buckets)
    table = ht.HashTable(bks, bkc, ukeys, krs, krc, pr.rel.rid[order], skey,
                         num_keys.astype(jnp.int32))

    pbkt = bucket_fn(ps.rel.key)
    kstart, kcount = ht.probe_p2(table, pbkt)
    entry, nmatch = ht.probe_p3(table, ps.rel.key, kstart, kcount)
    return ht.probe_p4(table, ps.rel.rid, entry, nmatch, max_out)


# --------------------------------------------------------------------------
# Coarse-grained step definition (paper §3.3, PHJ-PL' in Table 3).
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_parts", "part_cap", "buckets_per_part",
                                   "max_out_per_part"))
def phj_coarse_join(pr: Partitions, ps: Partitions, *, num_parts: int,
                    part_cap: int, buckets_per_part: int,
                    max_out_per_part: int) -> ht.JoinResult:
    """Join each partition pair as ONE item with its own private table.

    Partitions are padded to ``part_cap`` and vmapped: one work item per
    partition pair, separate hash tables (the paper notes this "potentially
    loses the opportunities of cache reuse" — Table 3 quantifies it, our
    benchmark reproduces the comparison).
    """

    def gather_part(parts: Partitions, i):
        idx = parts.part_start[i] + jnp.arange(part_cap, dtype=jnp.int32)
        valid = jnp.arange(part_cap, dtype=jnp.int32) < parts.part_count[i]
        idx = jnp.clip(idx, 0, parts.rel.size - 1)
        key = jnp.where(valid, parts.rel.key[idx], -1)
        rid = jnp.where(valid, parts.rel.rid[idx], ht.INVALID)
        return Relation(rid, key), valid

    def join_one(i):
        r_i, r_valid = gather_part(pr, i)
        s_i, s_valid = gather_part(ps, i)
        # Mask padding: send invalid build keys to a sentinel that matches
        # nothing, and zero out invalid probe rows afterwards.
        rkey = jnp.where(r_valid, r_i.key, -2)
        skey = jnp.where(s_valid, s_i.key, -3)
        table = ht.build_hash_table(Relation(r_i.rid, rkey), buckets_per_part)
        res = ht.probe_hash_table(Relation(s_i.rid, skey), table,
                                  max_out_per_part)
        return res

    results = jax.vmap(join_one)(jnp.arange(num_parts, dtype=jnp.int32))
    probe = results.probe_rid.reshape(-1)
    build = results.build_rid.reshape(-1)
    count = results.count.sum()
    valid = probe != ht.INVALID
    order = jnp.argsort(~valid, stable=True)
    return ht.JoinResult(probe[order], build[order], count)
