"""The paper's contribution: fine-grained hash-join co-processing.

Public surface:
  * relations + generators            — ``repro.core.relation``
  * dense bucketed hash table         — ``repro.core.hash_table``
  * fine-grained steps (SHJ/PHJ)      — ``repro.core.{steps,shj,phj}``
  * radix partitioning / MoE dispatch — ``repro.core.partition``
  * OL/DD/PL two-group executor       — ``repro.core.coprocess``
  * unified cost model (Eqs. 1-5)     — ``repro.core.cost_model``
  * calibration, skew grouping, scan allocator
"""
from .relation import (Relation, uniform_relation, unique_relation,
                       skewed_relation, probe_with_selectivity,
                       murmur3_fmix32, bucket_of, radix_of)
from .hash_table import (HashTable, JoinResult, build_hash_table,
                         probe_hash_table, merge_hash_tables, join_oracle,
                         default_num_buckets)
from .shj import shj_join, BUILD_SERIES, PROBE_SERIES
from .phj import (phj_join, phj_coarse_join, partition_series,
                  resolve_schedule, default_shj_bits, phj_bucket_count)
from .partition import (radix_partition, radix_partition_scheduled,
                        radix_partition_unfused, Partitions)
from .pass_planner import (PassPlan, PassPlanner, default_planner,
                           even_schedule, calibrate_partition_unit_costs)
from .cost_model import (SeriesCostModel, series_model_from_costs, LinkSpec,
                         DeviceSpec, PCIE_LINK, ICI_LINK, DCN_LINK,
                         ZEROCOPY_LINK)
from .coprocess import CoProcessor, Timing, DeviceGroup
from .calibrate import OnlineUnitCosts, calibrated_overrides
from .allocator import scan_alloc, alloc_stats, basic_alloc_units
from .divergence import (divergence_order, inverse_permutation,
                         tile_divergence_waste)

__all__ = [n for n in dir() if not n.startswith("_")]
