"""Two-group co-processing executor: OL / DD / PL on real devices (§3.2).

The paper's coupled CPU+GPU is re-created as two *device groups* (DESIGN.md
§2): a small C-group and a large G-group.  On this container the groups are
host CPU devices (spawned with --xla_force_host_platform_device_count in the
benchmark harness); on a pod they are chip groups of one mesh.  "Coupled"
executions exchange intermediates directly (zero-copy / ICI); "discrete"
executions add the paper's emulated bus delay (§5.1: latency + size/bw).

Schemes:
  * CPU_ONLY / GPU_ONLY — whole series on one group.
  * OL  — per-step 0/1 assignment (paper: degenerates to GPU-only when the
          GPU wins every step — our Fig. 4 analogue decides).
  * DD  — one ratio for all steps of a phase; separate tables need a merge.
  * PL  — per-step ratios with boundary exchanges (fine-grained scheme).
  * BASIC_UNIT — appendix baseline: dynamic chunk scheduling.

Build-table modes (§3.3):
  * separate — each group builds a partial table on its tuple share; an
    explicit merge combines them (the paper's Fig. 3 merge overhead).
  * shared   — one logical table, bucket-range ownership split between the
    groups; tuples are exchanged to their owning group (the distributed
    analogue of writing one table in shared memory; no merge step).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import hash_table as ht
from .cost_model import LinkSpec, ZEROCOPY_LINK
from .relation import Relation, bucket_of
from .shj import concat_results


def _round_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


# Fault-injection hook: ``repro.engine.faults.install`` plants its
# ``maybe_fault`` here (set back to None on uninstall), so the hot path
# costs one load and one branch when no injector is active, and this
# module never imports the engine package (which imports it back).
_FAULT_HOOK = None


def _maybe_fault(site: str) -> None:
    hook = _FAULT_HOOK
    if hook is not None:
        hook(site)


@dataclasses.dataclass
class Timing:
    wall_s: float = 0.0
    phase_s: dict = dataclasses.field(default_factory=dict)
    transfer_bytes: int = 0
    transfer_s: float = 0.0
    merge_s: float = 0.0
    notes: dict = dataclasses.field(default_factory=dict)
    # Observability hook: phases timed through ``phase()`` also emit
    # tracer spans (nested under whatever query span the calling thread
    # has open).  ``None``/disabled tracer keeps the old perf_counter
    # behavior with no extra work.  Excluded from equality/repr — two
    # timings are the same measurement regardless of who observed them.
    tracer: object = dataclasses.field(default=None, repr=False,
                                       compare=False)

    @contextlib.contextmanager
    def phase(self, name: str, **attrs):
        """Time a phase into ``phase_s[name]`` (and span it when traced).

        Phase seconds always come from ``time.perf_counter`` — the
        tracer's (possibly fake) clock only stamps the span — so cost-
        model feedback stays on real time even under test clocks.
        """
        tracer = self.tracer
        traced = tracer is not None and getattr(tracer, "enabled", False)
        if traced:
            ctx = tracer.span(name, **attrs)
        else:
            ctx = contextlib.nullcontext()
        with ctx:
            t0 = time.perf_counter()
            try:
                yield self
            finally:
                self.phase_s[name] = time.perf_counter() - t0

    def to_dict(self) -> dict:
        """JSON-serializable view (machine-readable bench artifacts)."""
        return {
            "wall_s": float(self.wall_s),
            "phase_s": {k: float(v) for k, v in self.phase_s.items()},
            "transfer_bytes": int(self.transfer_bytes),
            "transfer_s": float(self.transfer_s),
            "merge_s": float(self.merge_s),
            "notes": {k: (v if isinstance(v, (int, float, str, bool, list))
                          else str(v)) for k, v in self.notes.items()},
        }


class DeviceGroup:
    """A set of devices acting as one logical processor (C or G)."""

    def __init__(self, name: str, devices):
        self.name = name
        self.devices = list(devices)
        if len(self.devices) > 1:
            self.mesh = jax.sharding.Mesh(np.array(self.devices), ("i",))
            self.sharding = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec("i"))
        else:
            self.mesh = None
            self.sharding = jax.sharding.SingleDeviceSharding(self.devices[0])
        self.replicated = (jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
            if self.mesh else self.sharding)
        self._jit_cache: dict = {}
        self._jit_lock = threading.Lock()

    @property
    def size(self) -> int:
        return len(self.devices)

    def put_items(self, tree):
        """Place per-item arrays on the group (leading axis sharded)."""
        _maybe_fault("h2d")
        return jax.tree.map(lambda x: jax.device_put(x, self.sharding), tree)

    def put_shared(self, tree):
        return jax.tree.map(lambda x: jax.device_put(x, self.replicated), tree)

    def pad_to(self, n: int) -> int:
        return _round_up(max(n, self.size), self.size)

    def jit(self, key, fn):
        # Lock: the engine's worker threads share one CoProcessor, so the
        # compile cache sees concurrent lookups for the same key.
        with self._jit_lock:
            cached = self._jit_cache.get(key)
            if cached is None:
                jf = jax.jit(fn)

                def cached(*args, _jf=jf, **kw):
                    _maybe_fault("kernel")   # launch-site fault injection
                    return _jf(*args, **kw)

                self._jit_cache[key] = cached
            return cached


class CoProcessor:
    """Executes hash-join step series across a C-group and a G-group.

    PHJ orchestration and the BasicUnit baseline are attached from
    ``PhjCoProcessorMixin`` at the bottom of this module."""

    def __init__(self, c_devices=None, g_devices=None, *,
                 link: LinkSpec = ZEROCOPY_LINK, discrete: bool = False,
                 ratio_quantum: int = 64, tracer=None):
        # Observability: phase timings flow through ``Timing.phase`` and
        # emit spans on this tracer.  The default is the shared no-op
        # recorder, so a standalone CoProcessor pays one branch per
        # phase; ``JoinQueryService`` swaps in its real tracer.
        from repro.obs import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        devs = jax.devices()
        if c_devices is None or g_devices is None:
            want_c = os.environ.get("REPRO_C_DEVICES")
            if want_c is not None and len(devs) >= 2:
                k = min(max(int(want_c), 1), len(devs) - 1)
                c_devices, g_devices = devs[:k], devs[k:]
            elif len(devs) >= 8:
                c_devices, g_devices = devs[:2], devs[2:]
            elif len(devs) >= 2:
                c_devices, g_devices = devs[:1], devs[1:]
            else:  # single device: both groups share it (functional mode)
                c_devices = g_devices = devs[:1]
        self.c = DeviceGroup("C", c_devices)
        self.g = DeviceGroup("G", g_devices)
        # Per-group execution locks for concurrent callers (the engine's
        # worker threads).  Two sharded programs with collectives must
        # never interleave on the same device group — XLA's rendezvous
        # deadlocks — but a C-only and a G-only query may overlap freely.
        # Acquire in fixed C-then-G order.
        self.group_locks = {"C": threading.Lock(), "G": threading.Lock()}
        self.link = link
        self.discrete = discrete
        self.ratio_quantum = ratio_quantum
        # Cuts and relation sizes are kept multiples of this, so both
        # groups' slices shard evenly over their devices.
        self.lcm = math.lcm(self.c.size, self.g.size)

    BUILD_PAD_KEY = -2   # sentinel keys: pads never match real (>=0) keys
    PROBE_PAD_KEY = -3

    def pad_relation(self, rel: Relation, sentinel: int) -> Relation:
        n = rel.size
        m = _round_up(n, self.lcm)
        if m == n:
            return rel
        pad = m - n
        return Relation(
            jnp.concatenate([rel.rid, jnp.full((pad,), ht.INVALID)]),
            jnp.concatenate([rel.key,
                             jnp.full((pad,), jnp.int32(sentinel))]))

    # ------------------------------------------------------------------
    # Emulated bus (paper §5.1: delay = latency + size/bandwidth).
    # ------------------------------------------------------------------
    def _bus_delay(self, nbytes: int, timing: Timing):
        timing.transfer_bytes += int(nbytes)
        if self.discrete and nbytes > 0:
            d = float(self.link.xfer_time(nbytes))
            timing.transfer_s += d
            time.sleep(d)

    def _cut(self, n: int, ratio: float) -> int:
        """Quantized split point (bounds recompilation count and keeps both
        slices divisible by the group sizes).

        Exact at the endpoints: ratio 0/1 must assign the WHOLE relation to
        one group — quantization leaving a remainder slice on the other
        group would dispatch work there that callers (and the engine's
        group locks) believe cannot happen."""
        if ratio <= 0.0:
            return 0
        if ratio >= 1.0:
            return n
        q = max(self.lcm, _round_up(n // self.ratio_quantum, self.lcm))
        cut = int(round(ratio * n / q)) * q
        return min(n, max(0, cut))

    # ------------------------------------------------------------------
    # Map-series execution with per-step ratios (PL backbone).
    # ------------------------------------------------------------------
    def run_map_series(self, series, shared, items, ratios,
                       timing: Timing | None = None):
        """Run splittable map steps with per-step ratios.

        Boundary rule (paper Fig. 2): when r_i != r_{i-1}, the slice between
        the two cut points moves across groups — a real device transfer plus
        the emulated bus delay in discrete mode.
        """
        timing = timing or Timing()
        n = next(iter(items.values())).shape[0]
        shared_c = self.c.put_shared(shared)
        shared_g = self.g.put_shared(shared)
        cut = self._cut(n, ratios[0])
        items_c = self.c.put_items({k: v[:cut] for k, v in items.items()})
        items_g = self.g.put_items({k: v[cut:] for k, v in items.items()})
        if self.discrete:
            moved = sum(int(np.prod(v.shape[1:]) or 1) * v.dtype.itemsize
                        * (n - cut) for v in items.values())
            self._bus_delay(moved, timing)
        extra_shared: dict = {}
        for i, step in enumerate(series.steps):
            new_cut = self._cut(n, ratios[i])
            if new_cut != cut:
                items_c, items_g, moved = self._move_boundary(
                    items_c, items_g, cut, new_cut)
                self._bus_delay(moved, timing)
                cut = new_cut
            fc = self.c.jit((series.name, step.name, "c",
                             tuple(v.shape for v in items_c.values())),
                            step.apply)
            fg = self.g.jit((series.name, step.name, "g",
                             tuple(v.shape for v in items_g.values())),
                            step.apply)
            out_c, sh_c = fc(shared_c, items_c)   # async dispatch: C ...
            out_g, sh_g = fg(shared_g, items_g)   # ... overlaps with G
            items_c, items_g = out_c, out_g
            for k, how in step.combine.items():
                a, b = sh_c.get(k), sh_g.get(k)
                if how == "add":
                    extra_shared[k] = jax.device_put(a, self.c.replicated) + \
                        jax.device_put(jax.device_get(b), self.c.replicated)
                elif how == "list":
                    extra_shared.setdefault(k, []).extend(
                        [x for x in (a if isinstance(a, list) else [a])] +
                        [x for x in (b if isinstance(b, list) else [b])])
        return items_c, items_g, extra_shared, timing

    def _move_boundary(self, items_c, items_g, cut, new_cut):
        """Move the [min(cut,new_cut), max) slice between the groups."""
        moved_bytes = 0
        if new_cut > cut:            # C takes more: head of G moves to C
            take = new_cut - cut
            head = {k: jax.device_get(v[:take]) for k, v in items_g.items()}
            moved_bytes = sum(v.nbytes for v in head.values())
            items_c = self.c.put_items(
                {k: jnp.concatenate([jax.device_get(items_c[k]), head[k]])
                 for k in items_c})
            items_g = self.g.put_items(
                {k: jax.device_get(v[take:]) for k, v in items_g.items()})
        else:                        # G takes more: tail of C moves to G
            take = cut - new_cut
            tail = {k: jax.device_get(v[v.shape[0] - take:])
                    for k, v in items_c.items()}
            moved_bytes = sum(v.nbytes for v in tail.values())
            items_g = self.g.put_items(
                {k: jnp.concatenate([tail[k], jax.device_get(items_g[k])])
                 for k in items_g})
            items_c = self.c.put_items(
                {k: jax.device_get(v[: v.shape[0] - take])
                 for k, v in items_c.items()})
        return items_c, items_g, moved_bytes

    # ------------------------------------------------------------------
    # SHJ under a scheme.
    # ------------------------------------------------------------------
    def shj(self, build_rel: Relation, probe_rel: Relation, *,
            num_buckets: int, max_out: int,
            build_ratios, probe_ratios, table_mode: str = "shared",
            measure: bool = True) -> tuple[ht.JoinResult, Timing]:
        """Run SHJ with per-step ratios (len-4 each; DD = equal entries,
        OL = 0/1 entries, CPU-only = all 1, GPU-only = all 0)."""
        table, timing = self.build_table(build_rel, num_buckets=num_buckets,
                                         ratios=build_ratios,
                                         table_mode=table_mode)
        result, timing = self.probe_table(probe_rel, table, max_out=max_out,
                                          ratios=probe_ratios, timing=timing)
        timing.wall_s = timing.phase_s["build"] + timing.phase_s["probe"]
        return result, timing

    def build_table(self, build_rel: Relation, *, num_buckets: int, ratios,
                    table_mode: str = "shared",
                    timing: Timing | None = None
                    ) -> tuple[ht.HashTable, Timing]:
        """Build phase only, returning the finished table.

        The engine's build-table cache keeps this output resident so later
        probes against the same build relation skip the phase entirely (the
        paper's cache-reuse insight lifted to the query level)."""
        timing = timing or Timing(tracer=self.tracer)
        build_rel = self.pad_relation(build_rel, self.BUILD_PAD_KEY)
        with timing.phase("build", n=build_rel.size):
            table = self._build(build_rel, num_buckets, ratios, table_mode,
                                timing)
        return table, timing

    def probe_table(self, probe_rel: Relation, table: ht.HashTable, *,
                    max_out: int, ratios,
                    timing: Timing | None = None,
                    probe_fn=None, tag: str = "probe"
                    ) -> tuple[ht.JoinResult, Timing]:
        """Probe phase against an existing (possibly cached) table.

        ``probe_fn(max_out)`` overrides the per-group probe kernel (the
        join-variant emissions in ``repro.ops.join_variants`` route
        through here); ``tag`` keys the jit cache per kernel family.
        """
        timing = timing or Timing(tracer=self.tracer)
        probe_rel = self.pad_relation(probe_rel, self.PROBE_PAD_KEY)
        with timing.phase("probe", n=probe_rel.size):
            result = self._probe(probe_rel, table, max_out, ratios, timing,
                                 probe_fn=probe_fn, tag=tag)
            jax.block_until_ready(result.probe_rid)
        if not timing.wall_s:
            timing.wall_s = timing.phase_s.get("build", 0.0) + \
                timing.phase_s["probe"]
        return result, timing

    def _build(self, rel: Relation, num_buckets: int, ratios, table_mode,
               timing: Timing) -> ht.HashTable:
        n = rel.size
        r1 = ratios[0]
        cut = self._cut(n, r1)
        if table_mode == "separate" and 0 < cut < n:
            # Each group builds a partial table on its share; merge after.
            rel_c = self.c.put_items(rel.take(0, cut))
            rel_g = self.g.put_items(rel.take(cut, n))
            if self.discrete:
                self._bus_delay((n - cut) * 8, timing)
            fb_c = self.c.jit(("build", cut, num_buckets, "c"),
                              partial(ht.build_hash_table,
                                      num_buckets=num_buckets))
            fb_g = self.g.jit(("build", n - cut, num_buckets, "g"),
                              partial(ht.build_hash_table,
                                      num_buckets=num_buckets))
            part_c = fb_c(rel_c)
            part_g = fb_g(rel_g)
            jax.block_until_ready((part_c.rids, part_g.rids))
            tm = time.perf_counter()
            if self.discrete:  # ship the partial table back over the bus
                self._bus_delay(sum(x.nbytes for x in
                                    jax.tree.leaves(part_g)), timing)
            part_g_host = jax.tree.map(jax.device_get, part_g)
            fm = self.c.jit(("merge", n, num_buckets),
                            partial(ht.merge_hash_tables,
                                    num_buckets=num_buckets))
            table = fm([part_c, self.c.put_shared(part_g_host)])
            jax.block_until_ready(table.rids)
            timing.merge_s = time.perf_counter() - tm
            return table
        # Shared table (or degenerate single-group): bucket-range ownership.
        # C owns buckets [0, r1*B); each group receives its owned tuples and
        # builds its range; ranges concatenate into ONE table (no merge).
        own_c = self._cut(num_buckets, r1) if 0 < cut < n else \
            (num_buckets if cut == n else 0)
        if own_c in (0, num_buckets):
            grp = self.c if own_c == num_buckets else self.g
            if self.discrete and grp is self.g:
                self._bus_delay(n * 8, timing)
            fb = grp.jit(("build", n, num_buckets, grp.name),
                         partial(ht.build_hash_table, num_buckets=num_buckets))
            table = fb(grp.put_items(rel))
            jax.block_until_ready(table.rids)
            return table
        bkt = bucket_of(rel.key, num_buckets)
        to_c = bkt < own_c
        order = jnp.argsort(~to_c, stable=True)  # owners contiguous
        n_c = int(to_c.sum())
        srel = Relation(rel.rid[order], rel.key[order])
        # Exchange: tuples cross groups to reach their owner (bounded above
        # by the full relation; discrete pays the bus for the crossing part).
        crossing = min(n_c, n - cut) + min(n - n_c, cut)
        self._bus_delay(crossing * 8, timing)
        n_c_pad = _round_up(max(n_c, 1), self.lcm)
        n_g_pad = _round_up(max(n - n_c, 1), self.lcm)
        rel_c = self.c.put_items(_pad_slice(srel, 0, n_c, n_c_pad,
                                            self.BUILD_PAD_KEY))
        rel_g = self.g.put_items(_pad_slice(srel, n_c, n, n_g_pad,
                                            self.BUILD_PAD_KEY))
        fb_c = self.c.jit(("buildr", n_c_pad, num_buckets, "c"),
                          partial(ht.build_hash_table, num_buckets=num_buckets))
        fb_g = self.g.jit(("buildr", n_g_pad, num_buckets, "g"),
                          partial(ht.build_hash_table, num_buckets=num_buckets))
        part_c = fb_c(rel_c)
        part_g = fb_g(rel_g)
        table = _concat_bucket_ranges(part_c,
                                      jax.tree.map(jax.device_get, part_g),
                                      own_c)
        jax.block_until_ready(table.rids)
        return table

    def _probe(self, rel: Relation, table: ht.HashTable, max_out: int,
               ratios, timing: Timing, *, probe_fn=None,
               tag: str = "probe") -> ht.JoinResult:
        n = rel.size
        cut = self._cut(n, ratios[0])
        # Replicate the table to both groups (coupled: zero-copy; discrete:
        # the GPU-side copy pays the bus once).
        table_bytes = sum(x.nbytes for x in jax.tree.leaves(table))
        if self.discrete and cut < n:
            self._bus_delay(table_bytes + (n - cut) * 8, timing)
        tbl_c = self.c.put_shared(table)
        tbl_g = self.g.put_shared(table)
        # Per-group result capacity: proportional to the tuple share, plus
        # slack covering statistical fluctuation of the match density (a
        # proportional cap with O(1) slack truncates skewed probes).
        slack = max(64, max_out // 16)
        max_c = max(1, _round_up(int(max_out * (cut / max(n, 1))), 8) + slack)
        max_g = max(1, max_out - max_c + 2 * slack)

        if probe_fn is None:
            def probe_fn(mo):
                return lambda r, t: ht.probe_hash_table(r, t, mo)

        res = []
        if cut > 0:
            fp = self.c.jit((tag, cut, max_c, "c"), probe_fn(max_c))
            res.append(fp(self.c.put_items(rel.take(0, cut)), tbl_c))
        if cut < n:
            fp = self.g.jit((tag, n - cut, max_g, "g"), probe_fn(max_g))
            res.append(fp(self.g.put_items(rel.take(cut, n)), tbl_g))
        if len(res) == 1:
            out = res[0]
            if self.discrete:
                self._bus_delay(int(out.count) * 8, timing)
            if out.probe_rid.shape[0] > max_out:
                # The per-group slack padded capacity past the caller's
                # max_out; restore the contract (valid pairs are front-
                # compacted, so a prefix slice keeps the first matches).
                out = ht.JoinResult(out.probe_rid[:max_out],
                                    out.build_rid[:max_out],
                                    jnp.minimum(out.count, max_out))
            return out
        res_host = [jax.tree.map(jax.device_get, r) for r in res]
        if self.discrete:
            self._bus_delay(int(res_host[1].count) * 8, timing)
        fcat = self.c.jit(("concat", tag,
                           tuple(r.probe_rid.shape[0] for r in res_host),
                           max_out),
                          partial(concat_results, max_out=max_out))
        return fcat([self.c.put_shared(r) for r in res_host])


def _phj_owned_join(rel_r: Relation, rel_s: Relation, *, total_bits: int,
                    shj_bits: int, max_out: int) -> ht.JoinResult:
    """Fused per-partition SHJ over a subset of partitions (see phj.py)."""
    from .relation import radix_of

    num_buckets = 1 << (total_bits + shj_bits)

    def bucket_fn(key):
        part = radix_of(key, shift=0, bits=total_bits).astype(jnp.uint32)
        sub = (jnp.uint32(0) if shj_bits == 0 else
               radix_of(key, shift=total_bits, bits=shj_bits).astype(jnp.uint32))
        return ((part << jnp.uint32(shj_bits)) | sub).astype(jnp.int32)

    bkt = bucket_fn(rel_r.key)
    order = ht.build_b2_order(bkt, rel_r.key)
    sbkt, skey = bkt[order], rel_r.key[order]
    (ukeys, krs, krc, bks, bkc, num_keys) = ht.build_b3_keylists(
        sbkt, skey, num_buckets)
    table = ht.HashTable(bks, bkc, ukeys, krs, krc, rel_r.rid[order], skey,
                         num_keys.astype(jnp.int32))
    pbkt = bucket_fn(rel_s.key)
    kstart, kcount = ht.probe_p2(table, pbkt)
    entry, nmatch = ht.probe_p3(table, rel_s.key, kstart, kcount)
    return ht.probe_p4(table, rel_s.rid, entry, nmatch, max_out)


class PhjCoProcessorMixin:
    """PHJ orchestration + the appendix's BasicUnit scheduler."""

    def _partition_side_cooperative(self, tag: str, rel: Relation,
                                    sched: tuple[int, ...],
                                    partition_ratio: float, ctx,
                                    start_pass: int, timing: "Timing",
                                    interpret: bool = False) -> Relation:
        """Ratio-split partitioning, one jitted program per pass.

        The preemptible sibling of the fused whole-schedule path: control
        returns to Python between passes so ``ctx.check`` can abort (a
        blown deadline / exhausted budget) at a pass boundary.  On abort
        the current per-group slices are collected into a partial layout
        via ``ctx.note_partial`` — the engine checkpoints it under a
        schedule-prefix cache key, and a re-admitted query resumes here
        with ``start_pass`` = completed passes.  Each pass is a stable
        reorder over its own bit slice, so the per-slice result is
        identical to the fused path's.
        """
        from .partition import partition_pass

        n = rel.size
        cut = self._cut(n, partition_ratio)
        if self.discrete and 0 < cut < n:
            self._bus_delay((n - cut) * 8, timing)
        slices = []
        if cut > 0:
            slices.append((self.c, self.c.put_items(rel.take(0, cut))))
        if cut < n:
            slices.append((self.g, self.g.put_items(rel.take(cut, n))))
        shift = sum(sched[:start_pass])

        def collect() -> Relation:
            pieces = [jax.tree.map(jax.device_get, r) for _, r in slices]
            return Relation(jnp.concatenate([x.rid for x in pieces]),
                            jnp.concatenate([x.key for x in pieces]))

        for i in range(start_pass, len(sched)):
            if ctx is not None:
                try:
                    ctx.check(f"partition:{tag}:pass{i}")
                except Exception:
                    if i > 0:
                        ctx.note_partial(tag, collect(), i)
                    raise
            bits = sched[i]
            slices = [(grp, grp.jit(
                ("part_pass", tag, r.size, shift, bits, interpret),
                partial(partition_pass, shift=shift, bits=bits,
                        interpret=interpret))(r))
                for grp, r in slices]
            shift += bits
        _maybe_fault("d2h")
        return collect()

    def phj(self, build_rel: Relation, probe_rel: Relation, *,
            bits_per_pass: int | None = None, num_passes: int | None = None,
            schedule: tuple[int, ...] | None = None, planner=None,
            shj_bits: int, max_out: int,
            partition_ratio: float, join_ratio: float,
            build_parts: Relation | None = None,
            probe_parts: Relation | None = None,
            parts_out: dict | None = None, ctx=None,
            build_resume: int | None = None,
            probe_resume: int | None = None
            ) -> tuple[ht.JoinResult, "Timing"]:
        """PHJ co-processing: ratio-split partitioning, then partition-pair
        ownership split for the join phase (paper PHJ-DD/PL skeleton).

        Pass knobs may be explicit or planner-chosen (``resolve_schedule``);
        every pass runs the fused n1+n2 / scan+scatter data path.

        ``partition_ratio`` — C-group share of the partition passes.
        ``join_ratio``      — fraction of partition pairs owned by C.
        ``build_parts``     — an already-partitioned build relation (as a
                              prior call returned through ``parts_out``
                              under the SAME schedule): R skips the n1–n3
                              partition passes entirely.  This is what the
                              engine's partition-layout cache feeds back.
        ``probe_parts``     — same for the probe side: a replayed pipeline
                              re-probes with an identical relation, and its
                              partition passes are the larger half of the
                              cost at star-query shapes.
        ``parts_out``       — when a dict is passed, its ``"R"`` / ``"S"``
                              slots receive the freshly partitioned layouts
                              for the caller to cache (only the sides that
                              were actually partitioned this call).
        ``ctx``             — cooperative ``QueryContext``: when given,
                              partitioning runs pass-at-a-time with
                              ``ctx.check`` at every pass boundary (and
                              once before the join phase), so deadline /
                              budget preemption can abort between passes
                              and checkpoint the partial layout.
        ``build_resume`` / ``probe_resume`` — with a value ``k``, the
                              corresponding ``*_parts`` relation is a
                              *partial* layout holding the schedule's
                              first ``k`` passes (a checkpoint); the
                              remaining passes run from there.
        """
        from .partition import radix_partition_scheduled
        from .phj import resolve_schedule
        from .relation import radix_of

        timing = Timing(tracer=self.tracer)
        sched = resolve_schedule(build_rel.size, bits_per_pass=bits_per_pass,
                                 num_passes=num_passes, schedule=schedule,
                                 planner=planner)
        total_bits = sum(sched)
        timing.notes["schedule"] = list(sched)
        build_rel = self.pad_relation(build_rel, self.BUILD_PAD_KEY)
        probe_rel = self.pad_relation(probe_rel, self.PROBE_PAD_KEY)

        def part_fn(rel):
            return radix_partition_scheduled(rel, schedule=sched).rel

        with timing.phase("partition", passes=len(sched)):
            parts = {}
            if build_parts is not None and build_resume is None:
                parts["R"] = build_parts
                timing.notes["build_parts_reused"] = True
            if probe_parts is not None and probe_resume is None:
                parts["S"] = probe_parts
                timing.notes["probe_parts_reused"] = True
            todo = []
            for tag, rel, given, resume in (
                    ("R", build_rel, build_parts, build_resume),
                    ("S", probe_rel, probe_parts, probe_resume)):
                if tag in parts:
                    continue
                start = 0
                if given is not None and resume:
                    # A checkpointed partial layout: first ``resume``
                    # passes are already absorbed (stable reorders — no
                    # re-running).  Checkpoints were captured post-pad.
                    rel, start = given, int(resume)
                    timing.notes[f"{tag}_resumed_at"] = start
                todo.append((tag, rel, start))
            for tag, rel, start in todo:
                if ctx is not None or start:
                    parts[tag] = self._partition_side_cooperative(
                        tag, rel, sched, partition_ratio, ctx, start,
                        timing)
                    continue
                n = rel.size
                cut = self._cut(n, partition_ratio)
                if self.discrete and 0 < cut < n:
                    self._bus_delay((n - cut) * 8, timing)
                pieces = []
                if cut > 0:
                    f = self.c.jit(("phj_part", tag, cut, sched), part_fn)
                    pieces.append(f(self.c.put_items(rel.take(0, cut))))
                if cut < n:
                    f = self.g.jit(("phj_part", tag, n - cut, sched),
                                   part_fn)
                    pieces.append(f(self.g.put_items(rel.take(cut, n))))
                _maybe_fault("d2h")
                pieces = [jax.tree.map(jax.device_get, x) for x in pieces]
                parts[tag] = Relation(
                    jnp.concatenate([x.rid for x in pieces]),
                    jnp.concatenate([x.key for x in pieces]))
            if parts_out is not None:
                for tag, _, _ in todo:
                    parts_out[tag] = parts[tag]

        if ctx is not None:
            ctx.check("join")
        with timing.phase("join"):
            # Ownership exchange: partitions [0, own) -> C, rest -> G.
            num_parts = 1 << total_bits
            own = self._cut(num_parts, join_ratio)
            results = []
            for grp, sel in ((self.c, lambda pid: pid < own),
                             (self.g, lambda pid: pid >= own)):
                if (own == 0 and grp is self.c) or (own == num_parts
                                                    and grp is self.g):
                    continue
                sub = {}
                for tag in ("R", "S"):
                    rel = parts[tag]
                    pid = radix_of(rel.key, shift=0, bits=total_bits)
                    mask = np.asarray(sel(pid))
                    idx = np.nonzero(mask)[0]
                    m = _round_up(max(len(idx), 1), self.lcm)
                    sent = (self.BUILD_PAD_KEY if tag == "R"
                            else self.PROBE_PAD_KEY)
                    rid = np.full(m, -1, np.int32)
                    key = np.full(m, sent, np.int32)
                    rid[:len(idx)] = np.asarray(rel.rid)[idx]
                    key[:len(idx)] = np.asarray(rel.key)[idx]
                    if self.discrete:
                        self._bus_delay(len(idx) * 8 // 2, timing)
                    sub[tag] = grp.put_items(Relation(jnp.asarray(rid),
                                                      jnp.asarray(key)))
                # Full capacity per group: partition ownership is by radix
                # value, so a skewed relation's hot partition (and all its
                # matches) can land wholly on either side regardless of
                # join_ratio — proportional caps would truncate it.
                mo = _round_up(max_out, 8) + 64
                f = grp.jit(("phj_join", sub["R"].size, sub["S"].size, mo),
                            partial(_phj_owned_join, total_bits=total_bits,
                                    shj_bits=shj_bits, max_out=mo))
                results.append(f(sub["R"], sub["S"]))
            _maybe_fault("d2h")
            results = [jax.tree.map(jax.device_get, r) for r in results]
            if len(results) == 1:
                out = results[0]
            else:
                fcat = self.c.jit(
                    ("concat", tuple(r.probe_rid.shape[0] for r in results),
                     max_out), partial(concat_results, max_out=max_out))
                out = fcat([self.c.put_shared(r) for r in results])
            jax.block_until_ready(out.probe_rid)
        timing.wall_s = timing.phase_s["partition"] + timing.phase_s["join"]
        return out, timing

    # ------------------------------------------------------------------
    # Appendix A: BasicUnit — coarse-grained dynamic chunk scheduling.
    # ------------------------------------------------------------------
    def basic_unit_shj(self, build_rel: Relation, probe_rel: Relation, *,
                       num_buckets: int, max_out: int, chunk: int = 4096
                       ) -> tuple[ht.JoinResult, "Timing", dict]:
        """Chunks of tuples dynamically assigned to whichever group is free.

        Greedy least-loaded assignment using one calibrated chunk time per
        group (the appendix's dynamic queue), then real execution of the
        assigned work.  Returns the realized per-phase CPU ratios (appendix
        Figs. 17/18)."""
        timing = Timing()
        build_rel = self.pad_relation(build_rel, self.BUILD_PAD_KEY)
        probe_rel = self.pad_relation(probe_rel, self.PROBE_PAD_KEY)
        chunk = _round_up(chunk, self.lcm)
        ratios = {}
        t0 = time.perf_counter()

        def assign(n_items, t_c, t_g):
            n_chunks = -(-n_items // chunk)
            load_c = load_g = 0.0
            sched = []
            for _ in range(n_chunks):  # the paper's dynamic queue, greedily
                if load_c + t_c <= load_g + t_g:
                    sched.append("C")
                    load_c += t_c
                else:
                    sched.append("G")
                    load_g += t_g
            return sched

        # calibrate one chunk per group (build)
        cal = build_rel.take(0, chunk)
        fb = {g.name: g.jit(("bu_build", chunk, num_buckets, g.name),
                            partial(ht.build_hash_table,
                                    num_buckets=num_buckets))
              for g in (self.c, self.g)}
        tc = _time_once(fb["C"], self.c.put_items(cal))
        tg = _time_once(fb["G"], self.g.put_items(cal))
        sched = assign(build_rel.size, tc, tg)
        ratios["build"] = sched.count("C") / max(len(sched), 1)
        partials = []
        for i, who in enumerate(sched):
            grp = self.c if who == "C" else self.g
            lo = i * chunk
            hi = min(build_rel.size, lo + chunk)
            sl = _pad_slice(build_rel, lo, hi, chunk, self.BUILD_PAD_KEY)
            partials.append(fb[who](grp.put_items(sl)))
        partials = [jax.tree.map(jax.device_get, t) for t in partials]
        fm = self.c.jit(("bu_merge", len(partials), chunk, num_buckets),
                        partial(ht.merge_hash_tables, num_buckets=num_buckets))
        table = fm([self.c.put_shared(t) for t in partials])
        jax.block_until_ready(table.rids)
        t1 = time.perf_counter()
        timing.phase_s["build"] = t1 - t0
        timing.merge_s = 0.0

        # probe chunks
        mo = max(64, _round_up(max_out // max(1, probe_rel.size // chunk), 8)
                 + 64)
        fp = {g.name: g.jit(("bu_probe", chunk, mo, g.name),
                            lambda r, t: ht.probe_hash_table(r, t, mo))
              for g in (self.c, self.g)}
        tbl = {g.name: g.put_shared(table) for g in (self.c, self.g)}
        calp = probe_rel.take(0, chunk)
        tcp = _time_once(lambda r: fp["C"](r, tbl["C"]), self.c.put_items(calp))
        tgp = _time_once(lambda r: fp["G"](r, tbl["G"]), self.g.put_items(calp))
        schedp = assign(probe_rel.size, tcp, tgp)
        ratios["probe"] = schedp.count("C") / max(len(schedp), 1)
        outs = []
        for i, who in enumerate(schedp):
            grp = self.c if who == "C" else self.g
            lo = i * chunk
            hi = min(probe_rel.size, lo + chunk)
            sl = _pad_slice(probe_rel, lo, hi, chunk, self.PROBE_PAD_KEY)
            outs.append(fp[who](grp.put_items(sl), tbl[who]))
        outs = [jax.tree.map(jax.device_get, r) for r in outs]
        fcat = self.c.jit(("bu_concat", len(outs), mo, max_out),
                          partial(concat_results, max_out=max_out))
        out = fcat([self.c.put_shared(r) for r in outs])
        jax.block_until_ready(out.probe_rid)
        t2 = time.perf_counter()
        timing.phase_s["probe"] = t2 - t1
        timing.wall_s = t2 - t0
        return out, timing, ratios


def _time_once(fn, *args) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def _pad_slice(rel: Relation, lo: int, hi: int, target: int,
               sentinel: int) -> Relation:
    """rel[lo:hi] padded with sentinel tuples up to ``target`` rows."""
    rid, key = rel.rid[lo:hi], rel.key[lo:hi]
    pad = target - (hi - lo)
    if pad <= 0:
        return Relation(rid, key)
    return Relation(
        jnp.concatenate([rid, jnp.full((pad,), ht.INVALID)]),
        jnp.concatenate([key, jnp.full((pad,), jnp.int32(sentinel))]))


def _concat_bucket_ranges(part_c: ht.HashTable, part_g: ht.HashTable,
                          own_c: int) -> ht.HashTable:
    """Stitch two bucket-range tables into one logical shared table.

    C's table covers buckets [0, own_c) of the global space, G's covers
    [own_c, B).  Entry/rid indices of the G range shift by C's counts.
    """
    nk_c = part_c.ukeys.shape[0]
    nr_c = part_c.rids.shape[0]
    bkc = jnp.concatenate([part_c.bucket_key_count[:own_c],
                           part_g.bucket_key_count[own_c:]])
    ukeys = jnp.concatenate([part_c.ukeys, part_g.ukeys])
    krs = jnp.concatenate([part_c.key_rid_start,
                           part_g.key_rid_start + nr_c])
    krc = jnp.concatenate([part_c.key_rid_count, part_g.key_rid_count])
    rids = jnp.concatenate([part_c.rids, part_g.rids])
    skeys = jnp.concatenate([part_c.skeys, part_g.skeys])
    num_keys = part_c.num_keys + part_g.num_keys
    # Re-point G's bucket starts past C's padded tail: C's valid entries are
    # [0, nk_valid_c); G's live at [nk_c, nk_c + ...).  Adjust offset.
    bks = jnp.concatenate([
        part_c.bucket_key_start[:own_c],
        part_g.bucket_key_start[own_c:] + nk_c,
    ])
    return ht.HashTable(bks, bkc, ukeys, krs, krc, rids, skeys, num_keys)


CoProcessor.phj = PhjCoProcessorMixin.phj
CoProcessor._partition_side_cooperative = \
    PhjCoProcessorMixin._partition_side_cooperative
CoProcessor.basic_unit_shj = PhjCoProcessorMixin.basic_unit_shj
