"""Radix partitioning (paper §3.1, Algorithm 2, steps n1..n3).

Each pass clusters tuples by a slice of the hash's low bits:

  n1: compute partition number        (VPU ALU map over tuples)
  n2: visit the partition header      (histogram + exclusive scan)
  n3: insert <key, rid> into partition (stable reorder = scan allocator)

On TPU there are no atomics, so n2+n3 use the deterministic
histogram -> scan -> reorder pattern (DESIGN.md §2): semantically identical
to the paper's latched partition buffers, contention-free by construction.
Multiple passes refine previous passes' clusters (paper: "performed by
multiple passes ... tuned according to the memory hierarchy"); pass ``g``
uses hash bits ``[g*bits, (g+1)*bits)`` and a globally stable reorder, so
after all passes tuples are clustered by the full ``total_bits`` radix.

This module is also the MoE dispatch engine: routing tokens to experts is a
radix partition by expert id (see ``repro.layers.moe``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .relation import Relation, radix_of


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Partitions:
    """A relation clustered into ``P`` partitions, with CSR headers."""

    rel: Relation            # tuples reordered so partitions are contiguous
    part_start: jax.Array    # (P,)
    part_count: jax.Array    # (P,)

    @property
    def num_partitions(self) -> int:
        return int(self.part_start.shape[0])

    def tree_flatten(self):
        return (self.rel, self.part_start, self.part_count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def partition_n1(key: jax.Array, *, shift: int, bits: int) -> jax.Array:
    """(n1) compute partition number from the hash's bit slice."""
    return radix_of(key, shift=shift, bits=bits)


def partition_n2(pid: jax.Array, num_parts: int):
    """(n2) partition headers: histogram + exclusive scan (the allocator)."""
    counts = jax.ops.segment_sum(jnp.ones_like(pid), pid,
                                 num_segments=num_parts)
    starts = jnp.cumsum(counts) - counts
    return starts, counts


def partition_n3(rel: Relation, pid: jax.Array) -> Relation:
    """(n3) insert <key, rid> into partitions: stable reorder by pid."""
    order = jnp.argsort(pid, stable=True)
    return Relation(rel.rid[order], rel.key[order])


@partial(jax.jit, static_argnames=("schedule", "use_pallas", "interpret"))
def radix_partition_scheduled(rel: Relation, *, schedule: tuple[int, ...],
                              use_pallas: bool | None = None,
                              interpret: bool = False) -> Partitions:
    """Multi-pass radix partitioning over an explicit pass ``schedule``.

    ``schedule`` lists each pass's digit width, low digit first (a
    ``PassPlan.schedule`` — see ``repro.core.pass_planner``).  Every pass
    is the FUSED data path (``repro.kernels.partition_hist.ops``): n1+n2
    in one VMEM sweep, n3 as a fused scan+scatter; stable reorders make
    the final layout clustered by the complete ``sum(schedule)``-bit radix.
    """
    from repro.kernels.partition_hist.ops import fused_partition_pass

    total_bits = sum(schedule)
    cur = rel
    shift = 0
    for bits in schedule:
        cur, _, _ = fused_partition_pass(cur, shift=shift, bits=bits,
                                         use_pallas=use_pallas,
                                         interpret=interpret)
        shift += bits
    full_pid = radix_of(cur.key, shift=0, bits=total_bits)
    start, count = partition_n2(full_pid, 1 << total_bits)
    return Partitions(cur, start, count)


def partition_pass(rel: Relation, *, shift: int, bits: int,
                   use_pallas: bool | None = None,
                   interpret: bool = False) -> Relation:
    """One fused partition pass (n1+n2 sweep, scan+scatter n3)."""
    from repro.kernels.partition_hist.ops import fused_partition_pass

    out, _, _ = fused_partition_pass(rel, shift=shift, bits=bits,
                                     use_pallas=use_pallas,
                                     interpret=interpret)
    return out


_coop_pass_cache: dict = {}


def _jitted_pass(shift: int, bits: int, use_pallas, interpret):
    key = (shift, bits, use_pallas, interpret)
    fn = _coop_pass_cache.get(key)
    if fn is None:
        fn = _coop_pass_cache[key] = jax.jit(partial(
            partition_pass, shift=shift, bits=bits,
            use_pallas=use_pallas, interpret=interpret))
    return fn


def radix_partition_cooperative(rel: Relation, *,
                                schedule: tuple[int, ...],
                                start_pass: int = 0, check=None,
                                use_pallas: bool | None = None,
                                interpret: bool = False) -> Partitions:
    """Preemptible multi-pass partitioning: one jitted program *per pass*.

    ``radix_partition_scheduled`` compiles the whole schedule into a
    single program — nothing can stop it mid-flight.  This variant runs
    the identical fused passes but returns control to Python between
    them, calling ``check(pass_idx)`` first; a check that raises (the
    engine's ``QueryContext.check`` raising ``DeadlineExceeded``) aborts
    with ``pass_idx`` passes complete.  ``start_pass=k`` resumes a
    relation that already absorbed the schedule's first ``k`` passes (a
    checkpointed partial layout): each pass is a stable reorder on its
    own bit slice, so completed passes never need re-running.
    """
    total_bits = sum(schedule)
    cur = rel
    shift = sum(schedule[:start_pass])
    for i in range(start_pass, len(schedule)):
        if check is not None:
            check(i)
        bits = schedule[i]
        cur = _jitted_pass(shift, bits, use_pallas, interpret)(cur)
        shift += bits
    full_pid = radix_of(cur.key, shift=0, bits=total_bits)
    start, count = partition_n2(full_pid, 1 << total_bits)
    return Partitions(cur, start, count)


def radix_partition(rel: Relation, *, bits_per_pass: int,
                    num_passes: int, use_pallas: bool | None = None,
                    interpret: bool = False) -> Partitions:
    """Uniform-schedule partitioning: (n1 n2 n3) x num_passes (fused)."""
    return radix_partition_scheduled(
        rel, schedule=(bits_per_pass,) * num_passes, use_pallas=use_pallas,
        interpret=interpret)


@partial(jax.jit, static_argnames=("bits_per_pass", "num_passes"))
def radix_partition_unfused(rel: Relation, *, bits_per_pass: int,
                            num_passes: int) -> Partitions:
    """The seed's materialized 3-step path, kept as the benchmark baseline
    (``benchmarks/run.py --only partition_fused`` compares against it)."""
    total_bits = bits_per_pass * num_passes
    cur = rel
    for g in range(num_passes):
        pid = partition_n1(cur.key, shift=g * bits_per_pass,
                           bits=bits_per_pass)
        # Headers are computed every pass (n2) as in the paper; only the
        # final pass's full-radix headers are returned.
        partition_n2(pid, 1 << bits_per_pass)
        cur = partition_n3(cur, pid)
    full_pid = radix_of(cur.key, shift=0, bits=total_bits)
    start, count = partition_n2(full_pid, 1 << total_bits)
    return Partitions(cur, start, count)


def partition_ids(rel: Relation, *, total_bits: int) -> jax.Array:
    """Final partition id per tuple (for tests / divergence grouping)."""
    return radix_of(rel.key, shift=0, bits=total_bits)
