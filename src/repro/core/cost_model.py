"""The paper's unified co-processing cost model (§4, Table 2, Eqs. 1–5).

Abstract model: a step series s_1..s_n with x_i input items at step i and a
CPU-side workload ratio r_i.  For each processor group XPU in {C, G}:

    T = max(T_C, T_G)                                               (Eq. 1)
    T_XPU = sum_i (C^i_XPU + M^i_XPU + D^i_XPU [+ L^i_XPU])        (Eq. 2)
    C^i + M^i = u^i_XPU * share_i * x_i                            (Eq. 3 +
                 calibrated memory term; u = sec/item from calibrate.py)
    D^i per Eqs. 4/5 (pipeline delay from ratio mismatch)
    L^i = link term (our TPU extension, DESIGN.md §7): moved items between
          groups when consecutive ratios differ, priced at ICI (coupled) or
          DCN/PCIe (discrete) latency+bandwidth.  On discrete, DD/OL also
          pay input shipping and result return (the paper's Fig. 3 bars).

Eqs. 4/5 reference T of the *current* step on the opposite group; to avoid
the circular definition we use the step's work time (C+M) for step i and the
full cumulative time (incl. D, L) for steps < i — this matches the paper's
described semantics ("time from Step 1 to the end of the pipelined
execution area").

The δ-sweep optimizer (§3.2, δ=0.02) evaluates the model over the full
ratio grid (vectorized over grid points), with DD (all-equal ratios) and OL
(0/1 ratios) as restricted sweeps — the paper's observation that DD and OL
are special cases of PL.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Inter-group link: ICI for coupled pods, DCN/PCIe for discrete."""

    name: str
    latency_s: float
    bw_bytes_per_s: float

    def xfer_time(self, nbytes) -> np.ndarray:
        nbytes = np.asarray(nbytes, dtype=np.float64)
        return np.where(nbytes > 0, self.latency_s + nbytes / self.bw_bytes_per_s, 0.0)


# Paper §5.1 emulates PCIe with latency 0.015 ms, bw 3 GB/s.
PCIE_LINK = LinkSpec("pcie_emulated", 0.015e-3, 3e9)
# TPU v5e: ~50 GB/s/link ICI, ~1 us software latency (coupled analogue).
ICI_LINK = LinkSpec("ici", 1e-6, 50e9)
# Cross-pod DCN (discrete analogue at pod scale).
DCN_LINK = LinkSpec("dcn", 25e-6, 3.2e9)
# Same-host zero-copy (what the CPU-only benches actually traverse).
ZEROCOPY_LINK = LinkSpec("zerocopy", 2e-7, 40e9)


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Analytic throughput of one processor group (seeds u when no
    measured calibration is available; v5e numbers in calibrate.py)."""

    name: str
    ops_per_s: float
    seq_bw_bytes_per_s: float
    rand_access_per_s: float

    def unit_cost(self, cost) -> float:
        """Seconds/item from a StepCost (paper Eq. 3 + memory term)."""
        return (cost.ops_per_item / self.ops_per_s
                + cost.seq_bytes_per_item / self.seq_bw_bytes_per_s
                + cost.rand_accesses_per_item / self.rand_access_per_s)


@dataclasses.dataclass
class CostBreakdown:
    total: float
    t_c: float
    t_g: float
    per_step_c: np.ndarray   # (n,) work time on C
    per_step_g: np.ndarray   # (n,) work time on G
    delay_c: np.ndarray
    delay_g: np.ndarray
    link: np.ndarray         # (n,) link time charged at each boundary


class SeriesCostModel:
    """Cost model for one step series (between barriers)."""

    def __init__(self, step_names, u_c, u_g, x, out_bytes, link: LinkSpec,
                 *, discrete: bool = False, item_bytes: float = 8.0):
        self.step_names = list(step_names)
        self.u_c = np.asarray(u_c, dtype=np.float64)
        self.u_g = np.asarray(u_g, dtype=np.float64)
        self.x = np.asarray(x, dtype=np.float64)
        self.out_bytes = np.asarray(out_bytes, dtype=np.float64)
        self.link = link
        self.discrete = discrete
        self.item_bytes = item_bytes
        self.n = len(self.step_names)
        assert self.u_c.shape == (self.n,)

    # -- vectorized evaluation over a batch of ratio assignments ------------
    def estimate_batch(self, ratios: np.ndarray) -> np.ndarray:
        """ratios: (m, n) in [0,1].  Returns (m,) total series time."""
        r = np.asarray(ratios, dtype=np.float64)
        if r.ndim == 1:
            r = r[None, :]
        m, n = r.shape
        w_c = self.u_c * r * self.x                  # (m, n) work time on C
        w_g = self.u_g * (1.0 - r) * self.x          # (m, n)
        cum_c = np.zeros(m)
        cum_g = np.zeros(m)
        for i in range(n):
            d_c = np.zeros(m)
            d_g = np.zeros(m)
            l_i = np.zeros(m)
            if i > 0:
                dr = r[:, i] - r[:, i - 1]
                # Eq. 4: CPU waits for GPU output of step i-1.
                up = dr > 0
                denom = np.maximum(1.0 - r[:, i - 1], 1e-12)
                not_piped = w_g[:, i - 1] * (1.0 - r[:, i]) / denom
                d_c = np.where(up, np.maximum(
                    0.0, (cum_g - not_piped) - (cum_c + w_c[:, i])), 0.0)
                # Eq. 5: GPU waits for CPU output of step i-1.
                dn = dr < 0
                denom2 = np.maximum(1.0 - r[:, i], 1e-12)
                not_piped2 = w_g[:, i] * (1.0 - r[:, i - 1]) / denom2
                d_g = np.where(dn, np.maximum(
                    0.0, cum_c - (cum_g + w_g[:, i] - not_piped2)), 0.0)
                # Link: |dr| * x_i items of the previous step's output cross
                # the groups.
                moved = np.abs(dr) * self.x[i] * self.out_bytes[i - 1]
                l_i = self.link.xfer_time(moved)
            elif self.discrete:
                # Discrete: ship the G-group's input share over the bus.
                l_i = self.link.xfer_time((1.0 - r[:, 0]) * self.x[0]
                                          * self.item_bytes)
            cum_c = cum_c + w_c[:, i] + d_c + l_i
            cum_g = cum_g + w_g[:, i] + d_g + l_i
        if self.discrete:
            # Result return for the G-group share of the last step.
            back = self.link.xfer_time((1.0 - r[:, -1]) * self.x[-1]
                                       * self.out_bytes[-1])
            cum_g = cum_g + back
        return np.maximum(cum_c, cum_g)

    def estimate(self, ratios) -> CostBreakdown:
        """Detailed single-assignment estimate with per-step breakdown."""
        r = np.asarray(ratios, dtype=np.float64)
        n = self.n
        w_c = self.u_c * r * self.x
        w_g = self.u_g * (1.0 - r) * self.x
        d_c = np.zeros(n)
        d_g = np.zeros(n)
        l = np.zeros(n)
        cum_c = cum_g = 0.0
        for i in range(n):
            if i > 0:
                dr = r[i] - r[i - 1]
                if dr > 0:
                    denom = max(1.0 - r[i - 1], 1e-12)
                    not_piped = w_g[i - 1] * (1.0 - r[i]) / denom
                    d_c[i] = max(0.0, (cum_g - not_piped) - (cum_c + w_c[i]))
                elif dr < 0:
                    denom = max(1.0 - r[i], 1e-12)
                    not_piped = w_g[i] * (1.0 - r[i - 1]) / denom
                    d_g[i] = max(0.0, cum_c - (cum_g + w_g[i] - not_piped))
                l[i] = float(self.link.xfer_time(abs(dr) * self.x[i]
                                                 * self.out_bytes[i - 1]))
            elif self.discrete:
                l[i] = float(self.link.xfer_time((1.0 - r[0]) * self.x[0]
                                                 * self.item_bytes))
            cum_c += w_c[i] + d_c[i] + l[i]
            cum_g += w_g[i] + d_g[i] + l[i]
        if self.discrete:
            cum_g += float(self.link.xfer_time((1.0 - r[-1]) * self.x[-1]
                                               * self.out_bytes[-1]))
        return CostBreakdown(max(cum_c, cum_g), cum_c, cum_g, w_c, w_g,
                             d_c, d_g, l)

    # -- δ-sweep optimizers (paper §3.2) -------------------------------------
    def _grid(self, delta: float) -> np.ndarray:
        k = int(round(1.0 / delta))
        return np.linspace(0.0, 1.0, k + 1)

    def optimize_pl(self, delta: float = 0.02,
                    max_grid: int = 20_000_000) -> tuple[np.ndarray, float]:
        """Full PL sweep over the δ-grid of per-step ratios.

        Falls back to cyclic coordinate descent when the full grid would
        exceed ``max_grid`` points (n > 4 at δ=0.02) — each sweep is exact
        per coordinate, iterated to a fixed point.
        """
        g = self._grid(delta)
        if len(g) ** self.n <= max_grid:
            mesh = np.stack(np.meshgrid(*([g] * self.n), indexing="ij"),
                            axis=-1).reshape(-1, self.n)
            t = self.estimate_batch(mesh)
            i = int(np.argmin(t))
            return mesh[i], float(t[i])
        r = np.full(self.n, 0.5)
        best = float(self.estimate_batch(r[None])[0])
        for _ in range(16):
            improved = False
            for i in range(self.n):
                cand = np.repeat(r[None], len(g), axis=0)
                cand[:, i] = g
                t = self.estimate_batch(cand)
                j = int(np.argmin(t))
                if t[j] < best - 1e-15:
                    best, r = float(t[j]), cand[j]
                    improved = True
            if not improved:
                break
        return r, best

    def optimize_dd(self, delta: float = 0.02) -> tuple[float, float]:
        """DD: one ratio for every step (PL restricted to equal ratios)."""
        g = self._grid(delta)
        mesh = np.repeat(g[:, None], self.n, axis=1)
        t = self.estimate_batch(mesh)
        i = int(np.argmin(t))
        return float(g[i]), float(t[i])

    def optimize_ol(self) -> tuple[np.ndarray, float]:
        """OL: each step wholly on C (r=1) or wholly on G (r=0): 2^n plans."""
        plans = np.array(list(itertools.product([0.0, 1.0], repeat=self.n)))
        t = self.estimate_batch(plans)
        i = int(np.argmin(t))
        return plans[i], float(t[i])

    def scheme_sweep(self, delta: float = 0.05,
                     schemes: tuple[str, ...] | None = None
                     ) -> dict[str, tuple[np.ndarray, float]]:
        """Best ratio assignment + estimate per named scheme (§3.2).

        Returns ``{scheme: (ratios, est_s)}`` over the requested subset of
        CPU_ONLY / GPU_ONLY / OL / DD / PL — the engine's planner picks the
        argmin per query instead of taking hard-coded knobs.
        """
        out: dict[str, tuple[np.ndarray, float]] = {}
        want = schemes or ("CPU_ONLY", "GPU_ONLY", "OL", "DD", "PL")
        ones = np.ones(self.n)
        if "CPU_ONLY" in want:
            out["CPU_ONLY"] = (ones, float(self.estimate_batch(ones)[0]))
        if "GPU_ONLY" in want:
            zeros = np.zeros(self.n)
            out["GPU_ONLY"] = (zeros, float(self.estimate_batch(zeros)[0]))
        if "OL" in want:
            r, t = self.optimize_ol()
            out["OL"] = (r, t)
        if "DD" in want:
            r, t = self.optimize_dd(delta=delta)
            out["DD"] = (np.full(self.n, r), t)
        if "PL" in want:
            r, t = self.optimize_pl(delta=delta)
            out["PL"] = (r, t)
        return out

    def monte_carlo(self, num: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """Random ratio assignments + their estimates (paper Fig. 9)."""
        rng = np.random.default_rng(seed)
        ratios = rng.uniform(0.0, 1.0, size=(num, self.n))
        return ratios, self.estimate_batch(ratios)


def series_model_from_costs(steps, x, device_c: DeviceSpec,
                            device_g: DeviceSpec, link: LinkSpec,
                            *, discrete: bool = False,
                            u_overrides: dict | None = None) -> SeriesCostModel:
    """Build a model from StepCost seeds, optionally overridden by measured
    per-step unit costs from calibrate.py (paper §4.2 instantiation)."""
    names = [s.name for s in steps]
    u_c = np.array([device_c.unit_cost(s.cost) for s in steps])
    u_g = np.array([device_g.unit_cost(s.cost) for s in steps])
    if u_overrides:
        for i, nm in enumerate(names):
            if nm in u_overrides:
                u_c[i], u_g[i] = u_overrides[nm]
    out_bytes = np.array([s.cost.out_bytes_per_item for s in steps])
    return SeriesCostModel(names, u_c, u_g, np.asarray(x, dtype=np.float64),
                           out_bytes, link, discrete=discrete)
