from .engine import (make_prefill_step, make_decode_step, abstract_cache,
                     ServeEngine)
