"""Serving: prefill/decode step factories + a batched request engine.

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a seq_len-sized KV/SSM cache.  KV caches are
sequence-sharded over the model axis when KV heads don't divide it
(flash-decode-style partial-softmax combine is inserted by SPMD).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import ShardingRules, shard_ctx
from repro.models import transformer as tfm
from repro.models.params import abstract, shardings


def make_prefill_step(cfg: ModelConfig, mesh, rules: ShardingRules):
    def prefill_step(params, batch):
        with shard_ctx(mesh, rules):
            logits, cache = tfm.prefill(params, cfg, batch["tokens"],
                                        batch.get("enc_frames"))
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, rules: ShardingRules):
    def decode_step(params, cache, tokens, cache_len):
        with shard_ctx(mesh, rules):
            logits, new_cache = tfm.decode_step(params, cfg, tokens, cache,
                                                cache_len)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_cache
    return decode_step


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int, mesh, rules):
    specs = tfm.cache_specs(cfg, batch, s_max)
    sh = shardings(specs, mesh, rules)
    return abstract(specs, jnp.dtype(cfg.dtype), shardings_tree=sh)


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched serving loop (greedy decoding) for the examples."""

    cfg: ModelConfig
    params: dict
    max_seq: int

    def generate(self, prompts: jax.Array, num_new: int,
                 enc_frames=None) -> jax.Array:
        """prompts: (B, P) int32 -> (B, P + num_new)."""
        cfg = self.cfg
        logits, cache = tfm.prefill(self.params, cfg, prompts, enc_frames)
        # Grow attention caches to max_seq capacity.
        from jax.tree_util import tree_map_with_path

        def grow(path, x):
            names = [str(getattr(p, "key", "")) for p in path]
            if any(n in ("k", "v") for n in names):
                ax = x.ndim - 3
                pad = [(0, 0)] * x.ndim
                pad[ax] = (0, self.max_seq - x.shape[ax])
                return jnp.pad(x, pad)
            return x

        cache = tree_map_with_path(grow, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [prompts, tok]
        step = jax.jit(lambda p, c, t, n: tfm.decode_step(cfg=cfg, params=p,
                                                          tokens=t, cache=c,
                                                          cache_len=n))
        cache_len = prompts.shape[1]
        for _ in range(num_new - 1):
            logits, cache = step(self.params, cache, tok,
                                 jnp.int32(cache_len))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
            cache_len += 1
        return jnp.concatenate(out, axis=1)
