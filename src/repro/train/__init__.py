from .step import (make_train_step, make_eval_step, loss_fn,
                   batch_specs, abstract_batch)
