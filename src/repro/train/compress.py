"""Error-feedback int8 gradient compression for the DCN ("pod") axis.

At multi-pod scale the pod-axis all-reduce crosses DCN (PCIe-class — the
paper's "discrete" regime), so coarse-grained, compressed communication is
the right grain there (the paper's own discrete-architecture conclusion).

Under pjit we cannot splice a custom collective into XLA's all-reduce, so
compression is expressed as quantize -> (implicit all-reduce in the update)
-> dequantize with an error-feedback residual carried in f32.  The
``shard_map`` variant (``ef_int8_psum``) performs the real int8 psum over
the pod axis for shard_map-based training loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_allreduce_sim(grads):
    """Quantize-dequantize each gradient leaf (error feedback is carried by
    the caller across steps when used in the loop; stateless form here)."""
    def qd(g):
        gf = g.astype(jnp.float32)
        q, s = _quant_int8(gf)
        return (q.astype(jnp.float32) * s).astype(g.dtype)
    return jax.tree.map(qd, grads)


def ef_int8_psum(grads, residual, axis_name: str = "pod"):
    """shard_map form: int8 psum over the DCN axis with error feedback.

    Returns (decompressed grads, new residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = _quant_int8(gf)
        deq = q.astype(jnp.float32) * s
        new_r = gf - deq
        summed = jax.lax.psum(deq, axis_name)
        return summed.astype(g.dtype), new_r
    out = jax.tree.map(one, grads, residual)
    return (jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple)))
