"""Train/eval step factories: sharded loss + grad + AdamW, with optional
gradient accumulation and pod-axis (DCN) gradient compression.

``make_train_step(cfg, mesh, rules, opt)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` ready for
``jax.jit`` with in/out shardings from the ParamSpec trees.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import (ShardingRules, axes_to_spec,
                                        shard_ctx)
from repro.models import transformer as tfm
from repro.models.params import ParamSpec
from repro.optim.adamw import AdamWConfig, adamw_update

AUX_LOSS_WEIGHT = 0.01


XENT_CHUNK = 8  # sequence chunks for the blockwise loss


def chunked_xent(embed_params, h, labels, vocab_size: int):
    """Blockwise softmax cross-entropy: logits exist only one sequence
    chunk at a time (f32 (B, S/k, V) instead of (B, S, V) — the 200k-vocab
    archs would otherwise spend >10 GiB/device on loss temps)."""
    b, s, _ = h.shape
    k = XENT_CHUNK if s % XENT_CHUNK == 0 else 1
    hs = h.reshape(b, k, s // k, h.shape[-1]).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, k, s // k).transpose(1, 0, 2)

    def one(args):
        hc, lc = args
        from repro.layers.core import logits_fn
        logits = logits_fn(embed_params, hc, vocab_size).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return (nll * mask).sum(), mask.sum()

    one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    nll_sum, tok_sum = jax.lax.map(one, (hs, ls))
    return nll_sum.sum(), tok_sum.sum()


def loss_fn(params, cfg: ModelConfig, batch):
    h, aux = tfm.forward_hidden(params, cfg, batch["tokens"],
                                batch.get("enc_frames"))
    nll, ntok = chunked_xent(params["embed"], h, batch["labels"],
                             cfg.vocab_size)
    loss = nll / jnp.maximum(ntok, 1.0)
    total = loss + AUX_LOSS_WEIGHT * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": ntok}


def make_train_step(cfg: ModelConfig, mesh, rules: ShardingRules,
                    opt: AdamWConfig, *, accum_steps: int = 1,
                    compress_pod_grads: bool = False):
    """Build the train step (microbatched when accum_steps > 1)."""

    def train_step(params, opt_state, batch):
        with shard_ctx(mesh, rules):
            if accum_steps == 1:
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg, batch)
            else:
                # Microbatch scan: per-microbatch grads accumulate in f32;
                # XLA overlaps each microbatch's collectives with the next
                # microbatch's compute (latency-hiding scheduler).
                def micro(carry, mb):
                    acc, met = carry
                    (_, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, cfg, mb)
                    acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), acc, g)
                    met = jax.tree.map(lambda a, b: a + b, met, m)
                    return (acc, met), 0

                mbs = jax.tree.map(
                    lambda x: x.reshape((accum_steps,
                                         x.shape[0] // accum_steps)
                                        + x.shape[1:]), batch)
                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                zero_m = {k: jnp.float32(0.0)
                          for k in ("loss", "aux_loss", "tokens")}
                (grads, metrics), _ = jax.lax.scan(micro, (zero_g, zero_m),
                                                   mbs)
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
                metrics = {k: v / accum_steps for k, v in metrics.items()}
            if compress_pod_grads:
                from repro.train.compress import ef_int8_allreduce_sim
                grads = ef_int8_allreduce_sim(grads)
            new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                                   opt)
            metrics.update(om)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, mesh, rules: ShardingRules):
    def eval_step(params, batch):
        with shard_ctx(mesh, rules):
            _, metrics = loss_fn(params, cfg, batch)
        return metrics
    return eval_step


# --------------------------------------------------------------------------
# Batch specs (ShapeDtypeStructs for the dry-run; see launch/dryrun.py).
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": ParamSpec((b, s), ("batch", "seq"), dtype="int32"),
        "labels": ParamSpec((b, s), ("batch", "seq"), dtype="int32"),
    }
    if cfg.encoder:
        out["enc_frames"] = ParamSpec(
            (b, cfg.encoder.num_frames, cfg.d_model),
            ("batch", None, None), dtype=cfg.dtype)
    return out


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec, mesh, rules):
    specs = batch_specs(cfg, shape)
    return {
        k: jax.ShapeDtypeStruct(
            v.shape, jnp.dtype(v.dtype),
            sharding=jax.sharding.NamedSharding(
                mesh, axes_to_spec(v.axes, v.shape, rules, mesh)))
        for k, v in specs.items()
    }
