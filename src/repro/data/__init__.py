from .pipeline import SyntheticLM, make_batch
