"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step): any host can regenerate any
shard, which is the straggler/elasticity story — a replacement host joining
mid-run rebuilds its input stream from the step counter alone (DESIGN.md
§5 fault tolerance).  The "dataset" is a mixture of Zipf-distributed tokens
and a repeated-ngram structure so the loss actually decreases.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, *, host_index: int = 0,
              host_count: int = 1) -> dict:
        """Host-sharded batch for ``step`` (numpy, ready to device_put)."""
        per_host = self.global_batch // host_count
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + host_index)
        zipf = rng.zipf(1.3, size=(per_host, self.seq_len))
        tokens = np.minimum(zipf, self.vocab_size - 1).astype(np.int32)
        # inject learnable structure: periodic ngrams
        period = 16
        base = rng.integers(0, self.vocab_size, size=(per_host, period))
        idx = np.arange(self.seq_len) % period
        structured = base[:, idx]
        mix = rng.random((per_host, self.seq_len)) < 0.7
        tokens = np.where(mix, structured, tokens).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}


def make_batch(cfg, shape, step: int = 0, *, enc: bool = False) -> dict:
    """Concrete batch for smoke tests / examples (small sizes only)."""
    ds = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch)
    b = ds.batch(step)
    out = {"tokens": jnp.asarray(b["tokens"]),
           "labels": jnp.asarray(b["labels"])}
    if cfg.encoder:
        rng = np.random.default_rng(step)
        out["enc_frames"] = jnp.asarray(
            rng.standard_normal((shape.global_batch,
                                 cfg.encoder.num_frames,
                                 cfg.d_model)) * 0.02, dtype=jnp.bfloat16)
    return out
