"""Production mesh construction (TPU v5e pods).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses DCN, the paper's discrete-architecture regime, so only
coarse-grained (DP / compressed-gradient) communication is mapped to it.

A function, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes, devices=None):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    axis_types / AxisType only exist on newer jax; explicit Auto is the
    default there, so older versions just omit it.
    """
    kw = {"devices": devices} if devices is not None else {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = model or 1
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"))


# v5e hardware constants (per chip) — used by roofline + cost model.
HW = {
    "peak_bf16_flops": 197e12,
    "hbm_bw": 819e9,
    "ici_link_bw": 50e9,          # per link
    "dcn_bw": 3.2e9,              # per host, pod-to-pod
    "hbm_bytes": 16 * 1024 ** 3,
}
