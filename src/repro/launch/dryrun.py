import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent at production
scale (no sharding mismatches, no unsupported collectives, memory fits) and
extracts the roofline inputs:

  * compiled.memory_analysis()  -> per-device bytes (argument/output/temp)
  * compiled.cost_analysis()    -> HLO FLOPs + bytes accessed
  * compiled.as_text()          -> per-collective moved bytes (parsed)

Artifacts land in reports/dryrun/<arch>__<shape>__<mesh>.json; the roofline
table (EXPERIMENTS.md §Roofline) is generated from them by
``python -m benchmarks.roofline``.

Usage:
  python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--and-multi-pod]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_configs, get_config, runnable
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        ShardingRules)
from repro.launch.mesh import HW, make_production_mesh
from repro.models import transformer as tfm
from repro.models.params import ParamSpec, abstract, shardings
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import abstract_cache, make_decode_step, \
    make_prefill_step
from repro.train.step import abstract_batch, make_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8,
                "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_collectives(hlo: str) -> list[dict]:
    """Per-collective: dtype, per-device result elements, group size."""
    out = []
    for m in _COLL_RE.finditer(hlo):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n_el = 1
        for d in dims.split(","):
            if d:
                n_el *= int(d)
        line = m.group(0)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = int(gi.group(2)) if gi else 2
        out.append({"kind": kind, "dtype": dt,
                    "bytes": n_el * _DTYPE_BYTES[dt], "group": gsize})
    return out


def collective_link_bytes(colls: list[dict]) -> float:
    """Per-chip bytes crossing ICI links (ring cost model, DESIGN.md §8).

    ``bytes`` is the op's per-device RESULT size parsed from the HLO, so
    ring factors differ per kind: an all-gather result is the big gathered
    buffer (receive (n-1)/n of it), a reduce-scatter result is the small
    shard (send (n-1) shards), an all-reduce moves 2(n-1)/n of its buffer.
    """
    total = 0.0
    for c in colls:
        n = max(c["group"], 2)
        factor = {"all-gather": (n - 1) / n,
                  "reduce-scatter": (n - 1),
                  "all-to-all": (n - 1) / n,
                  "collective-permute": 1.0,
                  "all-reduce": 2 * (n - 1) / n}[c["kind"]]
        total += c["bytes"] * factor
    return total


def _opt_abstract(cfg, params_spec, mesh, rules, opt: AdamWConfig):
    sdt = jnp.dtype(opt.state_dtype)
    mu_spec = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.axes, dtype=opt.state_dtype),
        params_spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    sh = shardings(mu_spec, mesh, rules)
    mu = abstract(mu_spec, sdt, shardings_tree=sh)
    return {"mu": mu, "nu": mu,
            "step": jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))}


def serve_rules_for(cfg, mesh) -> ShardingRules:
    """Replicate-vs-FSDP weights at serving time: keep FSDP ("data") on the
    weights only when TP alone cannot fit them in HBM (cost-model-style
    decision; llama4-400B needs it, 8B models do not)."""
    model_ways = mesh.shape.get("model", 1)
    per_dev = cfg.param_count() * 2 / model_ways
    if per_dev > 0.5 * HW["hbm_bytes"]:
        return TRAIN_RULES  # includes fsdp->data
    return SERVE_RULES


def _variant(cfg, k: int):
    """Same architecture with k pattern units (for scan-cost extrapolation:
    XLA's cost_analysis counts a while-loop body once, so the full model's
    FLOPs/bytes/collectives are F(1) + (U-1)*(F(2)-F(1)))."""
    import dataclasses as dc
    kw = {"num_layers": k * len(cfg.pattern_unit) + len(cfg.tail),
          "scan_layers": False}
    if cfg.encoder:
        from repro.configs.base import EncoderCfg
        kw["encoder"] = EncoderCfg(num_layers=k,
                                   num_frames=cfg.encoder.num_frames)
    return dc.replace(cfg, **kw)


def _build_lowered(cfg, shape, mesh, rules, opt_dtype):
    """Lower one step function for (cfg, shape) on mesh."""
    params_spec = tfm.param_specs(cfg)
    if shape.kind == "train":
        rules = rules or TRAIN_RULES
        # bf16 moments when fp32 states cannot fit (the 400B config).
        if opt_dtype is None:
            opt_dtype = ("bfloat16" if cfg.param_count() * 16
                         / mesh.devices.size > 0.6 * HW["hbm_bytes"]
                         else "float32")
        opt = AdamWConfig(state_dtype=opt_dtype)
        psh = shardings(params_spec, mesh, rules)
        params = abstract(params_spec, jnp.dtype(cfg.dtype),
                          shardings_tree=psh)
        opt_state = _opt_abstract(cfg, params_spec, mesh, rules, opt)
        batch = abstract_batch(cfg, shape, mesh, rules)
        # Unrolled cost-extrapolation variants run accum=1 so measured
        # FLOPs are the true whole-batch cost; the real artifact uses the
        # config's microbatching (what makes the 400B fit per-device HBM).
        accum = cfg.train_accum if cfg.scan_layers else 1
        step_fn = make_train_step(cfg, mesh, rules, opt, accum_steps=accum)
        with mesh:
            return jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                params, opt_state, batch)
    rules = rules or serve_rules_for(cfg, mesh)
    psh = shardings(params_spec, mesh, rules)
    params = abstract(params_spec, jnp.dtype(cfg.dtype), shardings_tree=psh)
    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape, mesh, rules)
        batch.pop("labels")
        step_fn = make_prefill_step(cfg, mesh, rules)
        with mesh:
            return jax.jit(step_fn).lower(params, batch)
    # decode
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len,
                           mesh, rules)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    from repro.distributed.sharding import axes_to_spec
    tok_sh = jax.sharding.NamedSharding(
        mesh, axes_to_spec(("batch", None), (shape.global_batch, 1),
                           rules, mesh))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                  sharding=tok_sh)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
    step_fn = make_decode_step(cfg, mesh, rules)
    with mesh:
        return jax.jit(step_fn, donate_argnums=(1,)).lower(
            params, cache, tokens, cache_len)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: older jax returns
    a one-element list of dicts, newer returns the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cost_of(compiled):
    cost = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "link_bytes": collective_link_bytes(colls),
            "colls": colls}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               opt_dtype: str | None = None, rules=None,
               extrapolate: bool = True, cfg=None, tag: str | None = None):
    """Lower + compile one cell; returns the report dict."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "why": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, rules, opt_dtype)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    full = _cost_of(compiled)

    # Scan-body extrapolation: compile 1-unit and 2-unit variants; the
    # full model's FLOPs/bytes/link-bytes = F1 + (U-1)*(F2-F1).
    u = cfg.num_units
    extra = {}
    if extrapolate and u > 2:
        f1 = _cost_of(_build_lowered(_variant(cfg, 1), shape, mesh, rules,
                                     opt_dtype).compile())
        f2 = _cost_of(_build_lowered(_variant(cfg, 2), shape, mesh, rules,
                                     opt_dtype).compile())
        for key in ("flops", "bytes", "link_bytes"):
            per_unit = max(0.0, f2[key] - f1[key])
            extra[key] = f1[key] + (u - 1) * per_unit
        extra["per_unit_flops"] = max(0.0, f2["flops"] - f1["flops"])
    else:
        extra = {k: full[k] for k in ("flops", "bytes", "link_bytes")}
        extra["per_unit_flops"] = 0.0

    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "kind": shape.kind,
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": extra["flops"],
        "bytes_accessed_per_device": extra["bytes"],
        "flops_per_device_raw": full["flops"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": {
            "count": len(full["colls"]),
            "per_chip_link_bytes": extra["link_bytes"],
            "per_chip_link_bytes_raw": full["link_bytes"],
            "by_kind": _by_kind(full["colls"]),
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch),
    }
    if tag:
        report["tag"] = tag
    return report


def _by_kind(colls):
    out: dict = {}
    for c in colls:
        k = out.setdefault(c["kind"], {"count": 0, "bytes": 0})
        k["count"] += 1
        k["bytes"] += c["bytes"]
    return out


def save_report(rep: dict):
    os.makedirs(REPORT_DIR, exist_ok=True)
    name = f"{rep['arch']}__{rep['shape']}__{rep.get('mesh', 'skip')}.json"
    with open(os.path.join(REPORT_DIR, name), "w") as f:
        json.dump(rep, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--and-multi-pod", action="store_true",
                    help="run each cell on both meshes")
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else list(all_configs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    meshes = [args.multi_pod] if not args.and_multi_pod else [False, True]
    failures = 0
    for a, s in cells:
        for mp in meshes:
            tag = f"{a} x {s} [{'2x16x16' if mp else '16x16'}]"
            try:
                rep = lower_cell(a, s, multi_pod=mp)
                save_report(rep)
                if rep["status"] == "skipped":
                    print(f"SKIP {tag}: {rep['why']}")
                    break  # same skip on both meshes
                m = rep["memory"]
                per_dev_gb = (m["argument_bytes"] + m["temp_bytes"]
                              + m["output_bytes"] - m["alias_bytes"]) / 2**30
                print(f"OK   {tag}: compile={rep['compile_s']}s "
                      f"flops/dev={rep['flops_per_device']:.3e} "
                      f"mem/dev={per_dev_gb:.2f}GiB "
                      f"coll={rep['collectives']['count']}")
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
