"""Serving driver: batched greedy generation on a smoke-scale model.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_2_7b --smoke \
      --prompt-len 32 --new-tokens 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.models import transformer as tfm
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch.replace("-", "_"))
    if args.smoke:
        cfg = reduced(cfg)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32))
    enc = None
    if cfg.encoder:
        enc = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder.num_frames, cfg.d_model)) * 0.02,
            dtype=jnp.dtype(cfg.dtype))
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.new_tokens + 8)
    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens, enc_frames=enc)
    jax.block_until_ready(out)
    dt = time.time() - t0
    n_new = args.batch * args.new_tokens
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
