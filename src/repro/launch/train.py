"""Training driver: real runs on host devices, production flags for pods.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt

On a real pod, XLA latency-hiding flags below overlap the FSDP/SP
collectives with compute (the §Perf overlap lever); on CPU they are inert.
Fault tolerance: periodic checkpoints, SIGTERM flush, resume-from-latest,
deterministic host-sharded data (any host can rebuild any shard).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true")
if os.environ.get("REPRO_TPU_FLAGS", "0") == "1":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + TPU_PERF_FLAGS)

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import ShapeSpec, get_config, reduced
    from repro.data.pipeline import SyntheticLM
    from repro.distributed.sharding import TRAIN_RULES
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tfm
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import make_train_step

    cfg = get_config(args.arch.replace("-", "_"))
    if args.smoke:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, train_accum=args.accum)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    mesh = make_host_mesh()
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={mesh.shape} "
          f"tokens/step={args.batch * args.seq_len}")

    step_fn = jax.jit(make_train_step(
        cfg, mesh, TRAIN_RULES, opt, accum_steps=args.accum,
        compress_pod_grads=args.compress_pod_grads))
    ds = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch)
    mgr = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every) \
        if args.ckpt_dir else None

    start = 0
    if mgr:
        restored, start = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            print(f"resumed from step {start}")

    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        hb = ds.batch(step)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        if cfg.encoder:
            rng = np.random.default_rng(step)
            batch["enc_frames"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.encoder.num_frames,
                                     cfg.d_model)) * 0.02,
                dtype=jnp.dtype(cfg.dtype))
        params, opt_state, m = step_fn(params, opt_state, batch)
        tokens_done += args.batch * args.seq_len
        if step % args.log_every == 0 or step == args.steps - 1:
            jax.block_until_ready(m["loss"])
            dt = time.time() - t0
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"tok/s={tokens_done / max(dt, 1e-9):,.0f}")
        if mgr and (mgr.maybe_save(step + 1, {"params": params,
                                              "opt": opt_state})
                    and mgr.preempted):
            print("preemption checkpoint flushed; exiting")
            return
    print(f"done: {args.steps} steps, final loss "
          f"{float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
