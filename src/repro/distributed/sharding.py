"""Logical-axis sharding rules (MaxText-style), with divisibility fallback.

Tensors are annotated with *logical* axis names; a rule table maps each
logical axis to an ordered list of candidate mesh axes.  The engine assigns,
in *priority* order (not tensor-dim order), the first candidate mesh axis
that (a) divides the dimension and (b) is not already used by the tensor.
This is what makes one config system serve all 10 architectures:

  * 40-head archs (qwen2.5, llama4, whisper): "heads" fails 16-way TP, so
    the engine falls through to sequence ("q_seq") or "head_dim" sharding —
    the cost-model-guided knob discussed in DESIGN.md §3.2.
  * 8-KV-head GQA decode: "kv_heads" fails, so KV caches shard on
    "cache_seq" (flash-decode style combine is inserted by SPMD).
  * granite's 40 experts fail expert-parallel 16-way, so expert weights fall
    back to TP over the expert FFN dim ("expert_mlp").

Parameters use "fsdp" on their d_model dim -> the "data" axis (ZeRO-3:
weights stream per layer inside the scan), and "model" on their TP dim.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Ordered (priority, logical_axis -> mesh-axis candidates) table."""

    rules: tuple[tuple[str, tuple[str, ...]], ...]

    def candidates(self, name: str) -> tuple[str, ...]:
        for k, v in self.rules:
            if k == name:
                return v
        return ()

    def priority(self, name: str) -> int:
        for i, (k, _) in enumerate(self.rules):
            if k == name:
                return i
        return len(self.rules)


# Priority order matters: e.g. "heads" grabs the model axis before "q_seq".
TRAIN_RULES = ShardingRules((
    ("batch", ("pod", "data")),
    ("experts", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("mlp", ("model",)),
    ("expert_mlp", ("model",)),
    ("vocab", ("model",)),
    ("ssm_heads", ("model",)),
    # NOTE: no head_dim/q_seq fallback for non-16-divisible head
    # counts (qwen2.5/llama4: 40H, whisper: 20H, granite: 24H): head_dim
    # sharding makes GSPMD psum full score tensors, and q_seq sharding
    # defeats q-chunking.  Baseline replicates their attention over the
    # model axis (bounded by q-chunking); fixing this is a designated
    # §Perf hillclimb (EXPERIMENTS.md).
    ("q_seq", ()),
    ("head_dim", ()),
    ("expert_cap", ("model",)),  # expert capacity dim when experts don't
    ("fsdp", ("data",)),        # ZeRO-3 dim of parameters
    ("ssm_state", ()),
    ("conv", ()),
    ("seq", ("model",)),        # SP: residual stream sequence-sharded
    ("layers", ()),
    ("moe_group", ("pod", "data")),
))

# §Perf alternative (beyond the baseline TP+SP layout): pure HSDP — the
# batch shards over BOTH mesh axes (1 sequence/chip at global batch 256),
# weights are ZeRO-3 sharded on their fsdp/TP dims and re-gathered per
# layer.  Hypothesis (EXPERIMENTS.md §Perf): per-chip collective volume
# becomes ~3x params_bytes (weight AG fwd+bwd + grad RS) instead of the
# TP+SP activation round-trips, and replicated-head attention waste
# disappears because every chip attends only over its own sequences.
DP_RULES = ShardingRules((
    ("batch", ("pod", "data", "model")),
    ("experts", ("model",)),
    ("heads", ()),              # no TP: attention is batch-local
    ("kv_heads", ()),
    ("mlp", ("model",)),        # weight-shard dim (gathered per layer)
    ("expert_mlp", ("model",)),
    ("vocab", ("model",)),
    ("ssm_heads", ()),
    ("q_seq", ()),
    ("head_dim", ()),
    ("expert_cap", ()),
    ("fsdp", ("data",)),
    ("ssm_state", ()),
    ("conv", ()),
    ("seq", ()),
    ("layers", ()),
    ("moe_group", ("pod", "data", "model")),
))

SERVE_RULES = ShardingRules((
    ("batch", ("pod", "data")),
    ("experts", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("cache_seq", ("model",)),  # KV cache sequence sharding (flash-decode)
    ("mlp", ("model",)),
    ("expert_mlp", ("model",)),
    ("vocab", ("model",)),
    ("ssm_heads", ("model",)),
    ("q_seq", ()),
    ("head_dim", ()),
    ("expert_cap", ("model",)),
    ("fsdp", ()),               # weights stay TP-only at serving time
    ("ssm_state", ()),
    ("conv", ()),
    ("seq", ("model",)),
    ("layers", ()),
    ("moe_group", ("pod", "data")),
))


def axes_to_spec(axes: tuple[str | None, ...], dims: tuple[int, ...],
                 rules: ShardingRules, mesh: Mesh) -> P:
    """Assign mesh axes to tensor dims by rule priority with divisibility."""
    assert len(axes) == len(dims), (axes, dims)
    assignment: dict[int, tuple[str, ...]] = {}
    used: set[str] = set()
    order = sorted((i for i, a in enumerate(axes) if a),
                   key=lambda i: rules.priority(axes[i]))
    for i in order:
        got: list[str] = []
        size = dims[i]
        for cand in rules.candidates(axes[i]):
            if cand in used or cand not in mesh.shape:
                continue
            if size % mesh.shape[cand] == 0 and size > 0:
                got.append(cand)
                used.add(cand)
                size //= mesh.shape[cand]
        if got:
            assignment[i] = tuple(got)
    return P(*[assignment.get(i, None) if i not in assignment
               else (assignment[i][0] if len(assignment[i]) == 1
                     else assignment[i])
               for i in range(len(axes))])


# --------------------------------------------------------------------------
# Context: current mesh + rules, so layers can annotate activations.
# --------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def shard_ctx(mesh: Mesh | None, rules: ShardingRules | None):
    prev = getattr(_ctx, "val", None)
    _ctx.val = (mesh, rules)
    try:
        yield
    finally:
        _ctx.val = prev


def current_mesh() -> Mesh | None:
    v = getattr(_ctx, "val", None)
    return v[0] if v else None


def current_rules() -> ShardingRules | None:
    v = getattr(_ctx, "val", None)
    return v[1] if v else None


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a context)."""
    mesh, rules = (getattr(_ctx, "val", None) or (None, None))
    if mesh is None or rules is None:
        return x
    spec = axes_to_spec(tuple(axes), tuple(x.shape), rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_sharding(mesh: Mesh, rules: ShardingRules,
                  axes: tuple[str | None, ...],
                  dims: tuple[int, ...]) -> NamedSharding:
    return NamedSharding(mesh, axes_to_spec(axes, dims, rules, mesh))


def spec_for_tree(axes_tree, shape_tree, rules: ShardingRules, mesh: Mesh):
    """Map a tree of logical-axis tuples + shapes to NamedShardings."""
    return jax.tree.map(
        lambda axes, shp: NamedSharding(
            mesh, axes_to_spec(tuple(axes), tuple(shp.shape), rules, mesh)),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
