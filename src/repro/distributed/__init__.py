from .sharding import (ShardingRules, TRAIN_RULES, SERVE_RULES, axes_to_spec,
                       shard_ctx, shard, current_mesh, current_rules,
                       make_sharding, spec_for_tree)
