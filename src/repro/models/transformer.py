"""Decoder-LM assembly for all 10 architectures (+ enc-dec wrapper).

The model is one ``lax.scan`` over stacked *pattern units* (configs/base.py)
with optional unrolled tail blocks — compile time stays O(unit), not
O(layers), which is what makes the 40-cell dry-run tractable.

Three entry modes:
  * ``forward_train`` — full-sequence causal, returns (logits, aux_loss)
  * ``prefill``       — same math, also returns the serving cache
  * ``decode_step``   — one token against the cache (KV / SSM state)

Everything (params, caches) is specified as ParamSpec trees first, so the
dry-run can lower any architecture at full scale without allocating.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.layers import attention as attn
from repro.layers import moe as moe_lib
from repro.layers import ssd
from repro.layers.core import (embed, embed_specs, logits_fn, mlp, mlp_specs,
                               rmsnorm, rmsnorm_spec)
from .params import ParamSpec, abstract, materialize


# --------------------------------------------------------------------------
# Parameter specs.
# --------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, char: str, *, cross: bool = False) -> dict:
    if char == "M":
        return {"ln": rmsnorm_spec(cfg.d_model),
                "mamba": ssd.ssd_specs(cfg)}
    out = {
        "ln1": rmsnorm_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "ln2": rmsnorm_spec(cfg.d_model),
    }
    if char == "E":
        out["moe"] = moe_lib.moe_specs(cfg)
    else:
        out["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff)
    if cross:
        out["ln_cross"] = rmsnorm_spec(cfg.d_model)
        out["cross"] = attn.attn_specs(cfg, cross=True)
    return out


def _stack(spec_tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale, dtype=s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> dict:
    cross = cfg.is_enc_dec
    specs: dict = {"embed": embed_specs(cfg),
                   "final_norm": rmsnorm_spec(cfg.d_model)}
    unit = {f"{j}{c}": block_specs(cfg, c, cross=cross)
            for j, c in enumerate(cfg.pattern_unit)}
    specs["unit"] = _stack(unit, cfg.num_units)
    if cfg.tail:
        specs["tail"] = {f"{j}{c}": block_specs(cfg, c, cross=cross)
                         for j, c in enumerate(cfg.tail)}
    if cfg.encoder:
        enc_unit = {"0D": block_specs(cfg, "D")}
        specs["encoder"] = {"unit": _stack(enc_unit, cfg.encoder.num_layers),
                            "final_norm": rmsnorm_spec(cfg.d_model)}
    return specs


def init_params(cfg: ModelConfig, key: jax.Array):
    return materialize(param_specs(cfg), key, jnp.dtype(cfg.dtype))


def abstract_params(cfg: ModelConfig, shardings_tree=None):
    return abstract(param_specs(cfg), jnp.dtype(cfg.dtype),
                    shardings_tree=shardings_tree)


# --------------------------------------------------------------------------
# Cache specs (serving).
# --------------------------------------------------------------------------

def _block_cache_specs(cfg: ModelConfig, char: str, batch: int, s_max: int,
                       *, cross: bool = False) -> dict:
    if char == "M":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        k = s.conv_kernel
        return {
            "ssm": ParamSpec((batch, nh, s.head_dim, s.d_state),
                             ("batch", "ssm_heads", None, None),
                             init="zeros", dtype="float32"),
            "conv_x": ParamSpec((batch, k - 1, d_in),
                                ("batch", None, "mlp"), init="zeros"),
            "conv_b": ParamSpec((batch, k - 1, s.d_state),
                                ("batch", None, None), init="zeros"),
            "conv_c": ParamSpec((batch, k - 1, s.d_state),
                                ("batch", None, None), init="zeros"),
        }
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    out = {"k": ParamSpec((batch, s_max, kv, hd),
                          ("batch", "cache_seq", "kv_heads", "head_dim"),
                          init="zeros"),
           "v": ParamSpec((batch, s_max, kv, hd),
                          ("batch", "cache_seq", "kv_heads", "head_dim"),
                          init="zeros")}
    if cross:
        f = cfg.encoder.num_frames
        out["ck"] = ParamSpec((batch, f, kv, hd),
                              ("batch", None, "kv_heads", "head_dim"),
                              init="zeros")
        out["cv"] = ParamSpec((batch, f, kv, hd),
                              ("batch", None, "kv_heads", "head_dim"),
                              init="zeros")
    return out


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    cross = cfg.is_enc_dec
    unit = {f"{j}{c}": _block_cache_specs(cfg, c, batch, s_max, cross=cross)
            for j, c in enumerate(cfg.pattern_unit)}
    specs = {"unit": _stack(unit, cfg.num_units)}
    if cfg.tail:
        specs["tail"] = {f"{j}{c}": _block_cache_specs(cfg, c, batch, s_max,
                                                       cross=cross)
                         for j, c in enumerate(cfg.tail)}
    return specs


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    return materialize(cache_specs(cfg, batch, s_max),
                       jax.random.PRNGKey(0), jnp.dtype(cfg.dtype))


# --------------------------------------------------------------------------
# Block forward.
# --------------------------------------------------------------------------

def _block_fwd(char: str, params: dict, cfg: ModelConfig, h: jax.Array,
               positions, mode: str, cache: dict | None,
               cache_len, enc_out):
    """One block.  Returns (h, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: dict = {}
    if char == "M":
        state = None
        if mode == "decode":
            state = {k: cache[k] for k in
                     ("ssm", "conv_x", "conv_b", "conv_c")}
        x = rmsnorm(h, params["ln"], cfg.rms_eps)
        # SP boundary: gather the sequence for the mixer, scatter after.
        x = shard(x, "batch", None, None)
        y, st = ssd.mamba_block(params["mamba"], cfg, x, state)
        y = shard(y, "batch", "seq", None)
        h = h + y
        new_cache = st
        return h, new_cache, aux

    x = rmsnorm(h, params["ln1"], cfg.rms_eps)
    # SP boundary (Megatron-SP): residual stream stays sequence-sharded;
    # attention sees the gathered sequence, its output reduce-scatters.
    x = shard(x, "batch", None, None)
    if mode == "decode":
        y, k_c, v_c = attn.decode_attention(params["attn"], cfg, x,
                                            cache["k"], cache["v"], cache_len)
        new_cache = {"k": k_c, "v": v_c}
        if "cross" in params:
            xc = rmsnorm(h + y, params["ln_cross"], cfg.rms_eps)
            y = y + attn.cross_attention(params["cross"], cfg, xc,
                                         (cache["ck"], cache["cv"]))
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
    else:
        y, (k_c, v_c) = attn.attention(params["attn"], cfg, x, positions,
                                       causal=(mode != "encode"))
        if mode == "prefill":
            new_cache = {"k": k_c, "v": v_c}
        if "cross" in params and enc_out is not None:
            ckv = attn.cross_kv(params["cross"], enc_out)
            xc = rmsnorm(h + y, params["ln_cross"], cfg.rms_eps)
            y = y + attn.cross_attention(params["cross"], cfg, xc, ckv)
            if mode == "prefill":
                new_cache["ck"], new_cache["cv"] = ckv
    y = shard(y, "batch", "seq", None)
    h = h + y
    x2 = rmsnorm(h, params["ln2"], cfg.rms_eps)
    if char == "E":
        y2, aux = moe_lib.moe(params["moe"], cfg, x2)
    else:
        y2 = mlp(params["mlp"], x2)
    return h + y2, new_cache, aux


# --------------------------------------------------------------------------
# Stacks.
# --------------------------------------------------------------------------

def _run_stack(params: dict, cfg: ModelConfig, h, positions, mode: str,
               cache, cache_len, enc_out, pattern_unit: str,
               want_cache: bool):
    """Scan over stacked units + unrolled tail."""

    def unit_body(carry, xs):
        hh, aux = carry
        unit_params, unit_cache = xs
        new_unit_cache = {}
        for j, c in enumerate(pattern_unit):
            key = f"{j}{c}"
            blk_cache = unit_cache.get(key) if unit_cache else None
            hh, nc, a = _block_fwd(c, unit_params[key], cfg, hh, positions,
                                   mode, blk_cache, cache_len, enc_out)
            new_unit_cache[key] = nc
            aux = aux + a
        hh = shard(hh, "batch", "seq", None)
        return (hh, aux), new_unit_cache

    body = unit_body
    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full" else
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(unit_body, policy=policy)

    if not cfg.scan_layers:
        # Unrolled variant (cost-analysis extrapolation in launch/dryrun).
        carry = (h, jnp.float32(0.0))
        caches_out = []
        for i in range(cfg.num_units):
            unit_p = jax.tree.map(lambda x: x[i], params["unit"])
            unit_c = (jax.tree.map(lambda x: x[i], cache["unit"])
                      if cache is not None else None)
            carry, nc = body(carry, (unit_p, unit_c))
            caches_out.append(nc)
        h, aux = carry
        new_unit_cache = (jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *caches_out)
                          if want_cache else None)
    elif cache is not None:
        (h, aux), new_unit_cache = jax.lax.scan(
            body, (h, jnp.float32(0.0)), (params["unit"], cache["unit"]))
    else:
        def body_nocache(carry, unit_params):
            out_carry, nc = body(carry, (unit_params, None))
            return out_carry, (nc if want_cache else 0)
        (h, aux), new_unit_cache = jax.lax.scan(
            body_nocache, (h, jnp.float32(0.0)), params["unit"])

    new_cache = {"unit": new_unit_cache} if want_cache else {}
    if "tail" in params:
        new_tail = {}
        for key, blk in params["tail"].items():
            c = key[-1]
            blk_cache = cache["tail"][key] if cache else None
            h, nc, a = _block_fwd(c, blk, cfg, h, positions, mode, blk_cache,
                                  cache_len, enc_out)
            new_tail[key] = nc
            aux = aux + a
        if want_cache:
            new_cache["tail"] = new_tail
    return h, aux, new_cache


def _encode(params: dict, cfg: ModelConfig, frames: jax.Array):
    """Whisper encoder over stub frontend embeddings (B, F, d)."""
    h = shard(frames, "batch", "seq", None)
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
    enc = params["encoder"]
    h, _, _ = _run_stack(enc, cfg, h, positions, "encode", None, None, None,
                         "D", want_cache=False)
    return rmsnorm(h, enc["final_norm"], cfg.rms_eps)


# --------------------------------------------------------------------------
# Entry points.
# --------------------------------------------------------------------------

def forward_hidden(params: dict, cfg: ModelConfig, tokens: jax.Array,
                   enc_frames: jax.Array | None = None):
    """tokens: (B, S) -> (final hidden states (B,S,d), aux_loss).

    The loss computes logits chunk-by-chunk from these (the 200k-vocab f32
    logits tensor is never materialized — see train.step.chunked_xent)."""
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_out = _encode(params, cfg, enc_frames.astype(dtype)) \
        if cfg.encoder else None
    h, aux, _ = _run_stack(params, cfg, h, positions, "train", None, None,
                           enc_out, cfg.pattern_unit, want_cache=False)
    return rmsnorm(h, params["final_norm"], cfg.rms_eps), aux


def forward_train(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  enc_frames: jax.Array | None = None):
    """tokens: (B, S) -> (logits (B,S,V), aux_loss)."""
    h, aux = forward_hidden(params, cfg, tokens, enc_frames)
    return logits_fn(params["embed"], h, cfg.vocab_size), aux


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            enc_frames: jax.Array | None = None):
    """Returns (last-position logits (B, V), cache)."""
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_out = _encode(params, cfg, enc_frames.astype(dtype)) \
        if cfg.encoder else None
    h, aux, cache = _run_stack(params, cfg, h, positions, "prefill", None,
                               None, enc_out, cfg.pattern_unit,
                               want_cache=True)
    h = rmsnorm(h, params["final_norm"], cfg.rms_eps)
    logits = logits_fn(params["embed"], h[:, -1:], cfg.vocab_size)
    return logits[:, 0], cache


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict, cache_len: jax.Array):
    """tokens: (B, 1); cache_len: scalar int32 (tokens already in cache).

    Returns (logits (B, V), new_cache)."""
    b, _ = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], tokens, dtype)
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    h, aux, new_cache = _run_stack(params, cfg, h, positions, "decode",
                                   cache, cache_len, None, cfg.pattern_unit,
                                   want_cache=True)
    h = rmsnorm(h, params["final_norm"], cfg.rms_eps)
    logits = logits_fn(params["embed"], h, cfg.vocab_size)
    return logits[:, 0], new_cache
