"""Parameter specs: single source of truth for shapes, init and sharding.

A model is described once as a tree of ``ParamSpec``; from it we derive
  * materialized parameters (``materialize`` — jax.random, for real runs),
  * abstract parameters (``abstract`` — ShapeDtypeStruct, for the dry-run:
    no allocation ever happens for the full-size configs),
  * shardings (``shardings`` — NamedSharding via the logical-axis engine).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, axes_to_spec
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]     # logical axes, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | scaled
    scale: float | None = None       # stddev override
    dtype: str | None = None         # override model dtype (e.g. f32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: tuple[int, ...]) -> int:
    # weights are (in_dims..., out_dims...); use the leading dim product
    # heuristic: all dims except the last group. For 2D (in, out) -> in.
    return int(np.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])


def materialize(spec_tree, key: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = jnp.dtype(spec.dtype) if spec.dtype else dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dt))
        else:
            scale = spec.scale if spec.scale is not None \
                else 1.0 / max(1.0, _fan_in(spec.shape)) ** 0.5
            out.append((jax.random.normal(k, spec.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract(spec_tree, dtype=jnp.bfloat16, *, shardings_tree=None):
    """ShapeDtypeStruct tree (optionally carrying shardings for .lower)."""
    if shardings_tree is None:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.dtype(s.dtype) if s.dtype else dtype),
            spec_tree, is_leaf=_is_spec)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype) if s.dtype else dtype, sharding=sh),
        spec_tree, shardings_tree, is_leaf=_is_spec)


def shardings(spec_tree, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: NamedSharding(mesh,
                                axes_to_spec(s.axes, s.shape, rules, mesh)),
        spec_tree, is_leaf=_is_spec)


def spec_bytes(spec_tree, bytes_per_el: int = 2) -> int:
    return sum(int(np.prod(s.shape)) * bytes_per_el
               for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec))
