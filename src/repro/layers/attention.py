"""GQA attention: qk-norm / qkv-bias variants, causal, cross, and decode.

The jnp path here is the distribution/dry-run path; the Pallas flash
kernel (``repro.kernels.flash_attn``) is the TPU compute path, selected by
``cfg.use_pallas`` and validated against this math in tests.

Sharding: q/k/v activations carry logical axes ("batch","seq","heads"/
"kv_heads","head_dim"); on archs whose head counts don't divide the model
axis, the rule engine falls through to sequence or head_dim sharding
(see repro.distributed.sharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.params import ParamSpec
from .core import apply_rope, rmsnorm, rmsnorm_spec

NEG_INF = jnp.float32(-1e9)


def attn_specs(cfg, *, cross: bool = False) -> dict:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    out = {
        "wq": ParamSpec((d, h, hd), ("fsdp", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        out["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        out["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                              init="zeros")
        out["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                              init="zeros")
    if cfg.qk_norm and not cross:
        out["q_norm"] = rmsnorm_spec(hd)
        out["k_norm"] = rmsnorm_spec(hd)
    return out


def _project_qkv(params, cfg, x, positions, *, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, params["k_norm"], cfg.rms_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", "head_dim")
    k = shard(k, "batch", None, "kv_heads", "head_dim")
    v = shard(v, "batch", None, "kv_heads", "head_dim")
    return q, k, v


def _sdpa(q, k, v, mask, num_kv: int):
    """Grouped scaled-dot-product attention (single shot).

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D); mask: broadcastable to
    (B, 1, 1, Sq, Sk) or None.
    """
    b, sq, h, d = q.shape
    g = h // num_kv
    qg = q.reshape(b, sq, num_kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, sq, h, d)


def _sdpa_chunked(q, k, v, num_kv: int, *, causal: bool, q_block: int):
    """Q-chunked attention: scores exist only (B, H, q_block, Sk) at a time
    (the jnp analogue of the flash kernel's tiling — required for the 32k
    prefill cells; see DESIGN.md §6)."""
    b, sq, h, d = q.shape
    if sq % q_block != 0 or sq <= q_block:
        mask = None
        if causal:
            i = jnp.arange(sq)
            mask = (i[:, None] >= i[None, :])[None, None, None]
        return _sdpa(q, k, v, mask, num_kv)
    nb = sq // q_block
    qb = q.reshape(b, nb, q_block, h, d).transpose(1, 0, 2, 3, 4)
    sk = k.shape[1]

    def one_block(i, qblk):
        mask = None
        if causal:
            rows = i * q_block + jnp.arange(q_block)
            mask = (rows[:, None] >= jnp.arange(sk)[None, :])[None, None,
                                                              None]
        return _sdpa(qblk, k, v, mask, num_kv)

    # Per-block remat: the backward recomputes each block's scores instead
    # of saving (B,H,q_block,Sk) softmax residuals for every block (the
    # flash-attention recompute strategy, in jnp form).
    one_block = jax.checkpoint(
        one_block, policy=jax.checkpoint_policies.nothing_saveable)
    out = jax.lax.map(lambda iq: one_block(iq[0], iq[1]),
                      (jnp.arange(nb), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


ATTN_Q_BLOCK = 128


def attention(params: dict, cfg, x: jax.Array, positions: jax.Array,
              *, causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = _sdpa_chunked(q, k, v, cfg.num_kv_heads, causal=causal,
                        q_block=ATTN_Q_BLOCK)
    out = shard(out, "batch", None, "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def cross_attention(params: dict, cfg, x: jax.Array,
                    kv_cache: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder-side cross attention over precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = shard(q, "batch", None, "heads", "head_dim")
    k, v = kv_cache
    out = _sdpa(q, k, v, None, cfg.num_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def cross_kv(params: dict, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return (shard(k, "batch", "seq", "kv_heads", "head_dim"),
            shard(v, "batch", "seq", "kv_heads", "head_dim"))


def decode_attention(params: dict, cfg, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, cache_len: jax.Array):
    """One-token attention against a KV cache.

    x: (B, 1, d).  k_cache/v_cache: (B, S_max, KV, D) — sequence-sharded
    when KV heads don't divide the model axis (flash-decode combine is
    inserted by SPMD).  Returns (out, new_k_cache, new_v_cache).
    """
    b, smax = k_cache.shape[0], k_cache.shape[1]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
    k_cache = shard(k_cache, "batch", "cache_seq", "kv_heads", "head_dim")
    v_cache = shard(v_cache, "batch", "cache_seq", "kv_heads", "head_dim")
    mask = (jnp.arange(smax) <= cache_len)[None, None, None, None, :]
    out = _sdpa(q, k_cache, v_cache, mask, cfg.num_kv_heads)
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"]),
            k_cache, v_cache)
