"""Mixture-of-Experts FFN with two dispatch engines.

1. ``dense``  — GSPMD-friendly one-hot dispatch (grouped tokens, capacity
   factor), the pjit baseline used by the dry-run.  Dispatch/combine are
   einsums, so expert parallelism is plain sharding: experts over the
   "model" axis when divisible (llama4: 128/16), else TP over the expert
   FFN dim (granite: 40 experts -> "expert_mlp").

2. ``sorted`` — the paper's radix-partition dispatch (DESIGN.md §3.1):
   routing tokens to experts IS partitioning step n1..n3 — expert id =
   partition number (n1), expert load histogram + scan-allocated offsets
   (n2), scatter into expert buffers (n3), with capacity overflow dropped
   exactly like the allocator's spill.  Used by examples/tests and as the
   §Perf alternative for dispatch-dominated cells.

Both produce identical outputs for the same routing (asserted in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.params import ParamSpec


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    out = {
        "router": ParamSpec((d, m.num_experts), ("fsdp", None)),
        "wi_gate": ParamSpec((m.num_experts, d, m.d_ff),
                             ("experts", "fsdp", "expert_mlp")),
        "wi_up": ParamSpec((m.num_experts, d, m.d_ff),
                           ("experts", "fsdp", "expert_mlp")),
        "wo": ParamSpec((m.num_experts, m.d_ff, d),
                        ("experts", "expert_mlp", "fsdp")),
    }
    if m.shared_d_ff:
        from .core import mlp_specs
        out["shared"] = mlp_specs(d, m.shared_d_ff)
    return out


def _capacity(tokens_per_group: int, m) -> int:
    c = -(-int(tokens_per_group * m.top_k * m.capacity_factor)
          // m.num_experts)
    if c >= 48:
        # Large capacities round to 64 so the capacity dim stays shardable
        # over the 16-way model axis (used when experts don't divide it).
        return ((c + 63) // 64) * 64
    return max(4, ((c + 3) // 4) * 4)


def _route(params, m, xg):
    """Router: top-k experts + normalized weights per token.

    xg: (G, T, d) grouped tokens.  Returns (expert_idx (G,T,k),
    weights (G,T,k), router_probs (G,T,E))."""
    logits = jnp.einsum("gtd,de->gte", xg,
                        params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    wk, idx = jax.lax.top_k(probs, m.top_k)
    wk = wk / jnp.maximum(wk.sum(-1, keepdims=True), 1e-9)
    return idx, wk.astype(xg.dtype), probs


def _experts_ffn(params, expert_in):
    """expert_in: (G, E, C, d) -> (G, E, C, d)."""
    gate = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_gate"])
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(expert_in.dtype) * up
    h = shard(h, "moe_group", "experts", "expert_cap", "expert_mlp")
    return jnp.einsum("gecf,efd->gecd", h, params["wo"])


def _aux_loss(probs, expert_idx, num_experts):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    f = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], num_experts,
                                dtype=jnp.float32), axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    return num_experts * jnp.sum(f * p)


def _group_len(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (dispatch group length)."""
    for t in range(min(pref, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def moe_dense(params: dict, cfg, x: jax.Array):
    """GSPMD one-hot dispatch.  x: (B, S, d) -> (B, S, d), aux loss."""
    m = cfg.moe
    b, s, d = x.shape
    t = _group_len(b * s, m.group_size)
    g = (b * s) // t
    xg = x.reshape(g, t, d)
    xg = shard(xg, "moe_group", None, None)
    idx, wk, probs = _route(params, m, xg)
    cap = _capacity(t, m)
    e = m.num_experts
    # Position of each (token, slot) within its expert's capacity buffer.
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)        # (G,T,K,E)
    oh_flat = oh.transpose(0, 2, 1, 3).reshape(g, m.top_k * t, e)
    pos_flat = jnp.cumsum(oh_flat, axis=1) - oh_flat    # rank within expert
    pos = pos_flat.reshape(g, m.top_k, t, e).transpose(0, 2, 1, 3)
    pos = (pos * oh).sum(-1)                             # (G,T,K)
    keep = pos < cap
    # Dispatch/combine tensors (G,T,E,C).
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None]
    disp = jnp.einsum("gtke,gtkc->gtec", oh.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gtke,gtkc->gtec", oh.astype(x.dtype),
                      pos_oh * wk[..., None])
    disp = shard(disp, "moe_group", None, "experts", "expert_cap")
    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xg)
    expert_in = shard(expert_in, "moe_group", "experts", "expert_cap", None)
    expert_out = _experts_ffn(params, expert_in)
    out = jnp.einsum("gtec,gecd->gtd", comb, expert_out)
    if "shared" in params:
        from .core import mlp
        out = out + mlp(params["shared"], xg)
    return out.reshape(b, s, d), _aux_loss(probs, idx, e)


def moe_sorted(params: dict, cfg, x: jax.Array):
    """Radix-partition dispatch (the paper's n1..n3 on expert ids)."""
    from repro.core.partition import partition_n2
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    idx, wk, probs = _route(params, m, xf[None])          # treat as 1 group
    idx, wk = idx[0], wk[0]                               # (N,K)
    e = m.num_experts
    cap = _capacity(n, m)
    # n1: partition number = expert id, one entry per (token, slot) —
    # slot-major order so capacity drops match moe_dense's priority.
    pid = idx.T.reshape(-1)                               # (K*N,)
    tok = jnp.tile(jnp.arange(n, dtype=jnp.int32), m.top_k)
    w = wk.T.reshape(-1)
    # n2: expert headers — histogram + scan allocation.
    starts, counts = partition_n2(pid, e)
    # n3: scatter <token, weight> into the expert's capacity buffer.
    order = jnp.argsort(pid, stable=True)
    rank = jnp.arange(n * m.top_k, dtype=jnp.int32) - starts[pid[order]]
    keep = rank < cap
    slot = jnp.where(keep, pid[order] * cap + rank, e * cap)  # spill -> drop
    buf_tok = jnp.full((e * cap + 1,), 0, jnp.int32).at[slot].set(tok[order])
    buf_valid = jnp.zeros((e * cap + 1,), jnp.bool_).at[slot].set(keep)
    expert_in = jnp.where(buf_valid[:e * cap, None], xf[buf_tok[:e * cap]],
                          0).reshape(1, e, cap, d)
    expert_out = _experts_ffn(params, expert_in).reshape(e * cap, d)
    # combine: gather each kept (token, slot)'s output back, weighted.
    contrib = jnp.where(keep[:, None],
                        expert_out[jnp.clip(slot, 0, e * cap - 1)], 0)
    out = jnp.zeros((n, d), x.dtype).at[tok[order]].add(
        contrib * w[order][:, None])
    if "shared" in params:
        from .core import mlp
        out = out + mlp(params["shared"], xf[None]).reshape(n, d)
    return out.reshape(b, s, d), _aux_loss(probs, idx[None], e)


def moe(params: dict, cfg, x: jax.Array):
    if cfg.moe_impl == "sorted":
        return moe_sorted(params, cfg, x)
    return moe_dense(params, cfg, x)
