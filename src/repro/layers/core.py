"""Shared primitive layers: RMSNorm, RoPE, SwiGLU MLP, embeddings.

Pure functions over explicit parameter dicts (specs in sibling ``specs``
functions).  All norm math in float32, outputs cast back to model dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.params import ParamSpec


# -- RMSNorm ----------------------------------------------------------------

def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones", dtype="float32")


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * weight
    return y.astype(x.dtype)


# -- RoPE ---------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- SwiGLU MLP ----------------------------------------------------------------

def mlp_specs(d: int, f: int) -> dict:
    return {
        "wi_gate": ParamSpec((d, f), ("fsdp", "mlp")),
        "wi_up": ParamSpec((d, f), ("fsdp", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "fsdp")),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])


# -- Embedding / logits ---------------------------------------------------------

def embed_specs(cfg) -> dict:
    pv, d = cfg.padded_vocab, cfg.d_model
    out = {"embedding": ParamSpec((pv, d), ("vocab", "fsdp"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((d, pv), ("fsdp", "vocab"))
    return out


def embed(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    h = params["embedding"].astype(dtype)[tokens]
    return shard(h, "batch", "seq", None)


def logits_fn(params: dict, h: jax.Array, vocab_size: int) -> jax.Array:
    if "lm_head" in params:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embedding"])
    logits = shard(logits, "batch", "seq", "vocab")
    pv = logits.shape[-1]
    if pv > vocab_size:  # mask vocab padding out of the softmax
        mask = jnp.arange(pv) >= vocab_size
        logits = jnp.where(mask, jnp.float32(-1e9).astype(logits.dtype),
                           logits)
    return logits
