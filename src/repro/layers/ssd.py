"""Mamba2 block via SSD (state-space duality), chunked for the MXU.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks of length Q:

  intra-chunk (quadratic, MXU-friendly):  Y_intra = (L ∘ (C B^T)) X
  inter-chunk (linear recurrence):        h_{c+1} = decay_c h_c + S_c
                                          Y_inter = C h

which is the paper-series structure of DESIGN.md §4: two "steps" with a
barrier, with the chunk length Q as the tiling knob the cost model sizes
(the Pallas kernel in repro.kernels.ssd tiles exactly these einsums).

Decode is the O(1) recurrent form: h = a h + dt x B^T; y = C h + D x.

Layout: x (B, L, H, P) heads sharded over "model" (ssm_heads); state
(B, H, P, N) likewise — long_500k decode state is sequence-length free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.params import ParamSpec
from .core import rmsnorm, rmsnorm_spec


def ssd_specs(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    return {
        "in_x": ParamSpec((d, d_in), ("fsdp", "mlp")),
        "in_z": ParamSpec((d, d_in), ("fsdp", "mlp")),
        "in_b": ParamSpec((d, s.d_state), ("fsdp", "ssm_state")),
        "in_c": ParamSpec((d, s.d_state), ("fsdp", "ssm_state")),
        "in_dt": ParamSpec((d, nh), ("fsdp", "ssm_heads")),
        "conv_x": ParamSpec((s.conv_kernel, d_in), ("conv", "mlp"),
                            scale=0.5),
        "conv_b": ParamSpec((s.conv_kernel, s.d_state), ("conv", None),
                            scale=0.5),
        "conv_c": ParamSpec((s.conv_kernel, s.d_state), ("conv", None),
                            scale=0.5),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros",
                           dtype="float32"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros",
                             dtype="float32"),
        "norm": rmsnorm_spec(d_in),
        "out": ParamSpec((d_in, d), ("mlp", "fsdp")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, L, D); w: (K, D).

    With ``state`` (B, K-1, D) performs streaming conv (decode), returning
    the updated state."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_state


def _segsum(a):
    """Stable segment-sum: S[i, j] = sum_{j < k <= i} a[k] (lower tri)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b, l, h, p); dt: (b, l, h) (post-softplus); A: (h,) negative;
    B, C: (b, l, n).  Returns y: (b, l, h, p) and final state (b, h, p, n).
    """
    b, l0, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l0)
    pad = (-l0) % q
    if pad:
        # Zero-pad the tail: dt=0 makes padded steps identity transitions
        # (decay exp(0)=1, contribution dt*B*x=0), so the state is exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    l = l0 + pad
    nc = l // q
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)
    da = dtc * A  # (b, nc, q, h)  log-decay per step

    # -- intra-chunk (quadratic in q, runs on the MXU) --------------------
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))        # (b,nc,h,q,q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # (b,nc,q,q)
    M = scores[:, :, None] * L                            # (b,nc,h,q,q)
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc,
                         xc.astype(jnp.float32))

    # -- chunk states + inter-chunk recurrence (lax.scan over chunks) ----
    suffix_incl = jnp.cumsum(da[..., ::-1, :], axis=2)[..., ::-1, :]
    decay_to_end = jnp.exp(suffix_incl - da)   # exclusive suffix decay
    S = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, dtc * decay_to_end,
                   xc.astype(jnp.float32))                # per-chunk state
    chunk_decay = jnp.exp(da.sum(axis=2))                 # (b,nc,h)

    def scan_fn(h0, inp):
        s_c, dec = inp                                    # (b,h,p,n),(b,h)
        h1 = h0 * dec[..., None, None] + s_c
        return h1, h0

    h_final, h_prev = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, n), jnp.float32),
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (b,nc,h,p,n)

    decay_from_start = jnp.exp(jnp.cumsum(da, axis=2))    # (b,nc,q,h)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_from_start,
                         h_prev)
    y = (y_intra + y_inter).reshape(b, l, h, p)[:, :l0]
    return y.astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, B, C, h):
    """One-token recurrence.  x: (b, h, p); B, C: (b, n); h: (b,h,p,n)."""
    da = jnp.exp(dt.astype(jnp.float32) * A)              # (b, h)
    h = h * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(jnp.float32), x.astype(jnp.float32), B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, C.astype(jnp.float32))
    return y.astype(x.dtype), h


def mamba_block(params: dict, cfg, x: jax.Array, state: dict | None = None):
    """Full Mamba2 block.  x: (B, L, d).

    ``state`` (decode): {"ssm": (B,H,P,N), "conv_x": (B,K-1,Din),
    "conv_b": (B,K-1,N), "conv_c": (B,K-1,N)}.  Returns (y, new_state).
    """
    s = cfg.ssm
    bsz, l, d = x.shape
    d_in = s.expand * d
    nh = d_in // s.head_dim
    decode = state is not None

    z = jnp.einsum("bld,de->ble", x, params["in_z"])
    xs = jnp.einsum("bld,de->ble", x, params["in_x"])
    Braw = jnp.einsum("bld,dn->bln", x, params["in_b"])
    Craw = jnp.einsum("bld,dn->bln", x, params["in_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"])

    xs, cx = _causal_conv(xs, params["conv_x"],
                          state["conv_x"] if decode else None)
    Bv, cb = _causal_conv(Braw, params["conv_b"],
                          state["conv_b"] if decode else None)
    Cv, cc = _causal_conv(Craw, params["conv_c"],
                          state["conv_c"] if decode else None)
    xs = shard(xs, "batch", "seq", "mlp")
    A = -jnp.exp(params["A_log"])                          # (h,) negative
    xh = xs.reshape(bsz, l, nh, s.head_dim)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)

    if decode:
        y1, h1 = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bv[:, 0], Cv[:, 0],
                                 state["ssm"])
        y = y1[:, None]
        new_state = {"ssm": h1, "conv_x": cx, "conv_b": cb, "conv_c": cc}
    else:
        y, h1 = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm.chunk)
        new_state = {"ssm": h1, "conv_x": cx, "conv_b": cb, "conv_c": cc}
    y = y + xh * params["D"][:, None].astype(x.dtype)
    y = y.reshape(bsz, l, d_in)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                params["norm"], cfg.rms_eps)
    return jnp.einsum("ble,ed->bld", y, params["out"]), new_state
