"""Predicted-vs-measured cost-model audit trail.

Every *executed* plan — not just the warmed/solo samples that feed the
online calibration — records its ``(phase, scheme, est_s, measured_s)``
pairs here.  That difference is the point: the planner's estimates are
solo-time predictions, and the audit's error ratios measure how wrong
they were *under contention*, which is exactly the signal ROADMAP item 1
needs for a per-tenant admission safety margin.

``summary()`` derives per-phase and per-tenant prediction-error ratios
(``measured_s / est_s``; p50/p95 over a bounded window) and is designed
to be registered as a ``MetricsRegistry`` collector, so the whole trail
surfaces through ``metrics.snapshot()["prediction_error"]``.
"""
from __future__ import annotations

import threading
from collections import deque

from .metrics import _percentile


class CostAudit:
    """Bounded ring of per-phase audit records with ratio summaries."""

    def __init__(self, max_records: int = 8192):
        self._lock = threading.Lock()
        self._records: deque = deque(maxlen=int(max_records))
        # Record listeners (e.g. the drift detector): invoked once per
        # appended record, outside the audit lock — a listener may call
        # back into components that themselves log metrics.
        self._listeners: list = []

    @property
    def capacity(self) -> int:
        """The retention bound (ring ``maxlen``) — long-running services
        cannot grow audit memory past it; exposed as a service gauge."""
        return int(self._records.maxlen or 0)

    def add_listener(self, fn) -> None:
        """Register ``fn(record_dict)`` to observe every appended record."""
        self._listeners.append(fn)

    def record(self, pairs, *, tenant: str = "default",
               query_id: int = -1) -> None:
        """Append one executed plan's phase pairs.

        ``pairs`` is ``[(phase, scheme, est_s, measured_s), ...]`` —
        produced by ``QueryPlanner.phase_pairs`` from the *executed* plan
        object and its measured ``Timing``, never from admission-time
        re-pricing.  Pairs with a non-positive estimate carry no ratio
        (they cannot be audited) but are still recorded.
        """
        recs = []
        for phase, scheme, est_s, measured_s in pairs:
            est_s = float(est_s)
            measured_s = float(measured_s)
            ratio = (measured_s / est_s) if est_s > 0.0 else None
            recs.append({"phase": phase, "scheme": scheme,
                         "est_s": est_s, "measured_s": measured_s,
                         "ratio": ratio, "tenant": tenant,
                         "query_id": query_id})
        with self._lock:
            self._records.extend(recs)
        for fn in self._listeners:
            for r in recs:
                try:
                    fn(r)
                except Exception:   # a broken listener must not sink queries
                    pass

    def records(self) -> list[dict]:
        with self._lock:
            return [dict(r) for r in self._records]

    def summary(self) -> dict:
        """Per-phase and per-tenant prediction-error ratio summaries.

        ``ratio = measured_s / est_s`` (1.0 = perfect prediction; > 1
        under-estimated, e.g. contention inflating solo-time estimates).
        """
        with self._lock:
            recs = list(self._records)
        by_phase: dict[str, list[float]] = {}
        by_tenant: dict[str, list[float]] = {}
        for r in recs:
            if r["ratio"] is None:
                continue
            by_phase.setdefault(r["phase"], []).append(r["ratio"])
            by_tenant.setdefault(r["tenant"], []).append(r["ratio"])

        def _summ(vals):
            s = sorted(vals)
            return {"count": len(s), "p50": _percentile(s, 0.50),
                    "p95": _percentile(s, 0.95)}

        return {"count": len(recs),
                "phases": {k: _summ(v) for k, v in sorted(by_phase.items())},
                "tenants": {k: _summ(v)
                            for k, v in sorted(by_tenant.items())}}

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
