"""Host-transfer ledger: every H2D/D2H byte attributed to its cause.

The coupled-architecture papers this repo reproduces agree on one thing:
the host boundary is the decisive cost.  PR 5 made the fused data path
provably quiet (``host_bytes_moved == 0``, CI-gated), but that counter is
flat — when it reads non-zero nobody can say *which* stage, column, or
mechanism moved the bytes.  The ledger fixes that: every crossing is
recorded as ``(stage, column, cause, direction, nbytes)`` with a closed
cause taxonomy:

  * ``fingerprint``   — a build/probe key column pulled to host to compute
    a content fingerprint for the ``BuildTableCache`` (the structural
    fingerprints added alongside this ledger eliminate these on both
    pipeline paths; any residual pull — e.g. a raw device relation
    submitted straight to the engine — shows up here).
  * ``multicol_pack`` — multi-column group-by keys gathered to host for
    mixed-radix packing, and the packed key/value upload that follows
    (ROADMAP: device-side composite-key packing removes these next).
  * ``handoff``       — host-materialize stage hand-off traffic: rid
    vectors gathered down, materialized intermediates re-uploaded.  The
    fused path's defining invariant is that this cause stays 0.
  * ``result``        — final result delivery (``StageView.materialize``,
    scalar-sink column pulls).  Someone always reads the answer; these
    bytes are attributed but — as everywhere in this repo since PR 5 —
    *not* counted as intermediate traffic.

The flat ``host_bytes_moved`` counter is now a **sum view over the
ledger**: :meth:`TransferLedger.record` increments it for every
intermediate cause (everything except ``result``), so existing gates and
tests keep their exact semantics while gaining attribution underneath.
"""
from __future__ import annotations

import threading
from collections import deque

CAUSES = ("fingerprint", "multicol_pack", "handoff", "result")
#: Causes that count toward the service's ``host_bytes_moved`` counter.
#: ``result`` is excluded — final result delivery has never been counted
#: as intermediate traffic (see PR 5's fused-path invariant).
INTERMEDIATE_CAUSES = ("fingerprint", "multicol_pack", "handoff")

DIRECTIONS = ("h2d", "d2h")


class TransferLedger:
    """Thread-safe host-boundary byte ledger with bounded raw entries.

    Aggregates are exact and unbounded in *value* but bounded in *key
    count* by the workload's (stage, column, cause, direction) space;
    raw per-crossing entries live in a bounded ring for debugging.
    """

    def __init__(self, metrics=None, *, max_entries: int = 8192):
        self._lock = threading.Lock()
        self._metrics = metrics
        self._agg: dict[tuple, list] = {}   # key -> [bytes, count]
        self._entries: deque = deque(maxlen=int(max_entries))

    def record(self, nbytes, *, cause: str, stage: str = "-",
               column: str = "-", direction: str = "d2h",
               tenant: str = "default") -> None:
        """Attribute one host-boundary crossing.

        Increments the registry's ``host_bytes_moved`` for intermediate
        causes and the labeled ``host_transfer_bytes{cause,direction}``
        series for all causes — the flat counter is a sum view over the
        ledger by construction, never a separately-maintained number.
        """
        if cause not in CAUSES:
            raise ValueError(f"unknown transfer cause {cause!r} "
                             f"(want one of {CAUSES})")
        if direction not in DIRECTIONS:
            raise ValueError(f"unknown transfer direction {direction!r}")
        n = int(nbytes)
        if n <= 0:
            return
        key = (str(stage), str(column), cause, direction)
        with self._lock:
            slot = self._agg.get(key)
            if slot is None:
                self._agg[key] = [n, 1]
            else:
                slot[0] += n
                slot[1] += 1
            self._entries.append({"stage": key[0], "column": key[1],
                                  "cause": cause, "direction": direction,
                                  "nbytes": n, "tenant": tenant})
        if self._metrics is not None:    # registry lock is a leaf lock
            self._metrics.inc("host_transfer_bytes", n,
                              cause=cause, direction=direction)
            if cause != "result":
                self._metrics.inc("host_bytes_moved", n)

    # -- readers -------------------------------------------------------------
    def total(self, *, intermediate_only: bool = True) -> int:
        """Sum over causes — with ``intermediate_only`` (the default) this
        equals the ``host_bytes_moved`` counter this ledger maintains."""
        with self._lock:
            return sum(b for (_, _, cause, _), (b, _) in self._agg.items()
                       if not intermediate_only
                       or cause in INTERMEDIATE_CAUSES)

    def by_cause(self) -> dict[str, int]:
        out = {c: 0 for c in CAUSES}
        with self._lock:
            for (_, _, cause, _), (b, _) in self._agg.items():
                out[cause] += b
        return out

    def by_stage(self) -> dict[str, dict[str, int]]:
        """``{stage: {cause: bytes}}`` over all recorded crossings."""
        out: dict[str, dict[str, int]] = {}
        with self._lock:
            items = list(self._agg.items())
        for (stage, _, cause, _), (b, _) in items:
            out.setdefault(stage, {}).setdefault(cause, 0)
            out[stage][cause] += b
        return out

    def entries(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._entries]

    def summary(self) -> dict:
        """Snapshot for the ``host_transfer_ledger`` metrics collector."""
        with self._lock:
            items = list(self._agg.items())
        by_cause = {c: 0 for c in CAUSES}
        by_direction = {d: 0 for d in DIRECTIONS}
        crossings = 0
        for (_, _, cause, direction), (b, n) in items:
            by_cause[cause] += b
            by_direction[direction] += b
            crossings += n
        intermediate = sum(by_cause[c] for c in INTERMEDIATE_CAUSES)
        return {"crossings": crossings,
                "total_bytes": sum(by_cause.values()),
                "intermediate_bytes": intermediate,
                "by_cause": by_cause,
                "by_direction": by_direction,
                "by_stage": self.by_stage()}

    def clear(self) -> None:
        with self._lock:
            self._agg.clear()
            self._entries.clear()
