"""Labeled counters/gauges/histograms with lock-safe snapshots.

One registry per service absorbs the engine's formerly scattered
counters (``host_bytes_moved``, shed/degraded/rejected, cache hits,
calibration version ticks) behind a single :meth:`MetricsRegistry.snapshot`.

Design rules:

  * Series are keyed by ``(name, sorted labels)``.  The snapshot is a
    flat dict: an unlabeled series (or the sum over a name's labeled
    series) appears under the plain ``name`` — so
    ``snapshot()["host_bytes_moved"]`` is an int — and each labeled
    series additionally appears under ``name{k=v,...}``.
  * **Lock ordering:** the registry lock is a *leaf* lock.  Components
    must never call into the registry while holding their own locks;
    conversely :meth:`snapshot` reads all native series atomically under
    the registry lock, then invokes registered *collectors* (which take
    their components' locks) outside it — one consistent pass, no
    lock-order cycle.
  * *Events* are bounded structured records (dicts) for decisions that
    matter individually — admission shed/degrade — so consumers (e.g.
    ``slo_bench``) read them from the registry instead of re-deriving
    them from raised exceptions.
"""
from __future__ import annotations

import threading
import time
from collections import deque


def _percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q * (len(sorted_vals) - 1)))))
    return float(sorted_vals[idx])


class MetricsRegistry:
    """Thread-safe registry of counters, gauges, histograms, events and
    collectors."""

    def __init__(self, *, max_events: int = 4096,
                 histogram_window: int = 4096,
                 histogram_window_s: float | None = None,
                 clock=time.monotonic):
        self._lock = threading.Lock()
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, deque] = {}
        self._events: deque = deque(maxlen=int(max_events))
        self._hist_window = int(histogram_window)
        # Optional *time* window on top of the count bound: a sample whose
        # age reaches the window is gone — strictly older-than keeps, so a
        # sample lands exactly at the edge ages out (see test_obs_loop).
        self._hist_window_s = (None if histogram_window_s is None
                               else float(histogram_window_s))
        self._clock = clock
        self._collectors: dict[str, object] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    @staticmethod
    def _flat(name: str, label_items: tuple) -> str:
        inner = ",".join(f"{k}={v}" for k, v in label_items)
        return f"{name}{{{inner}}}"

    # -- writers -------------------------------------------------------------
    def inc(self, name: str, value: float = 1, **labels) -> None:
        """Increment a (possibly labeled) counter."""
        key = self._key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._gauges[key] = value

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one histogram sample (sliding window, per series).

        Samples are stamped with the registry clock; when a time window is
        configured (``histogram_window_s``) aged-out samples are pruned
        here and excluded from summaries.
        """
        key = self._key(name, labels)
        now = self._clock()
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = deque(maxlen=self._hist_window)
            h.append((now, float(value)))
            self._prune_locked(h, now)

    def _prune_locked(self, h: deque, now: float) -> None:
        if self._hist_window_s is None:
            return
        edge = now - self._hist_window_s
        while h and h[0][0] <= edge:
            h.popleft()

    def _hist_values(self, samples, now: float) -> list[float]:
        """Window-filtered sample values (edge-exclusive on the old side)."""
        if self._hist_window_s is None:
            return [v for _, v in samples]
        edge = now - self._hist_window_s
        return [v for t, v in samples if t > edge]

    def histogram_summary(self, name: str, **labels) -> dict:
        """Point-in-time summary of one histogram series (an empty or
        fully-aged-out window reads as count=0 with zeroed stats)."""
        key = self._key(name, labels)
        now = self._clock()
        with self._lock:
            samples = list(self._hists.get(key, ()))
        return self._hist_summary(self._hist_values(samples, now))

    def event(self, name: str, **payload) -> None:
        """Append a structured event record (bounded ring)."""
        with self._lock:
            self._events.append({"event": name, **payload})

    # -- readers -------------------------------------------------------------
    def counter_value(self, name: str) -> float:
        """Sum of a counter's series across labels (cheap: no collectors
        run — unlike :meth:`snapshot`)."""
        with self._lock:
            return sum(v for (n, _), v in self._counters.items()
                       if n == name)

    def counter_series(self, name: str) -> dict[tuple, float]:
        """All of one counter's labeled series: ``{label-items: value}``
        (label items are the sorted ``(key, value)`` tuples).  The cheap
        read the SLO monitor samples per-tenant counters through."""
        with self._lock:
            return {labels: v for (n, labels), v in self._counters.items()
                    if n == name}

    def events(self, name: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        if name is None:
            return evs
        return [e for e in evs if e.get("event") == name]

    def register_collector(self, name: str, fn) -> None:
        """Register ``fn() -> value`` to be materialized under ``name``
        in every snapshot.  Collectors run *outside* the registry lock
        (they may take their own component locks); registering the same
        name again replaces the previous collector."""
        with self._lock:
            self._collectors[name] = fn

    @staticmethod
    def _hist_summary(vals) -> dict:
        s = sorted(vals)
        n = len(s)
        return {"count": n, "sum": float(sum(s)),
                "min": (s[0] if n else 0.0), "max": (s[-1] if n else 0.0),
                "p50": _percentile(s, 0.50), "p95": _percentile(s, 0.95)}

    def snapshot(self) -> dict:
        """One consistent point-in-time view.

        All native series are read atomically under the registry lock;
        collectors (queue depth, cache stats, planner stats, audit
        summaries) are then invoked immediately after in the same pass.
        """
        now = self._clock()
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: self._hist_values(v, now)
                     for k, v in self._hists.items()}
            collectors = list(self._collectors.items())
        out: dict = {}
        totals: dict[str, float] = {}
        for (name, labels), v in counters.items():
            totals[name] = totals.get(name, 0) + v
            if labels:
                out[self._flat(name, labels)] = v
        for name, v in totals.items():
            out[name] = v
        for (name, labels), v in gauges.items():
            out[self._flat(name, labels) if labels else name] = v
        for (name, labels), vals in hists.items():
            key = self._flat(name, labels) if labels else name
            out[key] = self._hist_summary(vals)
        for name, fn in collectors:
            try:
                out[name] = fn()
            except Exception:   # a broken collector must not sink stats()
                out[name] = None
        return out
