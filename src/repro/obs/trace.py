"""Query-lifecycle tracing: lightweight spans with an injectable clock.

The engine's observability substrate.  A ``Tracer`` records *spans* —
named, attributed time intervals — from every layer of a query's life:

    admit -> queue -> plan -> partition -> build -> probe -> gather/agg
          -> finalize

Spans opened with :meth:`Tracer.span` nest per thread via a thread-local
stack, so worker threads and deferred pipeline stages each get a
correctly nested lane; *ambient* attributes (``q_key``, ``query_id``,
``tenant``, ``tag``, ``scheme``) flow from a parent span to its children
automatically, which is how a ``CoProcessor`` phase span deep inside a
kernel wrapper ends up tagged with the query that caused it without the
kernel knowing anything about queries.

Retroactive intervals that *cannot* nest on a thread's stack — queue
wait is measured on the submitting thread but ends on a worker — are
recorded with :meth:`Tracer.lane` and exported as Chrome *async* events,
which carry no nesting constraint.

Exports:

  * :meth:`Tracer.chrome_trace` / :meth:`Tracer.write_chrome_trace` —
    Chrome trace-event JSON (open in https://ui.perfetto.dev).
  * :meth:`Tracer.spans_for` — the structured per-query span list that
    ``JoinQueryService`` attaches to ``QueryOutcome.trace``.

``NullTracer`` (singleton ``NULL_TRACER``) is the no-op recorder: every
call is a cheap early return, so a standalone ``CoProcessor`` — which
defaults to it — pays nothing for the plumbing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import threading
import time

# Attribute keys a child span inherits from its innermost open ancestor
# on the same thread (unless it sets them itself).
AMBIENT_ATTRS = ("q_key", "query_id", "tenant", "tag", "scheme")


@dataclasses.dataclass
class SpanRecord:
    """One finished span: a closed interval on the tracer's clock."""

    name: str
    t0: float
    t1: float
    thread: str
    attrs: dict
    # Non-None marks an async "lane" interval (e.g. queue wait) that is
    # exempt from per-thread nesting and exported as Chrome b/e events.
    lane: str | None = None

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "dur_s": self.t1 - self.t0, "thread": self.thread,
                "lane": self.lane, "attrs": dict(self.attrs)}


class _ActiveSpan:
    """Mutable handle yielded by ``Tracer.span`` while the span is open."""

    __slots__ = ("name", "t0", "attrs")

    def __init__(self, name: str, t0: float, attrs: dict):
        self.name = name
        self.t0 = t0
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. the chosen plan's
        scheme, known only after planning but ambient for the phases)."""
        self.attrs.update((k, v) for k, v in attrs.items() if v is not None)


class Tracer:
    """Thread-safe span recorder with an injectable clock.

    ``clock`` must be monotonic within one tracer (tests inject fake
    clocks).  Finished spans are kept in a bounded ring; per-``q_key``
    indexing serves the structured per-query trace on ``QueryOutcome``.
    """

    def __init__(self, clock=time.perf_counter, *, enabled: bool = True,
                 max_spans: int = 200_000):
        self.enabled = bool(enabled)
        self.max_spans = int(max_spans)
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: list[SpanRecord] = []
        self._dropped = 0
        self._by_key: dict[int, list[SpanRecord]] = {}
        self._local = threading.local()
        self._key_seq = itertools.count(1)

    # -- clocks and keys -----------------------------------------------------
    def now(self) -> float:
        return self._clock()

    def next_key(self) -> int:
        """Allocate a per-execution correlation key (``q_key``).  Unique
        per tracer; stamped on every span of one query's lifecycle."""
        return next(self._key_seq)

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # -- recording -----------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Open a nested span on the calling thread.

        Yields the active span (``.set(**attrs)`` adds attributes
        mid-flight) or ``None`` when the tracer is disabled.  ``None``
        attribute values are dropped; ambient keys are inherited from the
        innermost open ancestor on this thread.
        """
        if not self.enabled:
            yield None
            return
        stack = self._stack()
        if stack:
            parent = stack[-1].attrs
            for k in AMBIENT_ATTRS:
                if k in parent and k not in attrs:
                    attrs[k] = parent[k]
        attrs = {k: v for k, v in attrs.items() if v is not None}
        sp = _ActiveSpan(name, self.now(), attrs)
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            self._finish(SpanRecord(name, sp.t0, self.now(),
                                    threading.current_thread().name,
                                    sp.attrs))

    def lane(self, name: str, t0: float, t1: float, *,
             lane: str = "queue", **attrs) -> None:
        """Record a retroactive interval on a named async lane.

        Lane intervals start on one thread and end on another (queue
        wait), so they are exempt from per-thread nesting and become
        Chrome async (``b``/``e``) events rather than ``X`` slices.
        """
        if not self.enabled:
            return
        attrs = {k: v for k, v in attrs.items() if v is not None}
        self._finish(SpanRecord(name, float(t0), max(float(t0), float(t1)),
                                threading.current_thread().name,
                                attrs, lane=lane))

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-length event (e.g. an admission shed decision)."""
        if not self.enabled:
            return
        stack = self._stack()
        if stack:
            parent = stack[-1].attrs
            for k in AMBIENT_ATTRS:
                if k in parent and k not in attrs:
                    attrs[k] = parent[k]
        attrs = {k: v for k, v in attrs.items() if v is not None}
        t = self.now()
        self._finish(SpanRecord(name, t, t,
                                threading.current_thread().name, attrs))

    def _finish(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self._dropped += 1
                return
            self._spans.append(rec)
            key = rec.attrs.get("q_key")
            if key is not None:
                # The per-query index is bounded by wholesale reset: one
                # query contributes ~10 spans, so the cap is generous.
                if len(self._by_key) > 8192:
                    self._by_key.clear()
                self._by_key.setdefault(key, []).append(rec)

    # -- reading -------------------------------------------------------------
    def spans(self) -> list[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def spans_for(self, key) -> list[dict]:
        """Structured per-query trace: every finished span stamped with
        this ``q_key``, in completion order (what ``QueryOutcome.trace``
        carries)."""
        with self._lock:
            return [r.to_dict() for r in self._by_key.get(key, ())]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._by_key.clear()
            self._dropped = 0

    # -- Chrome trace-event export -------------------------------------------
    def chrome_trace(self) -> list[dict]:
        """Render finished spans as Chrome trace events.

        Thread spans become complete (``"X"``) events — nesting per
        ``tid`` is guaranteed because they were built from per-thread
        stacks.  Lane intervals become async begin/end (``"b"``/``"e"``)
        pairs on a synthetic lane track.  Timestamps are microseconds
        relative to the earliest recorded span (never negative), sorted
        ascending; ``"M"`` metadata events name the tracks.
        """
        recs = self.spans()
        if not recs:
            return []
        epoch = min(r.t0 for r in recs)
        tids: dict[str, int] = {}

        def tid_of(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids) + 1
            return tids[track]

        events: list[dict] = []
        async_id = 0
        for r in recs:
            ts = max(0.0, r.t0 - epoch) * 1e6
            dur = max(0.0, r.t1 - r.t0) * 1e6
            if r.lane is not None:
                async_id += 1
                tid = tid_of(f"lane:{r.lane}")
                events.append({"ph": "b", "cat": r.lane, "id": async_id,
                               "name": r.name, "pid": 1, "tid": tid,
                               "ts": ts, "args": dict(r.attrs)})
                events.append({"ph": "e", "cat": r.lane, "id": async_id,
                               "name": r.name, "pid": 1, "tid": tid,
                               "ts": ts + dur})
            else:
                events.append({"ph": "X", "cat": "span", "name": r.name,
                               "pid": 1, "tid": tid_of(r.thread),
                               "ts": ts, "dur": dur,
                               "args": dict(r.attrs)})
        # Stable order: ascending ts; at equal ts the longer slice first
        # so a parent precedes its children (fake clocks produce ties).
        events.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        meta = [{"ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                 "args": {"name": track}}
                for track, tid in sorted(tids.items(), key=lambda kv: kv[1])]
        return meta + events

    def write_chrome_trace(self, path) -> str:
        """Write the Chrome trace JSON (Perfetto/chrome://tracing load it
        directly).  Returns the path written."""
        payload = {"traceEvents": self.chrome_trace(),
                   "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(payload, f)
        return str(path)


class NullTracer(Tracer):
    """No-op recorder: the default for a standalone ``CoProcessor``.

    Every entry point is an ``enabled`` check followed by an early
    return, so instrumented code paths cost a branch when tracing is off.
    """

    def __init__(self):
        super().__init__(enabled=True, max_spans=0)
        self.enabled = False


#: Shared no-op tracer instance (safe to share: it never records).
NULL_TRACER = NullTracer()
