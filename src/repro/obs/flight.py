"""Flight recorder: a bounded ring of recent query lifecycles that dumps
itself when things go wrong.

Traces and metrics answer "what is happening"; the flight recorder
answers "what *was* happening just before it broke".  It keeps an
always-on, bounded ring of compact per-query records — outcome summary,
plan signature, admission decisions, failures — plus per-tenant
sub-rings, and produces a post-mortem JSON bundle (:meth:`dump`)
automatically on:

  * a **query failure** (any execution exception),
  * a **shed storm** (``storm_n`` sheds/rejects inside
    ``storm_window_s``),
  * a **deadline-miss burst** (``burst_n`` misses inside
    ``burst_window_s``).

Auto-dumps are rate-limited (``min_dump_gap_s``) and land either on disk
(``dump_dir`` set: ``FLIGHT_<name>_<stamp>_<n>_<reason>.json``, the
prefix keeping them out of ``check_regression``'s ``BENCH_*`` glob while
CI uploads them next to the rollups) or in the in-memory ``auto_dumps``
ring.
Every live recorder self-registers in a module-level weak set so the
bench harness can dump *all* of them when a bench run fails
(:func:`dump_live_recorders`).

Records are plain dicts built by :func:`summarize_outcome` — a span
digest distilled from ``Timing.phase_s`` rather than the full trace, so
the recorder works (and stays cheap) even under ``NULL_TRACER``.
"""
from __future__ import annotations

import json
import os
import threading
import time
import weakref
from collections import deque

#: Schema tag stamped into every dump (consumers validate against it).
SCHEMA = "flight-recorder/v1"

_LIVE: "weakref.WeakSet[FlightRecorder]" = weakref.WeakSet()


def summarize_outcome(outcome) -> dict:
    """Compact lifecycle record for one ``QueryOutcome`` (duck-typed so
    the recorder has no dependency on engine types)."""
    plan = outcome.plan
    timing = outcome.timing
    phases = {k: round(float(v), 6)
              for k, v in getattr(timing, "phase_s", {}).items()}
    return {
        "kind": "outcome",
        "query_id": outcome.query_id, "tag": outcome.tag,
        "tenant": outcome.tenant,
        "algorithm": getattr(plan, "algorithm", None),
        "scheme": getattr(plan, "scheme", None),
        "join_kind": getattr(plan, "kind", None),
        "schedule": (list(plan.schedule)
                     if getattr(plan, "schedule", None) else None),
        "est_s": round(float(getattr(plan, "est_s", 0.0)), 6),
        "queued_s": round(float(outcome.queued_s), 6),
        "wall_s": round(float(outcome.wall_s), 6),
        "deadline_hit": outcome.deadline_hit,
        "degraded": outcome.degraded,
        "cache_hit": outcome.cache_hit,
        "phases": phases,
    }


class FlightRecorder:
    """Always-on bounded recorder of recent query lifecycles."""

    def __init__(self, *, capacity: int = 512, tenant_capacity: int = 128,
                 clock=time.monotonic, name: str = "service",
                 storm_n: int = 8, storm_window_s: float = 5.0,
                 burst_n: int = 8, burst_window_s: float = 5.0,
                 min_dump_gap_s: float = 30.0,
                 dump_dir: str | None = None):
        self.name = name
        self.dump_dir = dump_dir
        self._clock = clock
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=int(capacity))
        self._tenant_cap = int(tenant_capacity)
        self._tenants: dict[str, deque] = {}
        self._counts = {"outcome": 0, "admission": 0, "failure": 0}
        # Trigger state: timestamps of recent sheds / deadline misses.
        self.storm_n, self.storm_window_s = int(storm_n), float(storm_window_s)
        self.burst_n, self.burst_window_s = int(burst_n), float(burst_window_s)
        self.min_dump_gap_s = float(min_dump_gap_s)
        self._sheds: deque = deque(maxlen=max(self.storm_n, 1))
        self._misses: deque = deque(maxlen=max(self.burst_n, 1))
        self._last_dump_t: float | None = None
        self.dump_count = 0
        #: In-memory auto-dumps when no ``dump_dir`` is configured.
        self.auto_dumps: deque = deque(maxlen=4)
        #: Paths of dumps written to disk (auto or explicit).
        self.dump_paths: list[str] = []
        _LIVE.add(self)

    # -- recording -----------------------------------------------------------
    def _append(self, rec: dict) -> None:
        t = self._clock()
        rec = {"t": round(float(t), 6), **rec}
        with self._lock:
            self._ring.append(rec)
            self._counts[rec["kind"]] = self._counts.get(rec["kind"], 0) + 1
            tenant = rec.get("tenant")
            if tenant is not None:
                ring = self._tenants.get(tenant)
                if ring is None:
                    ring = self._tenants[tenant] = deque(
                        maxlen=self._tenant_cap)
                ring.append(rec)

    def record_outcome(self, outcome) -> None:
        rec = summarize_outcome(outcome)
        self._append(rec)
        if rec.get("deadline_hit") is False:
            self._bump_trigger(self._misses, self.burst_n,
                               self.burst_window_s, "deadline_miss_burst")

    def record_admission(self, action: str, **payload) -> None:
        """One shed/reject/degrade decision (mirrors the registry event)."""
        self._append({"kind": "admission", "action": action, **payload})
        if action in ("shed", "reject"):
            self._bump_trigger(self._sheds, self.storm_n,
                               self.storm_window_s, "shed_storm")

    def record_failure(self, *, tenant: str = "default", query_id: int = -1,
                       where: str = "execute", error: str = "") -> None:
        """One execution failure — always triggers a dump (rate-limited)."""
        self._append({"kind": "failure", "tenant": tenant,
                      "query_id": query_id, "where": where,
                      "error": error[:500]})
        self._maybe_dump("query_failure")

    def record_resilience(self, what: str, **payload) -> None:
        """One recovery-ladder transition (preemption, retry, degrade
        fallback, breaker state change, worker restart, checkpoint) —
        recorded, never a dump trigger by itself: the ladder *handling*
        a fault is normal operation, only unhandled failures dump."""
        self._append({"kind": "resilience", "what": what, **payload})

    def _bump_trigger(self, ring: deque, n: int, window_s: float,
                      reason: str) -> None:
        now = self._clock()
        with self._lock:
            ring.append(now)
            fired = (len(ring) >= n and now - ring[0] <= window_s)
        if fired:
            self._maybe_dump(reason)

    # -- dumping -------------------------------------------------------------
    def _maybe_dump(self, reason: str) -> None:
        now = self._clock()
        with self._lock:
            if (self._last_dump_t is not None
                    and now - self._last_dump_t < self.min_dump_gap_s):
                return
            self._last_dump_t = now
        bundle = self.dump(reason)
        if self.dump_dir:
            try:
                self._write(bundle)
            except OSError:
                self.auto_dumps.append(bundle)
        else:
            self.auto_dumps.append(bundle)

    def dump(self, reason: str = "manual") -> dict:
        """The post-mortem bundle: everything currently in the rings."""
        with self._lock:
            records = list(self._ring)
            tenants = {t: list(r) for t, r in self._tenants.items()}
            counts = dict(self._counts)
            self.dump_count += 1
        return {"schema": SCHEMA, "reason": reason, "name": self.name,
                "t": round(float(self._clock()), 6),
                "counts": counts, "records": records, "tenants": tenants}

    def _write(self, bundle: dict) -> str:
        import datetime
        stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
            "%Y%m%dT%H%M%SZ")
        os.makedirs(self.dump_dir, exist_ok=True)
        reason = "".join(c if c.isalnum() else "-" for c in bundle["reason"])
        # dump_count disambiguates dumps landing in the same second
        # (e.g. a shed storm with the cooldown disabled).
        path = os.path.join(
            self.dump_dir,
            f"FLIGHT_{self.name}_{stamp}_{self.dump_count:03d}_"
            f"{reason}.json")
        with open(path, "w") as f:
            json.dump(bundle, f, indent=1, default=float)
        self.dump_paths.append(path)
        return path

    def write_dump(self, path: str, reason: str = "manual") -> str:
        """Write one explicit dump to ``path`` (benches: the overload-run
        artifact the regression gate validates)."""
        bundle = self.dump(reason)
        with open(path, "w") as f:
            json.dump(bundle, f, indent=1, default=float)
        self.dump_paths.append(path)
        return path

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def summary(self) -> dict:
        """Registry-collector view: ring occupancy + trigger counters."""
        with self._lock:
            return {"records": len(self._ring),
                    "tenants": {t: len(r) for t, r in self._tenants.items()},
                    "counts": dict(self._counts),
                    "dumps": self.dump_count,
                    "auto_dumps": len(self.auto_dumps)
                    + len(self.dump_paths)}


def validate_dump(bundle: dict) -> bool:
    """Schema check for a flight dump (the regression gate's validator)."""
    return (isinstance(bundle, dict)
            and bundle.get("schema") == SCHEMA
            and isinstance(bundle.get("records"), list)
            and isinstance(bundle.get("tenants"), dict)
            and isinstance(bundle.get("counts"), dict)
            and isinstance(bundle.get("reason"), str))


def dump_live_recorders(dump_dir: str, reason: str = "bench_failure"
                        ) -> list[str]:
    """Dump every live, non-empty recorder to ``dump_dir`` — the bench
    harness calls this when a bench step fails so CI uploads the recent
    query lifecycles next to the ``BENCH_*.json`` rollup."""
    paths = []
    for rec in list(_LIVE):
        if len(rec) == 0:
            continue
        prev = rec.dump_dir
        rec.dump_dir = dump_dir
        try:
            paths.append(rec._write(rec.dump(reason)))
        except OSError:
            pass
        finally:
            rec.dump_dir = prev
    return paths
