"""SLO burn-rate monitoring over the engine's per-tenant counters.

An :class:`SLObjective` states a target success ratio over a pair of
counters — e.g. *deadline*: ``deadline_hits`` good / ``deadline_misses``
bad, target 0.75 — leaving an **error budget** of ``1 - target``.  The
**burn rate** over a window is how fast that budget is being consumed:

    burn = windowed_error_rate / error_budget

(burn 1.0 = exactly on budget; 2.0 = spending it twice as fast as the
objective allows).  Following the multi-window alerting idiom, an alert
fires only when the burn exceeds ``burn_threshold`` in *both* a fast and
a slow window — the fast window gives detection latency, the slow one
suppresses blips — and only once at least ``min_events`` landed in the
window (tiny denominators make infinite-looking burns out of one miss).

The monitor is fed from the ``MetricsRegistry`` the service already
maintains: :meth:`SLOMonitor.evaluate` samples the cumulative per-tenant
counters into a timestamped history and differences them against the
window edges, so it needs no second event stream.  Each tenant is
evaluated separately plus an aggregate pseudo-tenant ``"*"`` (small
smoke runs rarely give one tenant ``min_events`` alone).  On an alert
*transition* it bumps ``slo_alerts_total``, appends a structured
``slo`` event to the registry and an ``slo_alert`` instant to the
tracer; :meth:`summary` (registered as the ``"slo"`` collector) carries
the active alerts into every ``stats()`` snapshot.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One objective over a good/bad counter pair."""

    name: str                      # e.g. "deadline", "shed"
    good: str                      # counter name of successes
    bad: str                       # counter name of failures
    target: float                  # objective on good/(good+bad)
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    burn_threshold: float = 2.0
    min_events: int = 8

    @property
    def error_budget(self) -> float:
        return max(1e-9, 1.0 - float(self.target))


def default_objectives() -> tuple[SLObjective, ...]:
    """The service's stock objectives: per-tenant deadline hits and shed
    rate.  Targets are deliberately loose — the monitor exists to flag
    *storms* (burn >= 2x budget), not percentage drift."""
    return (
        SLObjective("deadline", good="deadline_hits",
                    bad="deadline_misses", target=0.75),
        SLObjective("shed", good="admitted", bad="shed", target=0.95),
    )


class SLOMonitor:
    """Multi-window burn-rate evaluation over registry counters."""

    def __init__(self, metrics, objectives=None, *,
                 clock=time.monotonic, tracer=None,
                 min_interval_s: float = 0.25, max_history: int = 4096):
        self.metrics = metrics
        self.objectives = tuple(objectives if objectives is not None
                                else default_objectives())
        self.tracer = tracer
        self._clock = clock
        self.min_interval_s = float(min_interval_s)
        self._lock = threading.Lock()
        # History: (t, {objective: {tenant: (good, bad)}}) cumulative
        # samples; bounded, oldest dropped (windows larger than the
        # retained span degrade to since-oldest deltas).
        self._history: deque = deque(maxlen=int(max_history))
        self._active: dict[tuple, dict] = {}
        self._last_eval_t: float | None = None
        self.evaluations = 0
        self.alerts_total = 0

    # -- sampling ------------------------------------------------------------
    def _sample(self) -> dict:
        out: dict = {}
        for obj in self.objectives:
            goods = self._per_tenant(obj.good)
            bads = self._per_tenant(obj.bad)
            tenants = set(goods) | set(bads)
            per = {t: (goods.get(t, 0.0), bads.get(t, 0.0))
                   for t in tenants}
            per["*"] = (sum(goods.values()), sum(bads.values()))
            out[obj.name] = per
        return out

    def _per_tenant(self, counter: str) -> dict[str, float]:
        out: dict[str, float] = {}
        for labels, v in self.metrics.counter_series(counter).items():
            tenant = dict(labels).get("tenant")
            if tenant is not None:
                out[tenant] = out.get(tenant, 0.0) + v
        return out

    def _baseline(self, now: float, window_s: float):
        """The newest sample at/before ``now - window_s`` (a sample aged
        exactly to the window edge IS the baseline), else the oldest
        retained sample (partial window: deltas since monitoring began)."""
        edge = now - window_s
        base = None
        for t, sample in self._history:
            if t <= edge:
                base = sample
            else:
                break
        if base is None and self._history:
            base = self._history[0][1]
        return base

    @staticmethod
    def _window_rate(cur: tuple, base: tuple | None
                     ) -> tuple[float, float]:
        """(error_rate, events) between a baseline and current sample."""
        bg, bb = base if base is not None else (0.0, 0.0)
        d_good = max(0.0, cur[0] - bg)
        d_bad = max(0.0, cur[1] - bb)
        total = d_good + d_bad
        if total <= 0:
            return 0.0, 0.0
        return d_bad / total, total

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, force: bool = False) -> list[dict]:
        """Sample the counters, update burn rates, fire/clear alerts.
        Returns the currently-active alerts.  Throttled to
        ``min_interval_s`` unless ``force``."""
        now = self._clock()
        with self._lock:
            if (not force and self._last_eval_t is not None
                    and now - self._last_eval_t < self.min_interval_s):
                return [dict(a) for a in self._active.values()]
            self._last_eval_t = now
        sample = self._sample()
        fired, cleared = [], []
        with self._lock:
            self._history.append((now, sample))
            self.evaluations += 1
            base_of = {}
            for obj in self.objectives:
                for win in (obj.fast_window_s, obj.slow_window_s):
                    if win not in base_of:
                        base_of[win] = self._baseline(now, win)
            for obj in self.objectives:
                for tenant, cur in sample[obj.name].items():
                    burns, events = {}, {}
                    for tag, win in (("fast", obj.fast_window_s),
                                     ("slow", obj.slow_window_s)):
                        base = base_of[win]
                        bt = (base or {}).get(obj.name, {}).get(tenant) \
                            if base else None
                        rate, n = self._window_rate(cur, bt)
                        burns[tag] = rate / obj.error_budget
                        events[tag] = n
                    firing = (burns["fast"] >= obj.burn_threshold
                              and burns["slow"] >= obj.burn_threshold
                              and events["fast"] >= obj.min_events)
                    key = (obj.name, tenant)
                    if firing and key not in self._active:
                        alert = {"objective": obj.name, "tenant": tenant,
                                 "burn_fast": round(burns["fast"], 3),
                                 "burn_slow": round(burns["slow"], 3),
                                 "events_fast": events["fast"],
                                 "threshold": obj.burn_threshold,
                                 "since_t": now}
                        self._active[key] = alert
                        self.alerts_total += 1
                        fired.append(alert)
                    elif not firing and key in self._active:
                        cleared.append(self._active.pop(key))
                    elif firing:
                        a = self._active[key]
                        a["burn_fast"] = round(burns["fast"], 3)
                        a["burn_slow"] = round(burns["slow"], 3)
            active = [dict(a) for a in self._active.values()]
        # Transitions emit outside the monitor lock (registry is a leaf
        # lock; tracer takes its own).
        for alert in fired:
            self.metrics.inc("slo_alerts_total",
                             objective=alert["objective"],
                             tenant=alert["tenant"])
            self.metrics.event("slo", action="fire", **alert)
            if self.tracer is not None:
                self.tracer.instant("slo_alert",
                                    objective=alert["objective"],
                                    slo_tenant=alert["tenant"],
                                    burn=alert["burn_fast"])
        for alert in cleared:
            self.metrics.event("slo", action="resolve",
                               objective=alert["objective"],
                               tenant=alert["tenant"])
        return active

    def alerts(self) -> list[dict]:
        with self._lock:
            return [dict(a) for a in self._active.values()]

    def summary(self) -> dict:
        """Registry-collector view: objectives, active alerts, totals."""
        with self._lock:
            return {"objectives": [
                        {"name": o.name, "target": o.target,
                         "fast_window_s": o.fast_window_s,
                         "slow_window_s": o.slow_window_s,
                         "burn_threshold": o.burn_threshold}
                        for o in self.objectives],
                    "active": [dict(a) for a in self._active.values()],
                    "alerts_total": self.alerts_total,
                    "evaluations": self.evaluations}
