"""Observability: query-lifecycle tracing, metrics, cost-model audit."""
from .audit import CostAudit
from .metrics import MetricsRegistry
from .trace import NULL_TRACER, NullTracer, SpanRecord, Tracer

__all__ = ["CostAudit", "MetricsRegistry", "NULL_TRACER", "NullTracer",
           "SpanRecord", "Tracer"]
