"""Observability: query-lifecycle tracing, metrics, cost-model audit,
flight recording, SLO burn-rate monitoring and drift detection."""
from .audit import CostAudit
from .drift import DriftDetector, PageHinkley
from .flight import (FlightRecorder, dump_live_recorders, summarize_outcome,
                     validate_dump)
from .metrics import MetricsRegistry
from .slo import SLObjective, SLOMonitor, default_objectives
from .trace import NULL_TRACER, NullTracer, SpanRecord, Tracer

__all__ = ["CostAudit", "DriftDetector", "FlightRecorder",
           "MetricsRegistry", "NULL_TRACER", "NullTracer", "PageHinkley",
           "SLObjective", "SLOMonitor", "SpanRecord", "Tracer",
           "default_objectives", "dump_live_recorders",
           "summarize_outcome", "validate_dump"]
