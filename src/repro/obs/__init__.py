"""Observability: query-lifecycle tracing, metrics, cost-model audit,
cardinality audit, host-transfer ledger, flight recording, SLO burn-rate
monitoring and drift detection."""
from .audit import CostAudit
from .cardinality import CardinalityAudit, q_error
from .drift import DriftDetector, PageHinkley
from .flight import (FlightRecorder, dump_live_recorders, summarize_outcome,
                     validate_dump)
from .ledger import CAUSES, INTERMEDIATE_CAUSES, TransferLedger
from .metrics import MetricsRegistry
from .slo import SLObjective, SLOMonitor, default_objectives
from .trace import NULL_TRACER, NullTracer, SpanRecord, Tracer

__all__ = ["CAUSES", "CardinalityAudit", "CostAudit", "DriftDetector",
           "FlightRecorder", "INTERMEDIATE_CAUSES", "MetricsRegistry",
           "NULL_TRACER", "NullTracer", "PageHinkley", "SLObjective",
           "SLOMonitor", "SpanRecord", "Tracer", "TransferLedger",
           "default_objectives", "dump_live_recorders", "q_error",
           "summarize_outcome", "validate_dump"]
