"""Cardinality audit: System-R estimates vs exact device-observed rows.

The executor already computes every stage's exact output cardinality
on-device (``_match_stats_jit`` counts matches before any gather), yet
until now that number was used only to size buffers — the optimizer's
System-R estimates were never confronted with it.  This audit records
the pair for every executed stage and summarizes the **q-error**

    q = max(est / actual, actual / est)    (rows clamped to >= 1)

the standard symmetric measure from the adaptive-query-processing
literature: 1.0 is a perfect estimate, q >= 2 means the optimizer was
off by 2x in either direction.  Per stage-type / depth / tenant p50/p95
summaries surface through ``snapshot()["cardinality_error"]`` alongside
PR 7's time-domain ``prediction_error``, and the executor's adaptive
replan loop uses the same per-stage q-error as its trigger.
"""
from __future__ import annotations

import threading
from collections import deque

from .metrics import _percentile


def q_error(est_rows, observed_rows) -> float:
    """Symmetric multiplicative estimate error, clamped to rows >= 1."""
    e = max(1.0, float(est_rows))
    a = max(1.0, float(observed_rows))
    return max(e / a, a / e)


class CardinalityAudit:
    """Bounded ring of per-stage (estimated, observed) cardinality pairs."""

    def __init__(self, max_records: int = 8192):
        self._lock = threading.Lock()
        self._records: deque = deque(maxlen=int(max_records))

    @property
    def capacity(self) -> int:
        return int(self._records.maxlen or 0)

    def record(self, *, stage_type: str, est_rows: float, observed_rows: int,
               depth: int = 0, tenant: str = "default",
               stage_id: int = -1) -> float:
        """Append one executed stage's pair; returns its q-error.

        ``est_rows`` is the optimizer's ``est_out`` for the stage;
        ``observed_rows`` is the exact pre-residual match count the device
        reported.  Both are clamped to >= 1 for the ratio (an estimate of
        0.3 rows vs an observed 0 is a perfect prediction, not infinite
        error).
        """
        q = q_error(est_rows, observed_rows)
        with self._lock:
            self._records.append({
                "stage_type": str(stage_type), "depth": int(depth),
                "tenant": tenant, "stage_id": int(stage_id),
                "est_rows": float(est_rows),
                "observed_rows": int(observed_rows), "q_error": q})
        return q

    def records(self) -> list[dict]:
        with self._lock:
            return [dict(r) for r in self._records]

    def summary(self) -> dict:
        """Per stage-type / depth / tenant q-error summaries.

        Registered as the ``cardinality_error`` metrics collector; the CI
        gate requires every executed stage type to show a finite p50/p95.
        """
        with self._lock:
            recs = list(self._records)
        by_type: dict[str, list[float]] = {}
        by_depth: dict[str, list[float]] = {}
        by_tenant: dict[str, list[float]] = {}
        for r in recs:
            by_type.setdefault(r["stage_type"], []).append(r["q_error"])
            by_depth.setdefault(str(r["depth"]), []).append(r["q_error"])
            by_tenant.setdefault(r["tenant"], []).append(r["q_error"])

        def _summ(vals):
            s = sorted(vals)
            return {"count": len(s), "p50": _percentile(s, 0.50),
                    "p95": _percentile(s, 0.95), "max": s[-1]}

        return {"count": len(recs),
                "stage_types": {k: _summ(v)
                                for k, v in sorted(by_type.items())},
                "depths": {k: _summ(v) for k, v in sorted(by_depth.items())},
                "tenants": {k: _summ(v)
                            for k, v in sorted(by_tenant.items())}}

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
