"""Cost-model drift detection over the audit trail — and what to do
about it.

The planner's estimates are only trustworthy while the measured-vs-
predicted ratio stays stationary; a workload shift (contention, cache
behaviour, data skew) shows up as a *sustained* move in
``log(measured_s / est_s)``.  :class:`DriftDetector` listens to every
``CostAudit`` record and runs a two-sided Page-Hinkley test per
``(phase, scheme)`` series: cheap (O(1) per sample), with an explicit
mean-shift magnitude (``delta``) below which wiggle is ignored and a
cumulative-deviation ``threshold`` that must accumulate before firing —
one outlier cannot trip it, a sustained shift must.

On a drift firing the detector **acts** (the closed loop this layer is
for):

  * bumps the ``cost_model_staleness`` gauge (global + per-series) and a
    ``cost_model_drift_events`` counter, emits a structured ``drift``
    event and a ``drift_alert`` trace instant;
  * invokes ``on_drift(phase, scheme, stats)`` — the service maps the
    phase to its algorithm and flags the affected sticky plans for
    re-pricing through ``QueryPlanner.flag_replan`` (the existing
    replan-hysteresis path, not a new one);
  * resets that series' test state so it can fire again on a later
    shift.

Independently, a rolling per-tenant ratio window prices a **safety
margin** — ``clamp(q75(ratio), 1.0, margin_cap)`` — pushed through
``on_margin(tenant, margin)`` into ``AdmissionController`` pricing, so
a tenant whose queries keep running 2x over estimate is admitted as if
its estimates were 2x larger (closing ROADMAP item 1's "prediction
error -> admission margin" remainder).
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque


class PageHinkley:
    """Two-sided Page-Hinkley test on a stream of (log-ratio) samples.

    Fires when the cumulative deviation from the running mean exceeds
    ``threshold`` in either direction after at least ``min_samples``.
    """

    def __init__(self, *, delta: float = 0.05, threshold: float = 0.5,
                 min_samples: int = 8):
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        # Separate up/down accumulators: the +/- delta slack must lean
        # *against* each direction's statistic, or a stationary stream
        # drifts one of them across the threshold all by itself.
        self._up = 0.0         # cumulative (x - mean - delta)
        self._up_min = 0.0
        self._dn = 0.0         # cumulative (x - mean + delta)
        self._dn_max = 0.0

    def update(self, x: float) -> bool:
        """Feed one sample; True when a sustained shift is detected."""
        self.n += 1
        self.mean += (x - self.mean) / self.n
        self._up += x - self.mean - self.delta
        self._up_min = min(self._up_min, self._up)
        self._dn += x - self.mean + self.delta
        self._dn_max = max(self._dn_max, self._dn)
        if self.n < self.min_samples:
            return False
        return (self._up - self._up_min > self.threshold
                or self._dn_max - self._dn > self.threshold)


class DriftDetector:
    """Per-(phase, scheme) drift detection + per-tenant safety margins."""

    def __init__(self, *, metrics=None, tracer=None,
                 on_drift=None, on_margin=None,
                 delta: float = 0.05, threshold: float = 0.5,
                 min_samples: int = 8,
                 margin_quantile: float = 0.75, margin_cap: float = 4.0,
                 margin_window: int = 64, margin_min_samples: int = 8,
                 clock=time.monotonic):
        self.metrics = metrics
        self.tracer = tracer
        self.on_drift = on_drift
        self.on_margin = on_margin
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.margin_quantile = float(margin_quantile)
        self.margin_cap = float(margin_cap)
        self.margin_window = int(margin_window)
        self.margin_min_samples = int(margin_min_samples)
        self._clock = clock
        self._lock = threading.Lock()
        self._ph: dict[tuple[str, str], PageHinkley] = {}
        self._ratios: dict[tuple[str, str], deque] = {}
        self._tenant_ratios: dict[str, deque] = {}
        self._margins: dict[str, float] = {}
        self.drift_events = 0
        self._stale_keys: set[tuple[str, str]] = set()
        if self.metrics is not None:
            # Pre-seed: the regression gate requires the staleness gauge
            # present and finite even when nothing ever drifted.
            self.metrics.set_gauge("cost_model_staleness", 0.0)

    # -- the audit listener --------------------------------------------------
    def observe_record(self, rec: dict) -> None:
        """One ``CostAudit`` record (the registered listener)."""
        ratio = rec.get("ratio")
        if ratio is None or not (ratio > 0.0) or not math.isfinite(ratio):
            return
        phase, scheme = rec.get("phase", "?"), rec.get("scheme", "?")
        tenant = rec.get("tenant", "default")
        x = math.log(ratio)
        fired_stats = None
        margin_update = None
        with self._lock:
            key = (phase, scheme)
            ph = self._ph.get(key)
            if ph is None:
                ph = self._ph[key] = PageHinkley(
                    delta=self.delta, threshold=self.threshold,
                    min_samples=self.min_samples)
            ring = self._ratios.setdefault(key, deque(maxlen=64))
            ring.append(float(ratio))
            if ph.update(x):
                self.drift_events += 1
                self._stale_keys.add(key)
                fired_stats = {"phase": phase, "scheme": scheme,
                               "mean_log_ratio": round(ph.mean, 4),
                               "mean_ratio": round(math.exp(ph.mean), 4),
                               "samples": ph.n,
                               "drift_events": self.drift_events}
                ph.reset()       # re-arm: a later shift can fire again
            tring = self._tenant_ratios.setdefault(
                tenant, deque(maxlen=self.margin_window))
            tring.append(float(ratio))
            if len(tring) >= self.margin_min_samples:
                margin = self._price_margin(tring)
                if abs(margin - self._margins.get(tenant, 1.0)) > 1e-3:
                    self._margins[tenant] = margin
                    margin_update = (tenant, margin)
        # Emissions happen outside the detector lock (registry is a leaf
        # lock; callbacks reach into planner/admission).
        if fired_stats is not None:
            self._emit_drift(fired_stats)
        if margin_update is not None:
            tenant, margin = margin_update
            if self.metrics is not None:
                self.metrics.set_gauge("admission_margin", margin,
                                       tenant=tenant)
            if self.on_margin is not None:
                self.on_margin(tenant, margin)

    def _price_margin(self, ratios: deque) -> float:
        s = sorted(ratios)
        idx = min(len(s) - 1,
                  max(0, int(round(self.margin_quantile * (len(s) - 1)))))
        return max(1.0, min(self.margin_cap, float(s[idx])))

    def _emit_drift(self, stats: dict) -> None:
        if self.metrics is not None:
            self.metrics.inc("cost_model_drift_events",
                             phase=stats["phase"], scheme=stats["scheme"])
            self.metrics.set_gauge("cost_model_staleness",
                                   float(len(self._stale_keys)))
            self.metrics.set_gauge("cost_model_staleness", 1.0,
                                   phase=stats["phase"],
                                   scheme=stats["scheme"])
            self.metrics.event("drift", **stats)
        if self.tracer is not None:
            self.tracer.instant("drift_alert", phase=stats["phase"],
                                drift_scheme=stats["scheme"],
                                mean_ratio=stats["mean_ratio"])
        if self.on_drift is not None:
            try:
                self.on_drift(stats["phase"], stats["scheme"], stats)
            except Exception:
                pass

    def mark_repriced(self, phase: str, scheme: str) -> None:
        """Clear a series' staleness after its plans were re-priced."""
        with self._lock:
            self._stale_keys.discard((phase, scheme))
            stale = float(len(self._stale_keys))
        if self.metrics is not None:
            self.metrics.set_gauge("cost_model_staleness", stale)
            self.metrics.set_gauge("cost_model_staleness", 0.0,
                                   phase=phase, scheme=scheme)

    def margin_of(self, tenant: str) -> float:
        with self._lock:
            return self._margins.get(tenant, 1.0)

    def summary(self) -> dict:
        """Registry-collector view: per-series state + tenant margins."""
        with self._lock:
            series = {}
            for (phase, scheme), ph in self._ph.items():
                ring = self._ratios.get((phase, scheme), ())
                n = len(ring)
                mean_ratio = (sum(ring) / n) if n else 1.0
                series[f"{phase}/{scheme}"] = {
                    "samples": ph.n, "window": n,
                    "mean_ratio": round(mean_ratio, 4),
                    "stale": (phase, scheme) in self._stale_keys}
            return {"series": series,
                    "margins": dict(self._margins),
                    "drift_events": self.drift_events,
                    "stale_series": len(self._stale_keys)}
