"""Trigger the flight recorder and read its post-mortem dump.

Drives a burst of deadline-doomed queries at ``JoinQueryService`` so the
admission layer sheds a storm of them, which trips the flight recorder's
shed-storm trigger; then injects one failing pipeline stage, which
always dumps.  Prints where each dump landed and a digest of the last
bundle — the recent query lifecycles (outcome summaries, admission
decisions, the failure) a post-mortem starts from.

    PYTHONPATH=src python examples/flight_recorder.py [--out-dir dumps]
"""
import argparse
import json
import os

from repro.core import CoProcessor, uniform_relation, unique_relation
from repro.engine import (Backpressure, JoinQuery, JoinQueryService,
                          QueryPlanner, Tenant)
from repro.obs import FlightRecorder, validate_dump


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="dumps")
    ap.add_argument("--rows", type=int, default=16384)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cp = CoProcessor()
    planner = QueryPlanner(delta=0.25)
    # A recorder that writes dumps straight to disk, with a small storm
    # threshold and no cooldown so the demo fires quickly.
    flight = FlightRecorder(name="demo", storm_n=4, storm_window_s=10.0,
                            min_dump_gap_s=0.0, dump_dir=args.out_dir)
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                           tenants=[Tenant("gold", deadline_s=30.0)],
                           flight=flight)
    with svc:
        # 1) Normal traffic: lifecycles land in the ring.
        for i in range(4):
            b = unique_relation(args.rows, seed=i)
            s = uniform_relation(args.rows, key_range=args.rows,
                                 seed=100 + i)
            svc.submit(JoinQuery(build=b, probe=s, query_id=i,
                                 tenant="gold"))()
        print(f"recorded {len(svc.flight)} lifecycle records")

        # 2) A shed storm: impossible deadlines -> admission sheds them
        #    back-to-back, tripping the storm trigger.
        svc._admission_estimate = lambda q: (60.0, 0.5)
        svc._degraded_estimate = lambda q: None
        shed = 0
        for i in range(8):
            b = unique_relation(256, seed=i)
            s = uniform_relation(256, key_range=256, seed=i + 1)
            try:
                svc.submit(JoinQuery(build=b, probe=s, query_id=100 + i,
                                     tenant="gold"), block=False)
            except Backpressure:
                shed += 1
        print(f"shed {shed} queries -> storm dump(s): "
              f"{[os.path.basename(p) for p in svc.flight.dump_paths]}")

        # 3) A failing stage: always dumps.
        svc._admission_estimate = lambda q: (1e-3, 0.5)
        handle = svc.submit_deferred(
            lambda outs: (_ for _ in ()).throw(RuntimeError("stage bug")),
            tenant="gold")
        try:
            handle()
        except RuntimeError:
            pass

    paths = svc.flight.dump_paths
    print(f"{len(paths)} dump(s) in {args.out_dir}/")
    with open(paths[-1]) as f:
        bundle = json.load(f)
    assert validate_dump(bundle), "dump failed schema validation"
    print(f"last dump: reason={bundle['reason']!r}, "
          f"counts={bundle['counts']}, tenants={list(bundle['tenants'])}")
    for rec in bundle["records"][-5:]:
        kind = rec["kind"]
        if kind == "outcome":
            print(f"  t={rec['t']:.3f} outcome q{rec['query_id']} "
                  f"{rec['algorithm']}/{rec['scheme']} "
                  f"wall={rec['wall_s']:.4f}s")
        elif kind == "admission":
            print(f"  t={rec['t']:.3f} admission {rec['action']} "
                  f"q{rec.get('query_id')} ({rec.get('reason')})")
        else:
            print(f"  t={rec['t']:.3f} FAILURE {rec.get('where')}: "
                  f"{rec.get('error')}")


if __name__ == "__main__":
    main()
