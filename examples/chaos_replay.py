"""Replay a join workload under seeded fault injection.

Installs a :class:`FaultInjector` whose per-site schedule is derived
from one seed — kernel launches raise, H2D transfers stall, one worker
thread dies — and drives a stream of joins through ``JoinQueryService``
while the faults fire.  The service's recovery ladder (bounded retries
-> degraded plan -> circuit breaker -> NumPy reference path) absorbs
every transient fault: the demo asserts that each query either succeeds
row-exactly against the NumPy oracle or fails with a *structured*
``Backpressure`` error, then prints the resilience counters and breaker
states the chaos left behind.

Because the injector is seed-deterministic, re-running with the same
``--seed`` replays the identical fault schedule — which is how the
chaos section of ``benchmarks/slo_bench.py`` stays debuggable.

    PYTHONPATH=src python examples/chaos_replay.py [--seed 7] [--queries 12]
"""
import argparse

import numpy as np

from repro.core import CoProcessor, uniform_relation, unique_relation
from repro.engine import (FaultInjector, FaultSpec, JoinQuery,
                         JoinQueryService, QueryPlanner, QueueFull,
                         injected)
from repro.ops.join_variants import join_variant_oracle


def result_rows(result):
    """(probe_rid, build_rid) pairs, sorted — the oracle's shape."""
    n = int(result.count)
    rows = np.stack([np.asarray(result.probe_rid[:n]),
                     np.asarray(result.build_rid[:n])], axis=1)
    return rows[np.lexsort((rows[:, 1], rows[:, 0]))]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--rows", type=int, default=4096)
    args = ap.parse_args()

    cp = CoProcessor()
    planner = QueryPlanner(delta=0.25)

    # The fault schedule: every 3rd kernel launch raises (transient, so
    # the ladder engages), 20% of H2D transfers stall 2ms, and the 2nd
    # worker-loop iteration dies (the supervisor restarts it).
    inj = FaultInjector(seed=args.seed, sites={
        "kernel": FaultSpec(mode="raise", every=3, max_faults=6),
        "h2d": FaultSpec(mode="delay", p=0.2, delay_s=0.002),
        "worker": FaultSpec(mode="raise", at=(2,)),
    })

    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                           preempt=True)
    queries, waits = [], []
    for i in range(args.queries):
        b = unique_relation(args.rows, seed=i)
        s = uniform_relation(args.rows, key_range=args.rows,
                             seed=100 + i)
        queries.append(JoinQuery(build=b, probe=s, query_id=i,
                                 max_out=8 * args.rows))

    structured = unstructured = exact = 0
    with injected(inj):                       # uninstalls on exit
        for q in queries:
            waits.append(svc.submit(q))
        for q, w in zip(queries, waits):
            try:
                out = w()
            except QueueFull:                 # Backpressure family
                structured += 1
                continue
            except Exception as e:            # would be a ladder bug
                unstructured += 1
                print(f"  q{q.query_id} UNSTRUCTURED: {e!r}")
                continue
            want = join_variant_oracle(q.build, q.probe, "inner")
            ok = np.array_equal(result_rows(out.result), want)
            exact += ok
            note = (" [reference path]" if out.timing is not None and
                    out.timing.notes.get("reference_path") else "")
            print(f"  q{q.query_id} {out.plan.algorithm}/{out.plan.scheme}"
                  f" rows={int(out.result.count)}"
                  f" exact={bool(ok)}{note}")
    svc.close(drain=True)

    res = svc.stats()["resilience"]
    print(f"\nfaults fired: {inj.stats()['fired']}")
    print(f"retries={res['retries']} worker_restarts="
          f"{res['worker_restarts']} preemptions={res['preemptions']}")
    print(f"breakers: { {k: v['state'] for k, v in res['breakers'].items()} }")
    print(f"{exact}/{args.queries - structured} row-exact, "
          f"{structured} structured failures, {unstructured} unstructured")
    assert unstructured == 0, "every failure must be structured"
    assert exact == args.queries - structured, "survivors must be exact"
    print("chaos replay clean: structured failures only, row-exact output")


if __name__ == "__main__":
    main()
