"""End-to-end driver: the paper's full experiment pipeline at scale.

Runs SHJ and PHJ under every co-processing scheme (CPU-only, OL, DD, PL,
BasicUnit) on uniform and skewed data, with cost-model-chosen knobs, and
verifies every result against the oracle.

    PYTHONPATH=src python examples/coprocess_join.py [--tuples 1000000]
"""
import argparse
import numpy as np

from repro.core import (CoProcessor, join_oracle, series_model_from_costs,
                        skewed_relation, uniform_relation, ICI_LINK)
from repro.core.calibrate import APU_CPU, APU_GPU
from repro.core.shj import BUILD_SERIES, PROBE_SERIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tuples", type=int, default=250_000)
    args = ap.parse_args()
    n = args.tuples
    cp = CoProcessor()

    for skew, gen in (("uniform", uniform_relation),
                      ("high-skew", lambda m, seed: skewed_relation(
                          m, s_percent=25, seed=seed))):
        r = gen(n, seed=1)
        s = gen(n, seed=2)
        exp = join_oracle(r, s)
        print(f"\n== {skew}: |R|=|S|={n:,}, matches={len(exp):,} ==")

        # Cost-model-chosen PL ratios per phase (the paper's automaticity).
        rb, _ = series_model_from_costs(
            BUILD_SERIES.steps, [n] * 4, APU_CPU, APU_GPU,
            ICI_LINK).optimize_pl(delta=0.05)
        rp, _ = series_model_from_costs(
            PROBE_SERIES.steps, [n] * 4, APU_CPU, APU_GPU,
            ICI_LINK).optimize_pl(delta=0.05)

        nb = max(1024, n // 4)
        mo = 2 * n + len(exp)
        plans = {
            "CPU-only": ([1.0] * 4, [1.0] * 4),
            "OL (GPU)": ([0.0] * 4, [0.0] * 4),
            "DD": ([0.25] * 4, [0.42] * 4),
            "PL (model)": (list(rb), list(rp)),
        }
        for name, (br, pr) in plans.items():
            res, t = cp.shj(r, s, num_buckets=nb, max_out=mo,
                            build_ratios=br, probe_ratios=pr,
                            table_mode="shared")
            ok = (res.valid_pairs() == exp).all()
            print(f"  SHJ {name:11s} {t.wall_s*1e3:8.0f}ms verified={ok}")
            assert ok
        res, t = cp.phj(r, s, shj_bits=2,  # planner picks the pass schedule
                        max_out=mo, partition_ratio=0.25, join_ratio=0.4)
        ok = (res.valid_pairs() == exp).all()
        print(f"  PHJ DD/PL     {t.wall_s*1e3:8.0f}ms verified={ok} "
              f"(partition {t.phase_s['partition']*1e3:.0f}ms)")
        assert ok
        res, t, ratios = cp.basic_unit_shj(r, s, num_buckets=nb, max_out=mo,
                                           chunk=max(4096, n // 16))
        ok = (res.valid_pairs() == exp).all()
        print(f"  BasicUnit     {t.wall_s*1e3:8.0f}ms verified={ok} "
              f"realized-ratios={ {k: round(v,2) for k,v in ratios.items()} }")
        assert ok
    print("\nall schemes verified ✓")


if __name__ == "__main__":
    main()
