"""Run a multi-join star query through the query pipeline.

Demonstrates the full stack: a declarative ``Query`` (fact table, filtered
dimensions, count sink), cost-model join ordering (chosen vs textual vs
worst estimates), pipelined execution through ``JoinQueryService`` with
per-stage scheme/algorithm planning and build-side cache reuse — verified
against the pure-NumPy reference join.

    PYTHONPATH=src python examples/query_pipeline.py [--fact-rows 65536]
"""
import argparse
import time

from repro.core import CoProcessor
from repro.engine import JoinQueryService, QueryPlanner
from repro.queries import (JoinOrderOptimizer, PipelineExecutor,
                           make_star_query, reference_execute)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fact-rows", type=int, default=65536)
    ap.add_argument("--dim-rows", type=int, default=8192)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    cp = CoProcessor()
    print("calibrating unit costs on this host (paper §4.2)...")
    planner = QueryPlanner.calibrated(cp, n=16384, reps=2, delta=0.1)
    optimizer = JoinOrderOptimizer(planner)

    query = make_star_query(args.fact_rows, [args.dim_rows] * 3,
                            selectivities=[0.02, None, 0.5], seed=17,
                            aggregate=("count",))
    print(f"query: {query.describe()}\n")

    chosen = optimizer.optimize(query)
    worst = optimizer.worst_order(query)
    textual = optimizer.price_order(query, query.joins)
    print(chosen.describe())
    print(f"(textual order est {textual.est_total_s * 1e3:.2f} ms, "
          f"worst order est {worst.est_total_s * 1e3:.2f} ms)\n")

    ref_rows, ref_agg = reference_execute(query)
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=args.workers)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        res = ex.run(query, chosen)          # compile + warm the caches
        t0 = time.perf_counter()
        res = ex.run(query, chosen)
        elapsed = time.perf_counter() - t0
        hdr = (f"{'stage':<28} {'plan':<12} {'build':>7} {'probe':>7} "
               f"{'ms':>8} {'cache':<10}")
        print(hdr + "\n" + "-" * len(hdr))
        for s, o in zip(chosen.stages, res.outcomes):
            hit = ("table" if o.cache_hit else
                   "partition" if o.partition_cache_hit else "")
            print(f"{o.tag:<28} {o.plan.algorithm}/{o.plan.scheme:<8} "
                  f"{s.est_build:>7} {s.est_probe:>7} "
                  f"{o.wall_s * 1e3:>8.1f} {hit:<10}")
        st = svc.stats()

    got_rows, got_agg = res.rows_array(), res.aggregate
    assert got_agg == ref_agg and (got_rows == ref_rows).all()
    print(f"\n{res.rows} result rows (count={got_agg}) verified against "
          f"the NumPy reference")
    print(f"pipeline wall: {elapsed * 1e3:.1f} ms "
          f"(optimizer estimated {chosen.est_total_s * 1e3:.2f} ms)")
    c = st["cache"]
    print(f"caches: {c['hits']} table hits, "
          f"{c['partition_hits']} partition-layout hits, "
          f"{c['bytes'] / 2**20:.1f} MiB resident")
    print(f"stage hand-off: device-resident (StageView rid-chains), "
          f"{st['host_bytes_moved']} intermediate bytes through the host")


if __name__ == "__main__":
    main()
