"""End-to-end LM training: ~100M-parameter dense model, few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300

One CPU core sustains ~100M params at seq 128 / batch 4; on a pod the same
script scales through repro.launch.train (this example is the minimal
self-contained form: config -> data -> sharded train step -> checkpoints).
Use --tiny for a seconds-long demo run.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ShapeSpec, get_config
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import TRAIN_RULES
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def config_100m():
    """qwen3-family block at ~100M params."""
    return dataclasses.replace(
        get_config("qwen3_8b"), name="qwen3_100m", num_layers=10,
        d_model=640, num_heads=10, num_kv_heads=2, head_dim=64, d_ff=1792,
        vocab_size=32_000, rope_theta=1e4, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    seq, batch = 128, 4
    if args.tiny:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=256, vocab_size=2048)
        seq, batch = 64, 4
    mesh = make_host_mesh()
    opt = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps of "
          f"{batch}x{seq} tokens")
    opt_state = adamw_init(params, opt)
    step_fn = jax.jit(make_train_step(cfg, mesh, TRAIN_RULES, opt))
    ds = SyntheticLM(cfg.vocab_size, seq, batch)
    mgr = CheckpointManager(args.ckpt_dir, save_every=100)

    t0, first_loss = time.time(), None
    for step in range(args.steps):
        batch_np = ds.batch(step)
        params, opt_state, m = step_fn(
            params, opt_state,
            {k: jnp.asarray(v) for k, v in batch_np.items()})
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            first_loss = first_loss if first_loss is not None else loss
            tok_s = (step + 1) * batch * seq / (time.time() - t0)
            print(f"  step {step:4d} loss={loss:.4f} tok/s={tok_s:,.0f}")
        mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
    print(f"loss {first_loss:.3f} -> {float(m['loss']):.3f} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
