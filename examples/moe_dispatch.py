"""The paper's technique inside the LM stack: MoE expert dispatch as
radix partitioning (n1/n2/n3), vs. the dense one-hot dispatch.

    PYTHONPATH=src python examples/moe_dispatch.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MoECfg, all_configs, reduced
from repro.layers.moe import moe_dense, moe_sorted, moe_specs
from repro.models.params import materialize

cfg = reduced(all_configs()["granite_moe_3b"])
cfg = dataclasses.replace(
    cfg, moe=MoECfg(num_experts=16, top_k=4, d_ff=64, capacity_factor=1.5,
                    group_size=4096))
params = materialize(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 512, cfg.d_model))

f_dense = jax.jit(lambda p, x: moe_dense(p, cfg, x))
f_sorted = jax.jit(lambda p, x: moe_sorted(p, cfg, x))
y1, aux1 = jax.block_until_ready(f_dense(params, x))
y2, aux2 = jax.block_until_ready(f_sorted(params, x))
np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5)
print("dense one-hot dispatch == radix-partition dispatch ✓",
      f"(aux load-balance loss {float(aux1):.3f})")

for name, f in (("dense", f_dense), ("sorted(n1-n3)", f_sorted)):
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f(params, x))
    print(f"  {name:14s} {(time.perf_counter()-t0)/5*1e3:7.1f} ms/call")
print("\nThe 'sorted' path routes tokens with repro.core.partition --")
print("the same n1 (expert id) / n2 (histogram+scan) / n3 (scatter) steps")
print("the paper defines for radix hash-join partitioning.")
