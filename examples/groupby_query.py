"""Declarative group-by with a semi-join filter, end to end.

A star query — fact table F against a filtered dimension (inner) and a
pure filter dimension (semi) — grouped on F's low-cardinality attribute
with a sum aggregate.  The optimizer prices every edge order through the
engine's cost model (semi filters schedule early: they shrink the
pipeline), each stage runs as one engine query, and the group-by sink is
one more engine submission.  The result is verified row/value-exact
against the pure-NumPy reference.

Run:  PYTHONPATH=src python examples/groupby_query.py
"""
import numpy as np

from repro.engine import JoinQueryService, QueryPlanner
from repro.queries import (JoinOrderOptimizer, PipelineExecutor,
                           make_star_query, reference_execute)


def main():
    query = make_star_query(
        1 << 15, [2048, 1024], selectivities=[0.2, 0.5], seed=7,
        join_kinds=["inner", "semi"], group_by=("F.g",),
        aggregate=("sum", "F.m"))
    print("query:", query.describe())

    svc = JoinQueryService(planner=QueryPlanner(delta=0.25), num_workers=2)
    optimizer = JoinOrderOptimizer(svc.planner)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        physical, result = ex.run_optimized(query)
        print(physical.describe())
        print(f"\n{result.rows} groups in {result.wall_s * 1e3:.1f} ms")
        for o in result.outcomes:
            d = o.to_dict()
            print(f"  {d['tag']:28s} {d['algorithm']}/{d['scheme']:9s} "
                  f"kind={d['kind']:6s} wall={d['wall_s'] * 1e3:7.1f} ms")

        ref_rows, _ = reference_execute(query)
        got = result.rows_array()
        assert got.shape == ref_rows.shape and (got == ref_rows).all()
        print("verified: exact match against the NumPy reference")
        top = np.argsort(got[:, -1])[-3:][::-1]
        print("top groups by sum(F.m):")
        for i in top:
            print(f"  F.g={int(got[i, 0]):3d}  sum={int(got[i, -1])}")


if __name__ == "__main__":
    main()
