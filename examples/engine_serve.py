"""Serve a stream of heterogeneous join queries through the engine.

Demonstrates the full loop: workload generation (uniform / zipf /
selectivity / hot-table mix), admission into ``JoinQueryService``,
cost-model planning per query (scheme + SHJ-vs-PHJ), build-table cache
reuse, and the online calibration feedback — with every result verified
against the oracle.

    PYTHONPATH=src python examples/engine_serve.py [--queries 24]
"""
import argparse
import time

from repro.core import CoProcessor, join_oracle
from repro.engine import JoinQueryService, QueryPlanner, make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--base-tuples", type=int, default=16384)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    cp = CoProcessor()
    print("calibrating unit costs on this host (paper §4.2)...")
    planner = QueryPlanner.calibrated(cp, n=16384, reps=2, delta=0.1)
    workload = make_workload("mixed", num_queries=args.queries,
                             base_tuples=args.base_tuples, seed=42)
    print(f"serving {len(workload)} queries "
          f"(C={cp.c.size} dev, G={cp.g.size} dev, "
          f"workers={args.workers})\n")
    t0 = time.perf_counter()
    with JoinQueryService(cp=cp, planner=planner,
                          num_workers=args.workers) as svc:
        outcomes = svc.run(workload)
        elapsed = time.perf_counter() - t0
        hdr = (f"{'id':>3} {'tag':<10} {'|R|':>7} {'|S|':>7} "
               f"{'plan':<10} {'cache':<5} {'ms':>8} {'matches':>8}")
        print(hdr + "\n" + "-" * len(hdr))
        for q, o in zip(workload, outcomes):
            exp = join_oracle(q.build, q.probe)
            assert (o.result.valid_pairs() == exp).all(), q.query_id
            plan = f"{o.plan.algorithm}/{o.plan.scheme}"
            print(f"{q.query_id:>3} {q.tag:<10} {q.build.size:>7} "
                  f"{q.probe.size:>7} {plan:<10} "
                  f"{'HIT' if o.cache_hit else '':<5} "
                  f"{o.wall_s * 1e3:>8.1f} {int(o.result.count):>8}")
        st = svc.stats()
    print(f"\nall {len(outcomes)} results verified against the oracle")
    print(f"throughput: {len(outcomes) / elapsed:.2f} queries/s")
    c = st["cache"]
    print(f"cache: {c['hits']} hits / {c['hits'] + c['misses']} lookups "
          f"(rate {c['hit_rate']:.0%}), {c['bytes'] / 2**20:.1f} MiB "
          f"resident, {c['evictions']} evictions")
    print(f"plans: {st['planner']['plan_counts']}")
    print("online unit-cost scales:",
          {k: round(v["scale"], 2)
           for k, v in st["planner"]["online"].items()})


if __name__ == "__main__":
    main()
