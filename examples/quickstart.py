"""Quickstart: hash-join co-processing in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CoProcessor, join_oracle, series_model_from_costs,
                        uniform_relation, unique_relation, ICI_LINK)
from repro.core.calibrate import APU_CPU, APU_GPU
from repro.core.shj import PROBE_SERIES

# 1. Data: build side R (unique keys), probe side S.
R = unique_relation(100_000, seed=0)
S = uniform_relation(400_000, key_range=150_000, seed=1)

# 2. Pick workload ratios with the paper's cost model (Eqs. 1-5 + δ-sweep).
model = series_model_from_costs(PROBE_SERIES.steps, [S.size] * 4,
                                APU_CPU, APU_GPU, ICI_LINK)
ratios, est = model.optimize_pl(delta=0.05)
print("PL ratios per probe step:", np.round(ratios, 2), f"est={est*1e3:.1f}ms")

# 3. Execute fine-grained co-processing across the two device groups.
cp = CoProcessor()
result, timing = cp.shj(R, S, num_buckets=32_768, max_out=2 * S.size,
                        build_ratios=[0.0, 0.3, 0.5, 0.3],
                        probe_ratios=list(ratios), table_mode="shared")
print(f"joined: {int(result.count):,} pairs in {timing.wall_s*1e3:.0f}ms "
      f"(build {timing.phase_s['build']*1e3:.0f}ms / "
      f"probe {timing.phase_s['probe']*1e3:.0f}ms)")

# 4. Verify against the oracle.
expected = join_oracle(R, S)
assert (result.valid_pairs() == expected).all()
print("verified against sort-merge oracle ✓")
