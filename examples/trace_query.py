"""Trace a 3-join star query and write a Perfetto-loadable trace.json.

Runs one multi-join star through ``PipelineExecutor`` with the service's
default ``Tracer`` on, then exports the recorded query lifecycle —
admit -> queue -> plan -> partition/build -> probe/join -> gather ->
finalize — as Chrome trace-event JSON.  Open https://ui.perfetto.dev and
drag ``trace.json`` in to see the worker tracks, the async queue-wait
lane, and every span's attributes (tenant, scheme, q_key).

Also prints the predicted-vs-measured cost-model audit: per-phase
prediction-error ratios (measured/estimated, p50/p95) from the same run.

    PYTHONPATH=src python examples/trace_query.py [--out trace.json]
"""
import argparse

from repro.core import CoProcessor
from repro.engine import JoinQueryService, QueryPlanner
from repro.queries import (JoinOrderOptimizer, PipelineExecutor,
                           make_star_query, reference_execute)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--fact-rows", type=int, default=65536)
    ap.add_argument("--dim-rows", type=int, default=8192)
    args = ap.parse_args()

    cp = CoProcessor()
    print("calibrating unit costs on this host (paper §4.2)...")
    planner = QueryPlanner.calibrated(cp, n=16384, reps=1, delta=0.25)
    optimizer = JoinOrderOptimizer(planner)

    query = make_star_query(args.fact_rows, [args.dim_rows] * 3,
                            selectivities=[0.02, None, 0.5], seed=17,
                            aggregate=("count",))
    print(f"query: {query.describe()}\n")
    ref_rows, ref_agg = reference_execute(query)

    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2)
    chosen = optimizer.optimize(query)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        ex.run(query, chosen)               # warm: compiles land here
        svc.tracer.clear()                  # keep only the traced run
        res = ex.run(query, chosen, tenant="demo")
        st = svc.stats()

    assert res.aggregate == ref_agg and (res.rows_array() == ref_rows).all()
    path = svc.tracer.write_chrome_trace(args.out)
    spans = svc.tracer.spans()
    print(f"{len(spans)} spans from {len(res.outcomes)} stages "
          f"-> {path}  (load it at https://ui.perfetto.dev)")

    # Per-stage structured traces ride on every outcome too.
    for o in res.outcomes:
        phases = ", ".join(
            f"{d['name']}={d['dur_s'] * 1e3:.1f}ms" for d in o.trace
            if d["name"] in ("partition", "build", "probe", "join"))
        print(f"  {o.tag:<28} {o.plan.algorithm}/{o.plan.scheme:<8} "
              f"{phases}")

    audit = st["metrics"]["prediction_error"]
    print(f"\ncost-model audit: {audit['count']} phase executions")
    for phase, s in sorted(audit["phases"].items()):
        print(f"  {phase:<10} measured/est p50={s['p50']:.2f} "
              f"p95={s['p95']:.2f}  (n={s['count']})")


if __name__ == "__main__":
    main()
