"""Group-by + join-variant benchmark for the ops subsystem.

Two measured figures:

  1. **group-by schemes** — both co-processed plans (DD_SEPARATE: row
     split + partial-aggregate merge; DD_PARTITIONED: planner-chosen radix
     schedule, ownership-split reduce) vs the same aggregation pinned
     CPU_ONLY / GPU_ONLY, per input size, each verified against the NumPy
     oracle.  The acceptance bar is the paper's: a co-processed scheme
     must beat the *worse* single group (co-processing never loses to the
     bad placement, even when one group dominates).
  2. **semi vs inner probe** — the same probe relation against the same
     build table under both kinds: semi emits match flags (no p4 payload
     gather), so its probe must not be slower than inner's.

Smoke mode (CI) shrinks sizes so the whole thing runs in tens of seconds.
"""
from __future__ import annotations

import numpy as np

from .common import bench_seed, csv_row, report, time_call


def groupby_bench(smoke: bool = False):
    import jax.numpy as jnp

    from repro.core import CoProcessor
    from repro.core.hash_table import build_hash_table, default_num_buckets
    from repro.core.relation import Relation, uniform_relation
    from repro.engine import QueryPlanner
    from repro.ops import groupby_ref, probe_table_variant

    sizes = [1 << 16] if smoke else [1 << 18, 1 << 19]
    reps = 3
    cp = CoProcessor()
    planner = QueryPlanner(delta=0.25)
    out: dict = {"smoke": smoke, "sizes": sizes, "groupby": []}

    rng = np.random.default_rng(bench_seed(7))
    for n in sizes:
        keys = rng.integers(0, max(64, n // 64), n).astype(np.int32)
        vals = rng.integers(0, 100, n).astype(np.int32)
        rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
        sep = planner._groupby_separate(n)
        part = planner._groupby_coproc(n)

        variants = {
            "CPU_ONLY": dict(schedule=None, partition_ratio=1.0,
                             agg_ratio=1.0),
            "GPU_ONLY": dict(schedule=None, partition_ratio=0.0,
                             agg_ratio=0.0),
            # Row-split partial aggregation + merge at a mid ratio (the
            # calibrated planner lands near here on this host) and at the
            # analytic planner's ratio, plus the partitioned reduce.
            "DD_SEPARATE": dict(schedule=None, partition_ratio=0.25,
                                agg_ratio=0.25),
            "DD_SEPARATE_PLANNED": dict(schedule=None,
                                        partition_ratio=sep.partition_ratio,
                                        agg_ratio=sep.join_ratio),
            "DD_PARTITIONED": dict(schedule=part.schedule,
                                   partition_ratio=part.partition_ratio,
                                   agg_ratio=part.join_ratio),
        }
        ref = groupby_ref(keys, vals)
        times = {}
        for name, kw in variants.items():
            res, _ = cp.groupby(rel, vals, **kw)     # warm + verify
            s = res.sorted()
            assert (s.keys == ref.keys).all() and \
                (s.sums == ref.sums).all() and \
                (s.counts == ref.counts).all(), f"{name} diverges"
            times[name] = time_call(lambda kw=kw: cp.groupby(rel, vals,
                                                             **kw)[0],
                                    reps=reps, warmup=1)
            csv_row(f"groupby/{name.lower()}_n{n}", times[name] * 1e6,
                    f"groups={ref.num_groups}")
        worse_single = max(times["CPU_ONLY"], times["GPU_ONLY"])
        best_single = min(times["CPU_ONLY"], times["GPU_ONLY"])
        coproc = min(times[k] for k in times
                     if k not in ("CPU_ONLY", "GPU_ONLY"))
        row = {"n": n, "num_groups": ref.num_groups,
               "schedule": list(part.schedule), **times,
               "best_coproc_s": coproc,
               "coproc_vs_worse_single": worse_single / coproc,
               "coproc_beats_worse_single": bool(coproc < worse_single),
               "coproc_vs_best_single": best_single / coproc}
        out["groupby"].append(row)
        csv_row(f"groupby/coproc_gain_n{n}", coproc * 1e6,
                f"vs_worse={row['coproc_vs_worse_single']:.2f}x;"
                f"vs_best={row['coproc_vs_best_single']:.2f}x")

    # -- 2. semi vs inner probe cost over the same table ------------------
    n = sizes[-1]
    b = uniform_relation(n // 4, seed=bench_seed(11))
    p = uniform_relation(n, key_range=n // 2, seed=bench_seed(12))   # ~half match
    table = build_hash_table(b, default_num_buckets(n // 4))
    probe_times = {}
    for kind, cap in (("inner", 4 * n + 1024), ("semi", n + 64)):
        res, _ = probe_table_variant(cp, p, table, kind=kind, max_out=cap,
                                     ratios=(0.5,) * 4)      # warm
        probe_times[kind] = time_call(
            lambda kind=kind, cap=cap: probe_table_variant(
                cp, p, table, kind=kind, max_out=cap,
                ratios=(0.5,) * 4)[0].probe_rid,
            reps=reps, warmup=1)
    out["probe_kinds"] = {
        "probe_n": n, **probe_times,
        "semi_speedup_vs_inner": probe_times["inner"] / probe_times["semi"]}
    csv_row("groupby/probe_inner", probe_times["inner"] * 1e6, "")
    csv_row("groupby/probe_semi", probe_times["semi"] * 1e6,
            f"speedup={out['probe_kinds']['semi_speedup_vs_inner']:.2f}x")

    report("groupby_bench", out)
    return out
