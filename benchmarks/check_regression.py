"""Tiny perf regression gate over the BENCH_*.json rollup artifact.

Reads the newest ``reports/bench/BENCH_*.json``, extracts the smoke
query-pipeline figures, and fails (exit 1) when:

  * the fused path moved any intermediate bytes through the host
    (``host_bytes_moved`` must be 0 — the device-resident invariant), or
  * the smoke 3-join star end-to-end time regressed more than
    ``TOLERANCE`` (25%) past the committed baseline value.

The baseline lives in ``benchmarks/baseline.json``; refresh it (with a
note in the commit) whenever an intentional change moves the number.

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import glob
import json
import os
import sys

TOLERANCE = 1.25

HERE = os.path.dirname(__file__)
BASELINE_PATH = os.path.join(HERE, "baseline.json")
BENCH_GLOB = os.path.join(HERE, "..", "reports", "bench", "BENCH_*.json")


def main() -> int:
    rollups = sorted(glob.glob(BENCH_GLOB))
    if not rollups:
        print("check_regression: no BENCH_*.json rollup found", flush=True)
        return 1
    with open(rollups[-1]) as f:
        rollup = json.load(f)
    entry = rollup.get("benchmarks", {}).get("query_pipeline")
    if not entry or not entry.get("ok") or not entry.get("payload"):
        print(f"check_regression: no successful query_pipeline payload in "
              f"{rollups[-1]}", flush=True)
        return 1
    payload = entry["payload"]
    if not payload.get("smoke"):
        print("check_regression: rollup is not a smoke run; gate applies "
              "to CI smoke figures only — skipping", flush=True)
        return 0
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["query_pipeline"]

    failures = []
    fused_bytes = payload["handoff"]["host_bytes_moved_fused"]
    if fused_bytes != 0:
        failures.append(f"fused hand-off moved {fused_bytes} intermediate "
                        f"bytes through the host (want 0)")
    measured = payload["join_order"]["chosen_s"]
    allowed = baseline["smoke_star_chosen_s"] * TOLERANCE
    verdict = "OK" if measured <= allowed else "REGRESSED"
    print(f"check_regression: smoke star chosen order {measured:.3f}s "
          f"(baseline {baseline['smoke_star_chosen_s']:.3f}s, "
          f"allowed {allowed:.3f}s) -> {verdict}", flush=True)
    if measured > allowed:
        failures.append(f"smoke star end-to-end {measured:.3f}s exceeds "
                        f"{TOLERANCE:.2f}x baseline "
                        f"{baseline['smoke_star_chosen_s']:.3f}s")
    print(f"check_regression: fused intermediate host bytes = "
          f"{fused_bytes}", flush=True)
    for msg in failures:
        print(f"check_regression: FAIL — {msg}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
