"""Tiny perf regression gate over the BENCH_*.json rollup artifact.

Reads the newest ``reports/bench/BENCH_*.json``, extracts the smoke
query-pipeline and SLO figures, and fails (exit 1) when:

  * the fused path moved any intermediate bytes through the host
    (``host_bytes_moved`` must be 0 — the device-resident invariant), or
  * the smoke 3-join star end-to-end time regressed more than
    ``TOLERANCE`` (25%) past the committed baseline value, or
  * the smoke ``slo_bench`` deadline hit rate (cost mode) fell below the
    baseline floor, its shed rate rose above the baseline ceiling, or a
    shed query escaped without a structured ``Backpressure``, or
  * the observability plumbing went dark: the cost-model audit trail is
    empty or carries non-finite prediction-error percentiles for an
    executed phase, or the metrics registry's ``host_bytes_moved``
    disagrees with the fused-path figure the hand-off section reported, or
  * the observability loop stopped *acting*: the SLO burn-rate monitor
    fired at steady state (alert noise) or stayed silent through the
    bursty overload replay, the flight-recorder dump is missing or
    schema-invalid, or the ``cost_model_staleness`` gauge is absent or
    non-finite, or
  * the resilience layer stopped earning its keep: deadline preemption
    never fired (or made the gold hit rate worse) in the overload A/B,
    the chaos replay leaked an unstructured failure, produced a
    non-row-exact result, counted a hard failure, left a hung worker,
    or opened a breaker without a structured event on record, or
  * the data-path observability went dark or dishonest: the cardinality
    audit carries no (or non-finite) q-error summary for an executed
    stage type, the fused run's transfer ledger shows an unknown cause
    or any ``handoff`` bytes, the ledger's intermediate sum disagrees
    with the flat fused-path figure, or the adaptive skewed-star run
    failed to replan or to beat static execution.

The baseline lives in ``benchmarks/baseline.json``; refresh it (with a
note in the commit) whenever an intentional change moves the number.
The SLO bounds are deliberately loose — CI hosts are noisy and the smoke
run is small; the gate catches the admission layer breaking outright
(hit rate collapsing, shedding everything), not percentage drift.

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys

TOLERANCE = 1.25

HERE = os.path.dirname(__file__)
BASELINE_PATH = os.path.join(HERE, "baseline.json")
BENCH_GLOB = os.path.join(HERE, "..", "reports", "bench", "BENCH_*.json")


def main() -> int:
    rollups = sorted(glob.glob(BENCH_GLOB))
    if not rollups:
        print("check_regression: no BENCH_*.json rollup found", flush=True)
        return 1
    with open(rollups[-1]) as f:
        rollup = json.load(f)
    entry = rollup.get("benchmarks", {}).get("query_pipeline")
    if not entry or not entry.get("ok") or not entry.get("payload"):
        print(f"check_regression: no successful query_pipeline payload in "
              f"{rollups[-1]}", flush=True)
        return 1
    payload = entry["payload"]
    if not payload.get("smoke"):
        print("check_regression: rollup is not a smoke run; gate applies "
              "to CI smoke figures only — skipping", flush=True)
        return 0
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["query_pipeline"]

    failures = []
    fused_bytes = payload["handoff"]["host_bytes_moved_fused"]
    if fused_bytes != 0:
        failures.append(f"fused hand-off moved {fused_bytes} intermediate "
                        f"bytes through the host (want 0)")
    measured = payload["join_order"]["chosen_s"]
    allowed = baseline["smoke_star_chosen_s"] * TOLERANCE
    verdict = "OK" if measured <= allowed else "REGRESSED"
    print(f"check_regression: smoke star chosen order {measured:.3f}s "
          f"(baseline {baseline['smoke_star_chosen_s']:.3f}s, "
          f"allowed {allowed:.3f}s) -> {verdict}", flush=True)
    if measured > allowed:
        failures.append(f"smoke star end-to-end {measured:.3f}s exceeds "
                        f"{TOLERANCE:.2f}x baseline "
                        f"{baseline['smoke_star_chosen_s']:.3f}s")
    print(f"check_regression: fused intermediate host bytes = "
          f"{fused_bytes}", flush=True)

    # -- observability gate: audit trail populated, registry coherent -----
    snap = payload.get("metrics_snapshot") or {}
    audit = snap.get("prediction_error")
    if not audit or not audit.get("count"):
        failures.append("cost-model audit trail is empty "
                        "(metrics_snapshot.prediction_error missing)")
    else:
        shown = []
        for phase, s in sorted((audit.get("phases") or {}).items()):
            p50, p95 = s.get("p50"), s.get("p95")
            finite = all(isinstance(v, (int, float)) and math.isfinite(v)
                         for v in (p50, p95))
            if not s.get("count") or not finite:
                failures.append(f"prediction-error summary for phase "
                                f"'{phase}' is missing or non-finite: {s}")
            else:
                shown.append(f"{phase}: p50={p50:.2f} p95={p95:.2f}")
        if not audit.get("phases"):
            failures.append("cost-model audit has records but no "
                            "per-phase prediction-error summaries")
        print(f"check_regression: audit records={audit['count']}, "
              f"prediction-error ratios {{{'; '.join(shown)}}}", flush=True)
    reg_bytes = snap.get("host_bytes_moved")
    if reg_bytes != fused_bytes:
        failures.append(f"metrics registry host_bytes_moved={reg_bytes} "
                        f"disagrees with the fused hand-off figure "
                        f"{fused_bytes}")

    # -- data-path observability: cardinality audit present + finite ------
    KNOWN_CAUSES = ("fingerprint", "multicol_pack", "handoff", "result")
    INTERMEDIATE = ("fingerprint", "multicol_pack", "handoff")
    card = payload.get("cardinality") or {}
    if not card.get("count") or not card.get("stage_types"):
        failures.append("cardinality audit is empty (payload.cardinality "
                        "missing stage-type q-error summaries)")
    else:
        shown = []
        for stype, s in sorted(card["stage_types"].items()):
            p50, p95 = s.get("p50"), s.get("p95")
            finite = all(isinstance(v, (int, float)) and math.isfinite(v)
                         and v >= 1.0 for v in (p50, p95))
            if not s.get("count") or not finite:
                failures.append(f"cardinality q-error for stage type "
                                f"'{stype}' is missing or non-finite: {s}")
            else:
                shown.append(f"{stype}: p50={p50:.2f} p95={p95:.2f}")
        print(f"check_regression: cardinality records={card['count']}, "
              f"q-error {{{'; '.join(shown)}}}", flush=True)

    # -- data-path observability: ledger attribution exact + fused-quiet --
    ledger = payload.get("ledger") or {}
    by_cause = ledger.get("by_cause") or {}
    if not by_cause:
        failures.append("transfer ledger missing from payload")
    else:
        unknown = sorted(set(by_cause) - set(KNOWN_CAUSES))
        if unknown:
            failures.append(f"transfer ledger reports unknown cause(s) "
                            f"{unknown}")
        if by_cause.get("handoff", 0) != 0:
            failures.append(f"fused-path ledger shows "
                            f"{by_cause['handoff']} handoff bytes (want 0)")
        inter_sum = sum(by_cause.get(c, 0) for c in INTERMEDIATE)
        if inter_sum != fused_bytes:
            failures.append(f"ledger intermediate sum {inter_sum} "
                            f"disagrees with the fused hand-off figure "
                            f"{fused_bytes}")
        print(f"check_regression: ledger by_cause={by_cause} "
              f"(intermediate sum {inter_sum})", flush=True)

    # -- adaptive re-optimization must fire and win on the skewed star ----
    adaptive = payload.get("adaptive") or {}
    if not adaptive:
        failures.append("adaptive skewed-star section missing from payload")
    else:
        replans = adaptive.get("replans") or []
        t_s, t_a = adaptive.get("static_s"), adaptive.get("adaptive_s")
        print(f"check_regression: adaptive static={t_s:.3f}s "
              f"adaptive={t_a:.3f}s replans={len(replans)} beats_static="
              f"{adaptive.get('adaptive_beats_static')}", flush=True)
        if not replans:
            failures.append("adaptive run performed no replans on the "
                            "skewed star (estimate-vs-observed trigger "
                            "went dark)")
        if not adaptive.get("adaptive_beats_static"):
            failures.append(f"adaptive execution ({t_a:.3f}s) did not "
                            f"beat static ({t_s:.3f}s) on the skewed star")

    slo = rollup.get("benchmarks", {}).get("slo_bench")
    if slo and slo.get("ok") and slo.get("payload"):
        with open(BASELINE_PATH) as f:
            slo_base = json.load(f).get("slo_bench", {})
        sp = slo["payload"]
        hit, shed = sp["deadline_hit_rate"], sp["shed_rate"]
        floor = slo_base.get("smoke_hit_rate_floor", 0.0)
        ceil = slo_base.get("smoke_shed_rate_ceiling", 1.0)
        print(f"check_regression: smoke slo hit_rate={hit:.2f} "
              f"(floor {floor:.2f}), shed_rate={shed:.2f} "
              f"(ceiling {ceil:.2f}), structured="
              f"{sp['sheds_structured']}", flush=True)
        if hit < floor:
            failures.append(f"smoke slo deadline hit rate {hit:.2f} below "
                            f"baseline floor {floor:.2f}")
        if shed > ceil:
            failures.append(f"smoke slo shed rate {shed:.2f} above "
                            f"baseline ceiling {ceil:.2f}")
        if not sp["sheds_structured"]:
            failures.append("smoke slo shed queries missing structured "
                            "Backpressure errors")
        # -- closed-loop observability gates ----------------------------
        steady = sp.get("slo_alerts_steady")
        burst = sp.get("slo_alerts_burst")
        stale = sp.get("cost_model_staleness")
        print(f"check_regression: slo alerts steady={steady} (want 0), "
              f"burst={burst} (want >=1), flight_dump_valid="
              f"{sp.get('flight_dump_valid')}, staleness={stale}",
              flush=True)
        if steady != 0:
            failures.append(f"SLO monitor fired {steady} alert(s) at "
                            f"steady state (want 0 — alerts that fire "
                            f"when nothing is wrong are noise)")
        if not burst:
            failures.append("SLO monitor stayed silent through the "
                            "bursty overload replay (want >= 1 alert)")
        if not sp.get("flight_dump_valid"):
            failures.append("flight-recorder dump missing or "
                            "schema-invalid")
        if not isinstance(stale, (int, float)) or not math.isfinite(stale):
            failures.append(f"cost_model_staleness gauge missing or "
                            f"non-finite: {stale!r}")
        # -- resilience gates --------------------------------------------
        pre = sp.get("preemption") or {}
        if not pre:
            failures.append("preemption on-vs-off section missing from "
                            "slo payload")
        else:
            g_on = pre.get("gold_hit_rate_on")
            g_off = pre.get("gold_hit_rate_off")
            n_pre = int(pre.get("preemptions") or 0)
            print(f"check_regression: preemption gold_hit on={g_on:.2f} "
                  f"off={g_off:.2f}, preemptions={n_pre}", flush=True)
            if n_pre < 1:
                failures.append("deadline preemption never fired under "
                                "the overload replay (want >= 1)")
            if not pre.get("preempt_improves"):
                failures.append(f"preemption did not improve the gold "
                                f"deadline hit rate at equal offered "
                                f"load (on={g_on:.2f} < off={g_off:.2f})")
        chaos = sp.get("chaos") or {}
        if not chaos:
            failures.append("chaos smoke section missing from slo payload")
        else:
            print(f"check_regression: chaos completed="
                  f"{chaos.get('completed')} unstructured="
                  f"{chaos.get('unstructured_failures')} row_exact="
                  f"{chaos.get('row_exact')} hung_workers="
                  f"{chaos.get('hung_workers')} failed="
                  f"{chaos.get('failed')} breakers="
                  f"{chaos.get('breakers')}", flush=True)
            if chaos.get("unstructured_failures") != 0:
                failures.append(f"chaos replay leaked "
                                f"{chaos.get('unstructured_failures')} "
                                f"unstructured failure(s) (want 0 — every"
                                f" abort must be structured Backpressure)")
            if not chaos.get("row_exact"):
                failures.append("chaos replay results were not row-exact "
                                "against the NumPy oracle")
            if chaos.get("hung_workers") != 0:
                failures.append(f"{chaos.get('hung_workers')} worker(s) "
                                f"still alive after drain-close")
            if chaos.get("failed") != 0:
                failures.append(f"chaos replay counted "
                                f"{chaos.get('failed')} hard failure(s) "
                                f"(recovery ladder must absorb injected "
                                f"faults)")
            breakers = chaos.get("breakers") or {}
            bad = {k: b for k, b in breakers.items()
                   if b.get("state") not in ("closed", "open",
                                             "half_open")}
            if bad:
                failures.append(f"breaker(s) in unknown state: {bad}")
            opened = any(b.get("state") != "closed"
                         for b in breakers.values())
            if opened and not chaos.get("breaker_events"):
                failures.append("a breaker opened without emitting any "
                                "structured breaker event")
    else:
        print("check_regression: no successful slo_bench payload — "
              "skipping SLO gate", flush=True)

    for msg in failures:
        print(f"check_regression: FAIL — {msg}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
