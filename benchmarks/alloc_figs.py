"""Figures 11/12 (allocator) and 20 (locking microbenchmark)."""
from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np

from .common import csv_row, report, time_call


def fig20_locking_microbench():
    """Appendix Fig. 20: real lock-contention microbenchmark on this host
    (K threads performing X guarded increments over arrays of size N) —
    calibrates the per-atomic cost used by the Fig. 11 lock-overhead model."""
    x_total = 200_000
    out = {"rows": []}
    for n in (1, 1024, 1_048_576):
        for k in (1, 4, 16):
            arr = np.zeros(n, np.int64)
            lock = threading.Lock()
            per = x_total // k

            def worker(seed):
                rng = np.random.default_rng(seed)
                idx = rng.integers(0, n, per)
                for i in idx:
                    with lock:
                        arr[i] += 1

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(k)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            dt = time.perf_counter() - t0
            out["rows"].append({"n": n, "threads": k, "time_s": dt,
                                "ns_per_op": dt / x_total * 1e9})
            csv_row(f"fig20/n={n}/k={k}", dt * 1e6,
                    f"{dt / x_total * 1e9:.0f}ns/op")
    out["ns_per_atomic"] = float(np.median(
        [r["ns_per_op"] for r in out["rows"]]))
    report("fig20_locking", out)
    return out


def fig11_12_allocator(ns_per_atomic: float | None = None):
    """Figs. 11/12: block-size sweep + basic-vs-optimized allocator.

    Measured part: the real scan-allocator time at each block size.
    Modelled part: lock overhead = #allocation-units x calibrated atomic
    cost (the paper itself estimates lock overhead as measured-minus-model,
    §5.4; we invert the same arithmetic with the Fig. 20 calibration).
    """
    from repro.core import alloc_stats, basic_alloc_units, scan_alloc
    if ns_per_atomic is None:
        ns_per_atomic = 120.0
    rng = np.random.default_rng(0)
    n = 1_048_576
    sizes = jnp.asarray(rng.integers(0, 8, n, dtype=np.int32))
    rows = []
    item_bytes = 8
    for block_items in (32, 64, 128, 256, 512, 1024, 2048):
        t = time_call(lambda bi=block_items: scan_alloc(
            sizes, tile=256, block_items=bi)[0])
        st = alloc_stats(sizes, tile=256, block_items=block_items)
        lock_s = st.global_units * ns_per_atomic * 1e-9
        rows.append({"block_bytes": block_items * item_bytes,
                     "scan_s": t, "lock_model_s": lock_s,
                     "fragmentation": st.fragmentation,
                     "total_s": t + lock_s})
        csv_row(f"fig11/block={block_items * item_bytes}B", t * 1e6,
                f"lock={lock_s*1e6:.0f}us;frag={st.fragmentation:.2f}")
    basic_units = basic_alloc_units(sizes)
    basic_lock_s = basic_units * ns_per_atomic * 1e-9
    best = min(rows, key=lambda r: r["total_s"])
    out = {"rows": rows, "basic_units": int(basic_units),
           "basic_lock_model_s": basic_lock_s,
           "best_block_bytes": best["block_bytes"],
           "ours_vs_basic_speedup_pct":
               100 * (1 - best["total_s"]
                      / (rows[0]["scan_s"] + basic_lock_s))}
    csv_row("fig12/basic", basic_lock_s * 1e6, f"units={basic_units}")
    csv_row("fig12/ours", best["total_s"] * 1e6,
            f"block={best['block_bytes']}B;"
            f"speedup={out['ours_vs_basic_speedup_pct']:.0f}%")
    report("fig11_12_allocator", out)
    return out


def workload_divergence():
    """§5.4 grouping: measured tile-divergence waste before/after."""
    from repro.core import (divergence_order, tile_divergence_waste)
    rng = np.random.default_rng(1)
    w = jnp.asarray(np.minimum(rng.zipf(1.3, 1_048_576), 4096)
                    .astype(np.int32))
    rows = {}
    before = float(tile_divergence_waste(w, tile=256))
    for groups in (1, 8, 64, 512):
        order = divergence_order(w, num_groups=groups)
        after = float(tile_divergence_waste(w[order], tile=256))
        rows[groups] = after
        csv_row(f"divergence/groups={groups}", after * 1e6,
                f"waste={after:.3f} (before={before:.3f})")
    out = {"waste_before": before, "waste_after": rows,
           "improvement_pct": 100 * (before - min(rows.values()))
           / max(before, 1e-9)}
    report("divergence_grouping", out)
    return out
