"""Paper figures 3–10, 15, 16 and Table 3 — one function per artifact.

Each returns a dict (saved to reports/bench/) and prints CSV rows
``name,us_per_call,derived``.
"""
from __future__ import annotations

import numpy as np

from .common import N_TUPLES, csv_row, default_relations, report, time_call


def _coprocessors():
    from repro.core import CoProcessor, PCIE_LINK
    return (CoProcessor(),                                   # coupled
            CoProcessor(link=PCIE_LINK, discrete=True))      # discrete(em.)


def _model_for(series, n_items, *, device_pair="apu", link="zerocopy",
               discrete=False, u_overrides=None):
    from repro.core.calibrate import (APU_CPU, APU_GPU, TPU_C_GROUP,
                                      TPU_G_GROUP)
    from repro.core.cost_model import (DCN_LINK, ICI_LINK, PCIE_LINK,
                                       ZEROCOPY_LINK, series_model_from_costs)
    dev = {"apu": (APU_CPU, APU_GPU),
           "tpu": (TPU_C_GROUP, TPU_G_GROUP)}[device_pair]
    lk = {"zerocopy": ZEROCOPY_LINK, "pcie": PCIE_LINK, "ici": ICI_LINK,
          "dcn": DCN_LINK}[link]
    return series_model_from_costs(series.steps, [n_items] * len(series.steps),
                                   *dev, lk, discrete=discrete,
                                   u_overrides=u_overrides)


# ---------------------------------------------------------------------------

def fig3_time_breakdown():
    """Fig. 3: time breakdown of DD/OL on discrete vs coupled."""
    b, s = default_relations(N_TUPLES // 4)
    nb = max(1024, N_TUPLES // 16)
    out = {}
    for label, cp in zip(("coupled", "discrete"), _coprocessors()):
        res, t = cp.shj(b, s, num_buckets=nb, max_out=2 * b.size,
                        build_ratios=[0.25] * 4, probe_ratios=[0.42] * 4,
                        table_mode="separate" if label == "discrete"
                        else "shared")
        out[f"shj_dd_{label}"] = {
            "build_s": t.phase_s["build"], "probe_s": t.phase_s["probe"],
            "merge_s": t.merge_s, "transfer_s": t.transfer_s,
            "transfer_bytes": t.transfer_bytes, "wall_s": t.wall_s}
        csv_row(f"fig3/shj_dd_{label}", t.wall_s * 1e6,
                f"merge={t.merge_s:.3f}s;xfer={t.transfer_s:.3f}s")
    d, c = out["shj_dd_discrete"], out["shj_dd_coupled"]
    out["merge_pct_discrete"] = 100 * d["merge_s"] / d["wall_s"]
    out["transfer_pct_discrete"] = 100 * d["transfer_s"] / d["wall_s"]
    report("fig3_breakdown", out)
    return out


def fig4_step_unit_costs():
    """Fig. 4: per-step unit costs on each group (measured + APU model)."""
    from repro.core import CoProcessor
    from repro.core.calibrate import (APU_CPU, APU_GPU, measure_unit_costs)
    from repro.core.phj import PARTITION_COSTS, partition_series
    from repro.core.shj import BUILD_SERIES, COSTS, PROBE_SERIES
    cp = CoProcessor()
    n = min(N_TUPLES // 4, 262144)
    b, s = default_relations(n)
    nb = 4096
    shared = {"num_buckets": nb, "shift": 0, "bits": 6, "max_out": 4 * n}
    out = {"measured": {}, "apu_model": {}}
    for series, rel in ((BUILD_SERIES, b), (partition_series(0), b)):
        items = {"rid": rel.rid, "key": rel.key}
        for grp in (cp.c, cp.g):
            got = measure_unit_costs(series, shared, items, grp, reps=3)
            for k, v in got.items():
                out["measured"].setdefault(k, {})[grp.name] = v * 1e9
    # probe series needs a built table in shared state
    from repro.core import build_hash_table
    table = build_hash_table(b, nb)
    items = {"rid": s.rid, "key": s.key}
    for grp in (cp.c, cp.g):
        got = measure_unit_costs(PROBE_SERIES, {**shared, "table": table},
                                 items, grp, reps=3)
        for k, v in got.items():
            out["measured"].setdefault(k, {})[grp.name] = v * 1e9
    for name, cost in {**COSTS, **PARTITION_COSTS}.items():
        out["apu_model"][name] = {
            "C": APU_CPU.unit_cost(cost) * 1e9,
            "G": APU_GPU.unit_cost(cost) * 1e9,
            "speedup_G": APU_CPU.unit_cost(cost) / APU_GPU.unit_cost(cost)}
    for k, v in out["apu_model"].items():
        csv_row(f"fig4/{k}", v["C"] / 1000, f"gpu_speedup={v['speedup_G']:.1f}x")
    hash_steps = [out["apu_model"][k]["speedup_G"] for k in ("n1", "b1", "p1")]
    walk_steps = [out["apu_model"][k]["speedup_G"] for k in ("b3", "p3")]
    out["claim_hash_speedup_gt15x"] = bool(min(hash_steps) > 15)
    out["claim_walk_speedup_near1x"] = bool(max(walk_steps) < 3)
    report("fig4_step_costs", out)
    return out


def fig5_6_pl_ratios():
    """Figs. 5/6: optimal per-step PL workload ratios (APU cost model)."""
    from repro.core.phj import partition_series
    from repro.core.shj import BUILD_SERIES, PROBE_SERIES
    out = {}
    for name, series in (("shj_build", BUILD_SERIES),
                         ("shj_probe", PROBE_SERIES),
                         ("phj_partition", partition_series(0))):
        m = _model_for(series, 16e6)
        r, t = m.optimize_pl(delta=0.02)
        out[name] = {"ratios": list(r), "est_s": t,
                     "steps": m.step_names}
        csv_row(f"fig5_6/{name}", t * 1e6,
                "r=" + "/".join(f"{x:.2f}" for x in r))
    spread = max(max(v["ratios"]) - min(v["ratios"]) for v in out.values())
    out["claim_ratios_vary_across_steps"] = bool(spread >= 0.3)
    report("fig5_6_pl_ratios", out)
    return out


def fig7_dd_estimate_vs_measured():
    """Fig. 7: estimated vs measured SHJ-DD time, ratio swept."""
    from repro.core import CoProcessor
    from repro.core.calibrate import calibrated_overrides
    from repro.core.shj import BUILD_SERIES, PROBE_SERIES
    from repro.core import build_hash_table
    cp = CoProcessor()
    n = min(N_TUPLES // 4, 262144)
    b, s = default_relations(n)
    nb = 4096
    table = build_hash_table(b, nb)
    u = calibrated_overrides(PROBE_SERIES, {"table": table,
                                            "max_out": 4 * n},
                             {"rid": s.rid, "key": s.key}, cp.c, cp.g,
                             reps=3)
    m = _model_for(PROBE_SERIES, n, u_overrides=u)
    rows = []
    for r in np.linspace(0, 1, 9):
        est = float(m.estimate_batch(np.full((1, 4), r))[0])
        _, t = cp.shj(b, s, num_buckets=nb, max_out=4 * n,
                      build_ratios=[r] * 4, probe_ratios=[r] * 4,
                      table_mode="shared")
        rows.append({"ratio": float(r), "est_s": est,
                     "measured_probe_s": t.phase_s["probe"]})
        csv_row(f"fig7/r={r:.2f}", t.phase_s["probe"] * 1e6,
                f"est={est*1e6:.0f}us")
    est = np.array([x["est_s"] for x in rows])
    meas = np.array([x["measured_probe_s"] for x in rows])
    out = {"rows": rows,
           "opt_ratio_est": float(np.linspace(0, 1, 9)[est.argmin()]),
           "opt_ratio_measured": float(np.linspace(0, 1, 9)[meas.argmin()])}
    report("fig7_dd_sweep", out)
    return out


def fig8_pl_special_case():
    """Fig. 8: offload b1/p1 to G entirely, sweep one ratio elsewhere."""
    from repro.core.shj import PROBE_SERIES
    m = _model_for(PROBE_SERIES, 16e6)
    rows = []
    for r in np.linspace(0, 1, 21):
        est = float(m.estimate_batch(np.array([[0.0, r, r, r]]))[0])
        rows.append({"r": float(r), "est_s": est})
    best = min(rows, key=lambda x: x["est_s"])
    csv_row("fig8/best", best["est_s"] * 1e6, f"r={best['r']:.2f}")
    report("fig8_pl_special", {"rows": rows, "best": best})
    return {"rows": rows, "best": best}


def fig9_monte_carlo():
    """Fig. 9: CDF of Monte-Carlo ratio assignments vs the model's pick."""
    from repro.core.shj import BUILD_SERIES
    from repro.core.phj import partition_series
    out = {}
    for name, series in (("shj_pl_build", BUILD_SERIES),
                         ("phj_pl_partition", partition_series(0))):
        m = _model_for(series, 16e6)
        _, t_model = m.optimize_pl(delta=0.02)
        _, times = m.monte_carlo(1000, seed=7)
        q = np.quantile(times, [0.0, 0.25, 0.5, 0.75, 1.0])
        out[name] = {"model_pick_s": t_model,
                     "mc_quantiles_s": list(q),
                     "model_beats_pct": float((times >= t_model).mean())}
        csv_row(f"fig9/{name}", t_model * 1e6,
                f"beats={out[name]['model_beats_pct']*100:.1f}%ofMC")
    report("fig9_monte_carlo", out)
    return out


def fig10_shared_vs_separate():
    """Fig. 10: build phase with shared vs separate hash tables."""
    from repro.core import CoProcessor
    cp = CoProcessor()
    b, s = default_relations(N_TUPLES // 2)
    nb = max(1024, N_TUPLES // 8)
    out = {}
    for mode in ("shared", "separate"):
        _, t = cp.shj(b, s, num_buckets=nb, max_out=2 * b.size,
                      build_ratios=[0.25] * 4, probe_ratios=[0.42] * 4,
                      table_mode=mode)
        out[mode] = {"build_s": t.phase_s["build"], "merge_s": t.merge_s}
        csv_row(f"fig10/{mode}", t.phase_s["build"] * 1e6,
                f"merge={t.merge_s:.3f}s")
    out["shared_speedup_pct"] = 100 * (1 - out["shared"]["build_s"]
                                       / out["separate"]["build_s"])
    report("fig10_shared_separate", out)
    return out


def fig15_selectivity():
    """Fig. 15: join selectivity 12.5% / 50% / 100%."""
    from repro.core import (CoProcessor, probe_with_selectivity,
                            unique_relation)
    cp = CoProcessor()
    n = N_TUPLES // 4
    b = unique_relation(n, seed=1)
    nb = max(1024, n // 4)
    out = {}
    for sel in (0.125, 0.5, 1.0):
        s = probe_with_selectivity(b, n, selectivity=sel, seed=2)
        _, t = cp.shj(b, s, num_buckets=nb, max_out=2 * n,
                      build_ratios=[0.25] * 4, probe_ratios=[0.42] * 4,
                      table_mode="shared")
        out[f"sel_{sel}"] = {"build_s": t.phase_s["build"],
                             "probe_s": t.phase_s["probe"]}
        csv_row(f"fig15/sel={sel}", t.wall_s * 1e6,
                f"probe={t.phase_s['probe']:.3f}s")
    report("fig15_selectivity", out)
    return out


def fig16_basic_unit():
    """Fig. 16 (appendix): BasicUnit chunk scheduling vs fine-grained."""
    from repro.core import CoProcessor
    cp = CoProcessor()
    b, s = default_relations(N_TUPLES // 4)
    nb = max(1024, N_TUPLES // 16)
    _, t_bu, ratios = cp.basic_unit_shj(b, s, num_buckets=nb,
                                        max_out=2 * b.size, chunk=65536)
    _, t_pl = cp.shj(b, s, num_buckets=nb, max_out=2 * b.size,
                     build_ratios=[0.0, 0.25, 0.5, 0.25],
                     probe_ratios=[0.0, 0.25, 0.75, 0.25],
                     table_mode="shared")
    out = {"basic_unit_s": t_bu.wall_s, "pl_s": t_pl.wall_s,
           "basic_unit_ratios": ratios,
           "pl_speedup_pct": 100 * (1 - t_pl.wall_s / t_bu.wall_s)}
    csv_row("fig16/basic_unit", t_bu.wall_s * 1e6,
            f"ratios={ratios}")
    csv_row("fig16/pl", t_pl.wall_s * 1e6,
            f"speedup={out['pl_speedup_pct']:.0f}%")
    report("fig16_basic_unit", out)
    return out


def partition_fused_bench():
    """Fused pipeline + planner vs the seed's unfused 3-step partition path.

    Times the partition phase only (the paper's dominant cost): the seed's
    materialized (n1, n2, n3) x 2 at its hard-coded knobs against the fused
    data path at the planner-chosen schedule for the same total radix.
    """
    from repro.core import (default_planner, radix_partition_scheduled,
                            radix_partition_unfused)
    n = min(N_TUPLES, 1 << 20)
    b, _ = default_relations(n)
    seed_bits, seed_passes = 3, 2          # the seed's hard-coded knobs
    total_bits = seed_bits * seed_passes
    plan = default_planner().plan(n, total_bits=total_bits)
    t_unfused = time_call(
        lambda: radix_partition_unfused(b, bits_per_pass=seed_bits,
                                        num_passes=seed_passes))
    t_fused = time_call(
        lambda: radix_partition_scheduled(b, schedule=plan.schedule))
    out = {"n": n, "total_bits": total_bits,
           "seed_schedule": [seed_bits] * seed_passes,
           "planned_schedule": list(plan.schedule),
           "unfused_s": t_unfused, "fused_s": t_fused,
           "speedup_pct": 100 * (1 - t_fused / t_unfused),
           "fused_no_slower": bool(t_fused <= t_unfused * 1.05)}
    csv_row("partition/unfused", t_unfused * 1e6,
            f"schedule={seed_bits}x{seed_passes}")
    csv_row("partition/fused", t_fused * 1e6,
            f"schedule={plan.schedule};speedup={out['speedup_pct']:.0f}%")
    report("partition_fused", out)
    return out


def table3_step_granularity():
    """Table 3: fine-grained PL vs coarse-grained PL' (per-pair step)."""
    from repro.core import default_planner, phj_join
    from repro.core.partition import radix_partition_scheduled
    from repro.core.phj import phj_coarse_join
    n = min(N_TUPLES // 4, 262144)
    b, s = default_relations(n)
    sched = default_planner().plan(n, total_bits=6).schedule
    t_fine = time_call(
        lambda: phj_join(b, s, schedule=sched,
                         buckets_per_part=64, max_out=2 * n))
    pr = radix_partition_scheduled(b, schedule=sched)
    ps = radix_partition_scheduled(s, schedule=sched)
    cap = int(max(np.asarray(pr.part_count).max(),
                  np.asarray(ps.part_count).max()))
    cap = ((cap + 127) // 128) * 128
    num_parts = 1 << sum(sched)
    t_coarse = time_call(
        lambda: phj_coarse_join(pr, ps, num_parts=num_parts, part_cap=cap,
                                buckets_per_part=64,
                                max_out_per_part=2 * cap))
    # Cache proxy: coarse-grained private tables overfetch by cap padding.
    fine_ws = 2 * n * 8
    coarse_ws = num_parts * cap * 8 * 2
    out = {"fine_s": t_fine, "coarse_s": t_coarse,
           "fine_working_set_mb": fine_ws / 2**20,
           "coarse_working_set_mb": coarse_ws / 2**20,
           "fine_faster": bool(t_fine < t_coarse)}
    csv_row("table3/phj_pl_fine", t_fine * 1e6, "")
    csv_row("table3/phj_pl_coarse", t_coarse * 1e6,
            f"fine_faster={out['fine_faster']}")
    report("table3_granularity", out)
    return out
