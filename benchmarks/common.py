"""Shared benchmark setup.

Benchmarks measure the REAL join algorithms on this host (all variants run
to completion and are verified against the oracle), with the two processor
groups mapped onto 8 XLA host devices (2 C + 6 G).  Because this container
has one physical core, wall-clock gains from group overlap are not
observable here — the measured numbers validate mechanism + overheads
(transfers, merges, scheduling), while the APU-calibrated cost model
carries the paper's headline-ratio validation and the TPU-pod projection
carries the deployment story.  EXPERIMENTS.md spells out which number is
which.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "bench")

# Paper default is 16M tuples; 1M keeps the full suite tractable on one
# core (scale with REPRO_BENCH_SCALE=16 for paper-scale runs).
SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "1"))
N_TUPLES = 1_000_000 * SCALE

# Reproducibility: REPRO_SEED offsets every benchmark's generator seeds
# (workloads, query generators, relations), so a rollup is reproducible
# run-to-run at REPRO_SEED=0 (the default) and re-rollable on fresh data
# with any other value.  The value is recorded in the BENCH_*.json rollup.
REPRO_SEED = int(os.environ.get("REPRO_SEED", "0"))


def bench_seed(offset: int = 0) -> int:
    """A deterministic per-site seed: the site's fixed offset + REPRO_SEED."""
    return REPRO_SEED + int(offset)


def report(name: str, payload: dict):
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_run_summary(results: dict) -> str:
    """Per-run rollup artifact: reports/bench/BENCH_<utc-stamp>.json.

    One file per harness invocation (timestamped, never overwritten) so
    the perf trajectory across commits is machine-readable.
    """
    import datetime
    import sys as _sys
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y%m%dT%H%M%SZ")
    try:  # record the topology the numbers were actually measured on
        import jax
        device_count = jax.device_count()
    except Exception:
        device_count = None
    payload = {
        "timestamp_utc": stamp,
        "argv": _sys.argv[1:],
        "scale": SCALE,
        "n_tuples": N_TUPLES,
        "repro_seed": REPRO_SEED,
        "device_count": device_count,
        "c_devices_env": os.environ.get("REPRO_C_DEVICES", ""),
        "benchmarks": results,
    }
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"BENCH_{stamp}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def write_trace(tracer, name: str = "trace") -> str | None:
    """Chrome-trace artifact: reports/bench/TRACE_<name>_<utc-stamp>.json.

    Emitted next to the ``BENCH_*.json`` rollups (CI uploads both).  The
    ``TRACE_`` prefix keeps it out of ``check_regression.py``'s newest-
    ``BENCH_*`` glob.  Returns the path, or None when the tracer recorded
    nothing (e.g. disabled).
    """
    import datetime
    if tracer is None or not getattr(tracer, "enabled", False):
        return None
    if not tracer.spans():
        return None
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y%m%dT%H%M%SZ")
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"TRACE_{name}_{stamp}.json")
    return tracer.write_chrome_trace(path)


def time_call(fn, *args, reps: int = 3, warmup: int = 1, **kw) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def default_relations(n: int | None = None, *, skew: str = "uniform",
                      seed: int = 0):
    from repro.core import skewed_relation, uniform_relation
    n = n or N_TUPLES
    if skew == "uniform":
        r = uniform_relation(n, seed=seed)
        s = uniform_relation(n, key_range=n, seed=seed + 1)
    else:
        pct = {"low": 10, "high": 25}[skew]
        r = skewed_relation(n, s_percent=pct, seed=seed)
        s = skewed_relation(n, s_percent=pct, seed=seed + 1)
    return r, s
