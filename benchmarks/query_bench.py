"""Query-pipeline benchmark: join ordering, data-path fusion, and reuse.

Five measured figures for the multi-join subsystem on a 3-join star
query (fact ⋈ D0 ⋈ D1 ⋈ D2, one highly selective dimension filter):

  1. **join order** — the cost-model-chosen order vs the worst enumerated
     order vs the textual left-deep baseline, all verified against the
     NumPy reference; the chosen order must beat the worst (the optimizer's
     reason to exist).
  2. **stage hand-off** — the same chosen plan under the fused
     device-resident hand-off (``StageView`` rid-chains, the default) vs
     the host-materialize baseline; the fused path must win end-to-end and
     report ``host_bytes_moved == 0`` for its intermediates.
  3. **single device** — the chosen order re-run with planning pinned to
     GPU_ONLY: what pipelined co-processing over both groups adds.
  4. **adaptive replan** — an estimator-hostile skewed star, static vs
     adaptive execution: the adaptive executor re-orders the remaining
     stages from observed cardinalities mid-pipeline and must win.
  5. **star replay** — a ``WorkloadGenerator.star()`` stream through one
     shared executor: multi-join traffic with recurring dimensions,
     reporting pipelines/sec and both build-side cache hit kinds.

Smoke mode (CI) shrinks sizes so the whole thing runs in tens of seconds;
it additionally hard-asserts the fused path's zero-intermediate-bytes
invariant (the regression gate in ``check_regression.py`` then bounds the
end-to-end time against the committed baseline).  ``REPRO_SEED`` offsets
every generator seed for reproducible-yet-refreshable rollups.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (N_TUPLES, bench_seed, csv_row, report, time_call,
                     write_trace)


def _run_verified(executor, query, physical, ref):
    res = executor.run(query, physical)
    got = res.rows_array()
    assert got.shape == ref[0].shape and (got == ref[0]).all(), \
        "pipeline rows diverge from the NumPy reference"
    assert res.aggregate == ref[1], (res.aggregate, ref[1])
    return res


def _skewed_star(fact: int, seed: int = 0):
    """Estimator-hostile 3-join star (scaled twin of the unit-test one).

    ``fact.fk0`` is half junk: the System-R estimate for the first join
    lands ~16x under the true cardinality, and the d2 edge — a shrink at
    the true intermediate size, a growth at the estimated one — flips
    which tail order is cheapest.  Static planning runs d2 last; the
    adaptive executor observes stage 0's exact count and runs it first.
    """
    from repro.queries import Join, Query, Table

    scale = max(1, fact // 8192)
    rng = np.random.default_rng(seed)
    d0_n, d1_n = 128 * scale, 144 * scale
    d2_distinct, d2_rep, fk2_range = 40 * scale, 10, 4000 * scale
    fk0 = np.where(rng.random(fact) < 0.5,
                   rng.integers(0, d0_n, fact),
                   rng.integers(10 * fact, 20 * fact, fact)).astype(np.int32)
    tables = {
        "fact": Table("fact", {
            "fk0": fk0,
            "fk1": rng.integers(0, d1_n, fact).astype(np.int32),
            "fk2": rng.integers(0, fk2_range, fact).astype(np.int32),
            "v": rng.integers(0, 100, fact).astype(np.int32)}),
        "d0": Table("d0", {"id": np.arange(d0_n, dtype=np.int32),
                           "a": rng.integers(0, 10, d0_n).astype(np.int32)}),
        "d1": Table("d1", {"id": np.arange(d1_n, dtype=np.int32),
                           "b": rng.integers(0, 10, d1_n).astype(np.int32)}),
        "d2": Table("d2", {
            "id": np.repeat(np.arange(d2_distinct, dtype=np.int32), d2_rep),
            "c": rng.integers(0, 10,
                              d2_distinct * d2_rep).astype(np.int32)})}
    return Query(tables=tables,
                 joins=(Join("fact", "fk0", "d0", "id"),
                        Join("fact", "fk1", "d1", "id"),
                        Join("fact", "fk2", "d2", "id")),
                 aggregate=("count",))


def query_pipeline(smoke: bool = False):
    from repro.core import CoProcessor
    from repro.engine import JoinQueryService, QueryPlanner, WorkloadGenerator
    from repro.queries import (JoinOrderOptimizer, PipelineExecutor,
                               make_star_query, reference_execute)

    # Sizes where data volume dominates per-stage dispatch overhead —
    # at a few thousand tuples every order costs the same ~5 ms of fixed
    # overhead per stage and the comparison measures noise.
    if smoke:
        fact, dim, delta, cal_n, reps, n_stars = 65536, 4096, 0.25, 8192, 3, 4
    else:
        fact = min(max(N_TUPLES // 4, 1 << 18), 1 << 20)
        dim, delta, cal_n, reps, n_stars = fact // 8, 0.1, 32768, 5, 6

    cp = CoProcessor()
    out: dict = {"smoke": smoke, "fact_rows": fact, "dim_rows": dim}
    planner = QueryPlanner.calibrated(cp, n=cal_n, reps=1, delta=delta)
    optimizer = JoinOrderOptimizer(planner)

    # -- 1. chosen vs worst vs textual join order -------------------------
    # One selective dimension: the chosen order shrinks the pipeline's
    # intermediates immediately, the worst order drags full-size ones.
    query = make_star_query(fact, [dim] * 3, selectivities=[0.02, None, 0.5],
                            seed=bench_seed(17), aggregate=("count",))
    ref = reference_execute(query)
    chosen = optimizer.optimize(query)
    worst = optimizer.worst_order(query)
    textual = optimizer.price_order(query, query.joins)
    out["plans"] = {"chosen": chosen.to_dict(), "worst": worst.to_dict(),
                    "textual": textual.to_dict()}

    def timed(physical, use_planner=None, handoff="device"):
        pl = use_planner or planner
        svc = JoinQueryService(cp=cp, planner=pl, num_workers=2)
        with PipelineExecutor(service=svc, optimizer=optimizer,
                              handoff=handoff) as ex:
            # Warm passes: compile every stage variant and let the online
            # scales settle, then freeze adaptation so the timed passes
            # measure the converged plans (engine_bench's protocol).
            _run_verified(ex, query, physical, ref)
            last = {}
            for _ in range(2):
                last["res"] = ex.run(query, physical)
            saved, pl.online.alpha = pl.online.alpha, 0.0
            try:
                t = time_call(lambda: last.update(
                    res=ex.run(query, physical)), reps=reps, warmup=1)
            finally:
                pl.online.alpha = saved
            stats = svc.stats()
        return t, stats, last["res"], svc.tracer

    t_chosen, st_chosen, res_chosen, tr_chosen = timed(chosen)
    t_worst, _, _, _ = timed(worst)
    t_textual, _, _, _ = timed(textual)
    out["join_order"] = {
        "chosen_s": t_chosen, "worst_s": t_worst, "textual_s": t_textual,
        "chosen_est_s": chosen.est_total_s, "worst_est_s": worst.est_total_s,
        "speedup_vs_worst": t_worst / t_chosen,
        "optimized_beats_worst": bool(t_chosen < t_worst),
        "chosen_cache": st_chosen["cache"]}
    csv_row("query/order_chosen", t_chosen * 1e6,
            f"est={chosen.est_total_s*1e3:.2f}ms")
    csv_row("query/order_worst", t_worst * 1e6,
            f"slowdown={t_worst/t_chosen:.2f}x")
    csv_row("query/order_textual", t_textual * 1e6, "")

    # -- observability artifacts ------------------------------------------
    # The chosen run's lifecycle trace (admit → queue → plan →
    # build/partition → probe/join → gather per stage) lands next to the
    # rollup as a Perfetto-loadable TRACE_*.json, and the registry
    # snapshot (including the predicted-vs-measured ``prediction_error``
    # summary) rides in the payload for the regression gate.
    out["metrics_snapshot"] = st_chosen["metrics"]
    # Data-path observability payload for the regression gate: the host-
    # transfer ledger (every byte attributed to a cause) and the
    # cardinality audit's q-error summary from the chosen fused run.
    out["ledger"] = st_chosen["host_transfer_ledger"]
    out["cardinality"] = st_chosen["cardinality_error"]
    out["trace_path"] = write_trace(tr_chosen, "query_pipeline")
    span_names = {s.name for s in tr_chosen.spans()}
    assert {"admit", "queue", "plan", "query", "pipeline", "finalize",
            "gather"} <= span_names, sorted(span_names)
    assert ({"build", "probe"} <= span_names
            or {"partition", "join"} <= span_names), sorted(span_names)

    # -- 2. fused device-resident hand-off vs host materialization --------
    # The SAME chosen physical plan, executed under both data paths.  The
    # fused path's intermediates never cross the host: its service-level
    # host_bytes_moved counter must read 0 (hard invariant, asserted in
    # smoke and at scale); the host path reports the actual gather +
    # re-upload volume its stages moved.
    t_host, st_host, res_host, _ = timed(chosen, handoff="host")
    fused_bytes = st_chosen["host_bytes_moved"]
    host_bytes = st_host["host_bytes_moved"]
    assert fused_bytes == 0, \
        f"fused hand-off moved {fused_bytes} intermediate bytes (want 0)"
    assert host_bytes > 0, "host path reported no intermediate traffic"
    assert (res_host.rows_array() == ref[0]).all()
    out["handoff"] = {
        "fused_s": t_chosen, "host_s": t_host,
        "fused_speedup": t_host / t_chosen,
        "fused_beats_host": bool(t_chosen < t_host),
        "host_bytes_moved_fused": fused_bytes,
        "host_bytes_moved_host": host_bytes,
        "host_bytes_per_pipeline": res_host.host_bytes_moved}
    csv_row("query/handoff_fused", t_chosen * 1e6,
            f"host_bytes={fused_bytes}")
    csv_row("query/handoff_host", t_host * 1e6,
            f"fused_speedup={t_host/t_chosen:.2f}x;"
            f"host_bytes={host_bytes}")
    if not smoke:
        assert t_chosen < t_host, \
            (f"fused hand-off ({t_chosen:.3f}s) did not beat host "
             f"materialization ({t_host:.3f}s)")

    # -- 3. pipelined co-processing vs a single device --------------------
    single_planner = QueryPlanner.calibrated(
        cp, n=cal_n, reps=1, delta=delta,
        allowed_schemes=("GPU_ONLY",), allow_phj=False)
    single_opt = JoinOrderOptimizer(single_planner)
    t_single, _, _, _ = timed(single_opt.optimize(query),
                              use_planner=single_planner)
    out["single_device"] = {"gpu_only_s": t_single,
                            "coproc_vs_single": t_single / t_chosen}
    csv_row("query/single_device", t_single * 1e6,
            f"coproc_speedup={t_single/t_chosen:.2f}x")

    # -- 4. adaptive mid-pipeline re-optimization -------------------------
    # The estimator-hostile skewed star, static vs adaptive: the adaptive
    # executor observes the first stage's exact cardinality, re-prices the
    # tail, and flips the remaining order — same rows, less work.
    skew_q = _skewed_star(fact, seed=bench_seed(41))
    skew_ref = reference_execute(skew_q)

    def timed_skew(adaptive: bool):
        svc = JoinQueryService(cp=cp, planner=planner, num_workers=2)
        with PipelineExecutor(service=svc, optimizer=optimizer,
                              adaptive=adaptive) as ex:
            res = _run_verified(ex, skew_q, None, skew_ref)
            for _ in range(2):
                res = ex.run(skew_q)
            saved, planner.online.alpha = planner.online.alpha, 0.0
            try:
                last = {"res": res}
                t = time_call(lambda: last.update(res=ex.run(skew_q)),
                              reps=reps, warmup=1)
            finally:
                planner.online.alpha = saved
            stats = svc.stats()
        return t, last["res"], stats

    t_skew_static, res_skew_static, _ = timed_skew(False)
    t_skew_adapt, res_skew_adapt, st_skew = timed_skew(True)
    assert res_skew_adapt.replans, \
        "skewed star did not trigger an adaptive replan"
    assert st_skew["host_bytes_moved"] == 0   # replans stay fused-quiet
    out["adaptive"] = {
        "static_s": t_skew_static, "adaptive_s": t_skew_adapt,
        "adaptive_speedup": t_skew_static / t_skew_adapt,
        "adaptive_beats_static": bool(t_skew_adapt < t_skew_static),
        "replans": res_skew_adapt.replans,
        "static_order": [str(s.join)
                         for s in res_skew_static.physical.stages],
        "adaptive_order": [str(s.join)
                           for s in res_skew_adapt.physical.stages],
        "cardinality": st_skew["cardinality_error"]}
    csv_row("query/adaptive_static", t_skew_static * 1e6, "")
    csv_row("query/adaptive_replan", t_skew_adapt * 1e6,
            f"speedup={t_skew_static/t_skew_adapt:.2f}x;"
            f"replans={len(res_skew_adapt.replans)}")

    # -- 5. star replay: multi-join traffic with recurring dimensions -----
    gen = WorkloadGenerator(max(1024, fact // 4), seed=bench_seed(29))
    stars = [gen.star() for _ in range(n_stars)]
    refs = [reference_execute(s) for s in stars]
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        for s, r in zip(stars, refs):                 # warm + verify
            _run_verified(ex, s, optimizer.optimize(s), r)
        t0 = time.perf_counter()
        outcomes = [ex.run(s) for s in stars]
        elapsed = time.perf_counter() - t0
        stats = svc.stats()
    assert stats["host_bytes_moved"] == 0       # fused replay stays fused
    pps = len(stars) / elapsed
    out["star_replay"] = {
        "pipelines_per_s": pps, "elapsed_s": elapsed,
        "stage_wall_s_mean": float(np.mean(
            [o.wall_s for r in outcomes for o in r.outcomes])),
        "host_bytes_moved": stats["host_bytes_moved"],
        "cache": stats["cache"],
        "pipelines": [r.to_dict() for r in outcomes]}
    csv_row("query/star_replay", 1e6 / pps,
            f"pipelines_per_s={pps:.2f};"
            f"hit_rate={stats['cache']['hit_rate']:.2f};"
            f"partition_hits={stats['cache']['partition_hits']}")
    report("query_pipeline", out)
    return out
