import os
# Benchmarks need real two-group co-processing.  The device-group layout is
# env-configurable: REPRO_NUM_DEVICES host devices total (default 8), of
# which REPRO_C_DEVICES form the C-group (default 2; consumed by
# CoProcessor).  (Deliberately NOT 512 — that flag belongs only to
# launch/dryrun.py.)
NUM_DEVICES = int(os.environ.get("REPRO_NUM_DEVICES", "8"))
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    # Append rather than setdefault: a user's unrelated XLA_FLAGS must not
    # silently swallow the requested device-group layout.  An explicit
    # count in XLA_FLAGS wins over REPRO_NUM_DEVICES.
    os.environ["XLA_FLAGS"] = (_flags + " " if _flags else "") + \
        f"--xla_force_host_platform_device_count={NUM_DEVICES}"
"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; per-figure artifacts land in
reports/bench/<name>.json and every invocation writes a machine-readable
rollup reports/bench/BENCH_<utc-stamp>.json (the perf trajectory).

  python -m benchmarks.run            # full suite
  python -m benchmarks.run --only fig4,roofline
  python -m benchmarks.run --only engine_throughput --smoke
  REPRO_BENCH_SCALE=16 ...            # paper-scale 16M-tuple relations
  REPRO_NUM_DEVICES=4 REPRO_C_DEVICES=1 ...  # device-group layout
"""
import argparse
import sys
import time
import traceback


def registry(smoke: bool = False):
    from functools import partial

    from . import (alloc_figs, engine_bench, groupby_bench, paper_figs,
                   query_bench, roofline, scale_figs, slo_bench)
    return {
        "fig3": paper_figs.fig3_time_breakdown,
        "fig4": paper_figs.fig4_step_unit_costs,
        "fig5_6": paper_figs.fig5_6_pl_ratios,
        "fig7": paper_figs.fig7_dd_estimate_vs_measured,
        "fig8": paper_figs.fig8_pl_special_case,
        "fig9": paper_figs.fig9_monte_carlo,
        "fig10": paper_figs.fig10_shared_vs_separate,
        "fig11_12": alloc_figs.fig11_12_allocator,
        "divergence": alloc_figs.workload_divergence,
        "partition_fused": paper_figs.partition_fused_bench,
        "table3": paper_figs.table3_step_granularity,
        "fig13_14_uniform": lambda: scale_figs.fig13_14_end_to_end("uniform"),
        "fig13_14_high_skew": lambda: scale_figs.fig13_14_end_to_end("high"),
        "fig15": paper_figs.fig15_selectivity,
        "fig16": paper_figs.fig16_basic_unit,
        "fig19": scale_figs.fig19_large_data,
        "fig20": alloc_figs.fig20_locking_microbench,
        "tpu_projection": scale_figs.tpu_pod_projection,
        "roofline": roofline.run,
        "engine_throughput": partial(engine_bench.engine_throughput,
                                     smoke=smoke),
        "query_pipeline": partial(query_bench.query_pipeline, smoke=smoke),
        "groupby": partial(groupby_bench.groupby_bench, smoke=smoke),
        "slo_bench": partial(slo_bench.slo_bench, smoke=smoke),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes/counts for CI (engine_throughput)")
    args = ap.parse_args()
    reg = registry(smoke=args.smoke)
    names = args.only.split(",") if args.only else list(reg)
    failures = 0
    results = {}
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            payload = reg[name]()
            dt = time.time() - t0
            results[name] = {"ok": True, "seconds": dt,
                             "payload": payload if isinstance(payload, dict)
                             else None}
            print(f"# {name} done in {dt:.1f}s", flush=True)
        except Exception:
            failures += 1
            results[name] = {"ok": False, "seconds": time.time() - t0,
                             "error": traceback.format_exc(limit=5)}
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
            # Post-mortem: dump every live flight recorder next to the
            # rollup so CI uploads the recent query lifecycles that led
            # up to the failure (FLIGHT_*.json — outside the BENCH_*
            # glob check_regression reads).
            try:
                from repro.obs import dump_live_recorders

                from .common import REPORT_DIR
                for p in dump_live_recorders(REPORT_DIR,
                                             reason=f"bench_{name}"):
                    print(f"# flight dump -> {p}", flush=True)
            except Exception:
                pass
    from .common import write_run_summary
    path = write_run_summary(results)
    print(f"# run summary -> {path}", flush=True)
    if failures:
        sys.exit(f"{failures} benchmarks failed")


if __name__ == '__main__':
    main()
