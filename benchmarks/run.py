import os
# Benchmarks need real two-group co-processing: 8 host devices (2 C + 6 G).
# (Deliberately NOT 512 — that flag belongs only to launch/dryrun.py.)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; artifacts land in reports/bench/.

  python -m benchmarks.run            # full suite
  python -m benchmarks.run --only fig4,roofline
  REPRO_BENCH_SCALE=16 ...            # paper-scale 16M-tuple relations
"""
import argparse
import sys
import time
import traceback


def registry():
    from . import alloc_figs, paper_figs, roofline, scale_figs
    return {
        "fig3": paper_figs.fig3_time_breakdown,
        "fig4": paper_figs.fig4_step_unit_costs,
        "fig5_6": paper_figs.fig5_6_pl_ratios,
        "fig7": paper_figs.fig7_dd_estimate_vs_measured,
        "fig8": paper_figs.fig8_pl_special_case,
        "fig9": paper_figs.fig9_monte_carlo,
        "fig10": paper_figs.fig10_shared_vs_separate,
        "fig11_12": alloc_figs.fig11_12_allocator,
        "divergence": alloc_figs.workload_divergence,
        "partition_fused": paper_figs.partition_fused_bench,
        "table3": paper_figs.table3_step_granularity,
        "fig13_14_uniform": lambda: scale_figs.fig13_14_end_to_end("uniform"),
        "fig13_14_high_skew": lambda: scale_figs.fig13_14_end_to_end("high"),
        "fig15": paper_figs.fig15_selectivity,
        "fig16": paper_figs.fig16_basic_unit,
        "fig19": scale_figs.fig19_large_data,
        "fig20": alloc_figs.fig20_locking_microbench,
        "tpu_projection": scale_figs.tpu_pod_projection,
        "roofline": roofline.run,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    reg = registry()
    names = args.only.split(",") if args.only else list(reg)
    failures = 0
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            reg[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(f"{failures} benchmarks failed")


if __name__ == '__main__':
    main()
