import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Each experiment re-lowers one of the three chosen cells with a candidate
change, extracts the roofline terms, and appends a record to
reports/perf_log.json.  EXPERIMENTS.md §Perf narrates the log.

  PYTHONPATH=src python -m benchmarks.perf_hillclimb [--exp NAME]
"""
import argparse
import dataclasses
import json

CELLS = ["granite_moe_3b", "qwen3_32b", "llama4_maverick_400b"]
LOG = os.path.join(os.path.dirname(__file__), "..", "reports",
                   "perf_log.json")


def _analyze(rep):
    from benchmarks.roofline import analyze_cell
    return analyze_cell(rep)


def run_exp(name: str, arch: str, *, rules=None, cfg_patch=None,
            hypothesis: str = "", shape: str = "train_4k"):
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell, save_report
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    rep = lower_cell(arch, shape, rules=rules, cfg=cfg, tag=name)
    r = _analyze(rep)
    rec = {"exp": name, "arch": arch, "shape": shape,
           "hypothesis": hypothesis, **r,
           "mem_gib": round((rep["memory"]["argument_bytes"]
                             + rep["memory"]["temp_bytes"]
                             + rep["memory"]["output_bytes"]
                             - rep["memory"]["alias_bytes"]) / 2**30, 2),
           "compile_s": rep["compile_s"]}
    log = json.load(open(LOG)) if os.path.exists(LOG) else []
    log.append(rec)
    os.makedirs(os.path.dirname(LOG), exist_ok=True)
    json.dump(log, open(LOG, "w"), indent=1)
    print(json.dumps(rec, indent=1))
    return rec


EXPERIMENTS = {}


def exp(name):
    def deco(f):
        EXPERIMENTS[name] = f
        return f
    return deco


@exp("hsdp_granite")
def _a():
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_granite", "granite_moe_3b", rules=DP_RULES,
        hypothesis=("HSDP (batch over both axes) removes the 16x "
                    "replicated-head attention waste and SP round-trips; "
                    "collectives become ~3x param bytes: expect useful "
                    "0.17->0.5+, frac 0.008->0.05+"))


@exp("hsdp_qwen32b")
def _b():
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_qwen32b", "qwen3_32b", rules=DP_RULES,
        hypothesis=("collective term is SP/TP activation round-trips "
                    "(~17s/chip); HSDP swaps them for ~3x65GB weight "
                    "gathers /256... wait, per-chip AG volume is full "
                    "params (65GB*3/50GB/s=3.9s): expect coll 17.1->~4s, "
                    "frac 0.238->~0.5"))


@exp("hsdp_llama4")
def _c():
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_llama4", "llama4_maverick_400b", rules=DP_RULES,
        cfg_patch={"train_accum": 8},
        hypothesis=("HSDP kills 40-head replication; but FSDP weight AG "
                    "is 800GB*3/chip/50GB/s = 48s >> baseline coll 14.3s "
                    "-> expect collective-term REGRESSION unless accum "
                    "amortizes; measuring to check"))


@exp("moe_group_llama4")
def _d():
    from repro.configs.base import MoECfg
    return run_exp(
        "moe_group_llama4", "llama4_maverick_400b",
        cfg_patch={"moe": MoECfg(num_experts=128, top_k=1, d_ff=8192,
                                 shared_d_ff=8192, capacity_factor=1.25,
                                 group_size=256),
                   "train_accum": 8},
        hypothesis=("dense-dispatch FLOPs/token scale with E*C = "
                    "T*k*cf: group 1024->256 cuts dispatch+combine einsum "
                    "flops ~2.5x (capacity floor): expect useful "
                    "0.30->~0.45, compute term down ~20%"))


@exp("moe_group_granite")
def _e():
    from repro.configs.base import MoECfg
    return run_exp(
        "moe_group_granite", "granite_moe_3b",
        cfg_patch={"moe": MoECfg(num_experts=40, top_k=8, d_ff=512,
                                 shared_d_ff=0, capacity_factor=1.25,
                                 group_size=128)},
        hypothesis=("granite dispatch E*C=10240 per token ~2x the expert "
                    "FFN work; T=128 -> C=32, E*C=1280 (8x less): expect "
                    "useful 0.17->0.3+"))


@exp("hsdp_moe_granite")
def _f():
    from repro.configs.base import MoECfg
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_moe_granite", "granite_moe_3b", rules=DP_RULES,
        cfg_patch={"moe": MoECfg(num_experts=40, top_k=8, d_ff=512,
                                 shared_d_ff=0, capacity_factor=1.25,
                                 group_size=128)},
        hypothesis="compose the two granite wins (HSDP + small groups)")


@exp("hsdp_moe_llama4")
def _g():
    from repro.configs.base import MoECfg
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_moe_llama4", "llama4_maverick_400b", rules=DP_RULES,
        cfg_patch={"moe": MoECfg(num_experts=128, top_k=1, d_ff=8192,
                                 shared_d_ff=8192, capacity_factor=1.25,
                                 group_size=256),
                   "train_accum": 8},
        hypothesis="compose dispatch shrink with HSDP for llama4")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    args = ap.parse_args()
    names = [args.exp] if args.exp else list(EXPERIMENTS)
    for n in names:
        print(f"# === {n} ===", flush=True)
        try:
            EXPERIMENTS[n]()
        except Exception as e:  # noqa: BLE001
            print(f"# {n} FAILED: {type(e).__name__}: {e}")
            import traceback
            traceback.print_exc()




@exp("baseline_granite")
def _h():
    return run_exp("baseline_granite", "granite_moe_3b",
                   hypothesis="re-baseline under corrected RS accounting")


@exp("baseline_qwen32b")
def _i():
    return run_exp("baseline_qwen32b", "qwen3_32b",
                   hypothesis="re-baseline under corrected RS accounting")


@exp("baseline_llama4")
def _j():
    return run_exp("baseline_llama4", "llama4_maverick_400b",
                   hypothesis="re-baseline under corrected RS accounting")


@exp("hsdp_accum_qwen32b")
def _k():
    from repro.distributed.sharding import DP_RULES
    return run_exp(
        "hsdp_accum_qwen32b", "qwen3_32b", rules=DP_RULES,
        cfg_patch={"train_accum": 2},
        hypothesis=("round 2: HSDP won (frac 0.72) but 21.6GiB > HBM; "
                    "accum=2 halves activation temps at unchanged "
                    "FLOPs/collectives: expect <16GiB, frac holds ~0.7"))


@exp("padheads_moe_granite")
def _l():
    from repro.configs.base import MoECfg
    return run_exp(
        "padheads_moe_granite", "granite_moe_3b",
        cfg_patch={"num_heads": 32,
                   "moe": MoECfg(num_experts=40, top_k=8, d_ff=512,
                                 shared_d_ff=0, capacity_factor=1.25,
                                 group_size=128)},
        hypothesis=("round 2: HSDP refuted (expert-TP conflict: 167s "
                    "collectives). Instead pad 24->32 heads (+33% attn "
                    "FLOPs, zero-init extra heads) so attention shards "
                    "16-way instead of replicating 16x, keep small "
                    "dispatch groups: expect useful 0.21->0.3+, frac up"))


@exp("sorted_moe_llama4")
def _m():
    from repro.configs.base import MoECfg
    return run_exp(
        "sorted_moe_llama4", "llama4_maverick_400b",
        cfg_patch={"moe_impl": "sorted", "train_accum": 8},
        hypothesis=("round 2: group-size shrink refuted (capacity floor "
                    "C>=4 raised expert slots 1.57M->2.1M). The paper's "
                    "own answer is sort-based dispatch (no capacity "
                    "padding): global argsort under pjit may cost "
                    "collectives; measuring flops vs comms tradeoff"))


@exp("granite_r3_dispatch_local")
def _n():
    from repro.configs.base import MoECfg
    from repro.distributed.sharding import ShardingRules, TRAIN_RULES
    # expert_cap -> (): dispatch/expert buffers stay data-sharded only, so
    # no per-layer model-axis reshard of the (G,T,E,C) tensors.
    rules = ShardingRules(tuple(
        (k, () if k == "expert_cap" else v) for k, v in TRAIN_RULES.rules))
    return run_exp(
        "granite_r3_dispatch_local", "granite_moe_3b", rules=rules,
        cfg_patch={"num_heads": 32,
                   "moe": MoECfg(num_experts=40, top_k=8, d_ff=512,
                                 shared_d_ff=0, capacity_factor=1.25,
                                 group_size=128)},
        hypothesis=("round 3: padheads won compute (0.63->0.30) but coll "
                    "rose to 14.9s — suspect model-axis resharding of "
                    "dispatch tensors (expert_cap sharding). Keep them "
                    "data-local: expect coll down toward ~8s, frac up "
                    "3-4x (memory may rise, buffers replicated on model)"))


@exp("llama4_r3_remat_dots")
def _o():
    return run_exp(
        "llama4_r3_remat_dots", "llama4_maverick_400b",
        cfg_patch={"remat": "dots", "train_accum": 8},
        hypothesis=("round 3: llama4 memory term (47.8s) includes remat "
                    "recompute re-reads; 'dots' policy saves matmul "
                    "outputs: expect bytes-accessed (memory term) down "
                    "~25%, compute down ~querter of recompute, at higher "
                    "residency (risk: >HBM)"))


if __name__ == "__main__":
    main()
