"""Engine throughput: queries/sec, cache hit rate, adaptive-vs-static.

Three measured figures for the concurrent join-query engine:

  1. **throughput** — a mixed workload (uniform / zipf / selectivity /
     hot-table) streamed through ``JoinQueryService`` with worker overlap;
     reports queries/sec and the build-table-cache hit rate.
  2. **cache reuse** — the same (build, probe) pair cold vs hot: the hot
     path skips the build phase off the resident table (the paper's
     coupled-architecture cache-reuse claim lifted to the query level).
  3. **adaptive planning** — the cost-model planner (measured calibration
     + online feedback) against each single static scheme forced across
     the whole mix; adaptive should match or beat the best static.

Smoke mode (CI) shrinks sizes and query counts so the whole thing runs in
tens of seconds on one core.
"""
from __future__ import annotations

import time

import numpy as np

from .common import N_TUPLES, bench_seed, csv_row, report, time_call


def _verify(queries, outcomes):
    from repro.core import join_oracle
    for q, o in zip(queries, outcomes):
        exp = join_oracle(q.build, q.probe)
        got = o.result.valid_pairs()
        assert got.shape == exp.shape and (got == exp).all(), \
            f"query {q.query_id} ({q.tag}) mismatch under {o.plan.scheme}"


def engine_throughput(smoke: bool = False):
    from repro.core import CoProcessor
    from repro.engine import (NULL_TRACER, JoinQueryService, QueryPlanner,
                              make_workload)

    if smoke:
        base, n_queries, delta, cal_n = 4096, 10, 0.25, 8192
    else:
        base = min(max(N_TUPLES // 16, 16384), 1 << 20)
        n_queries, delta, cal_n = 48, 0.1, 32768

    cp = CoProcessor()
    out: dict = {"smoke": smoke, "base_tuples": base,
                 "num_queries": n_queries}

    # -- 1. mixed-workload throughput ------------------------------------
    planner = QueryPlanner.calibrated(cp, n=cal_n, reps=2, delta=delta)
    # Throughput is the figure here: run with observability disabled
    # (the no-op recorder) — the instrumented paths must cost a branch.
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                           tracer=NULL_TRACER)
    workload = make_workload("mixed", num_queries=n_queries,
                             base_tuples=base, seed=bench_seed(7))
    warm = svc.run(workload)          # compile + warm the table cache
    _verify(workload, warm)
    svc.run(workload)                 # adaptation pass (clean observations)
    t0 = time.perf_counter()
    outs = svc.run(workload)          # steady-state throughput
    elapsed = time.perf_counter() - t0
    stats = svc.stats()
    qps = len(outs) / elapsed
    hit_rate = stats["cache"]["hit_rate"]
    out["throughput"] = {
        "queries_per_s": qps, "elapsed_s": elapsed,
        "queued_s_mean": float(np.mean([o.queued_s for o in outs])),
        "cache": stats["cache"], "plans": stats["planner"]["plan_counts"],
        "online_scales": stats["planner"]["online"],
        "outcomes": [o.to_dict() for o in outs]}
    csv_row("engine/throughput", 1e6 / qps,
            f"qps={qps:.2f};cache_hit_rate={hit_rate:.2f}")
    svc.close()

    # -- 2. cached-build probe path vs cold ------------------------------
    from repro.core import unique_relation
    from repro.engine import JoinQuery, WorkloadGenerator
    # The paper's reuse shape: a large hot build relation (dimension
    # table), repeated small probe batches — cold pays the build every
    # time, hot amortizes it away entirely.
    gen = WorkloadGenerator(base, seed=bench_seed(11))
    hot_build = unique_relation(4 * base, seed=bench_seed(101))
    hot_probe = gen.zipf().probe.take(0, max(256, base // 4))
    hot_q = JoinQuery(build=hot_build, probe=hot_probe, tag="hot",
                      max_out=hot_probe.size + 64, query_id=10_001)
    # This figure measures the cached-probe path against the cold build
    # path, so pin the algorithm to SHJ (PHJ produces no cacheable table).
    shj_pl = QueryPlanner.calibrated(cp, n=cal_n, reps=1, delta=delta,
                                     allow_phj=False)
    cold_svc = JoinQueryService(cp=cp, planner=shj_pl, num_workers=0,
                                tracer=NULL_TRACER)
    first = cold_svc.execute(hot_q)       # compile + populate the cache
    assert not first.cache_hit
    t_cold = time_call(lambda: cold_svc.cache.clear() or
                       cold_svc.execute(hot_q), reps=5)
    # leave the table resident: every call is a hit
    cold_svc.execute(hot_q)
    hot = cold_svc.execute(hot_q)
    assert hot.cache_hit, "expected a build-table cache hit"
    t_hot = time_call(lambda: cold_svc.execute(hot_q), reps=5)
    speedup = t_cold / t_hot
    out["cache_reuse"] = {"cold_s": t_cold, "hot_s": t_hot,
                          "speedup_x": speedup,
                          "hot_ge_2x_faster": bool(speedup >= 2.0)}
    csv_row("engine/cold_build", t_cold * 1e6, "")
    csv_row("engine/cached_probe", t_hot * 1e6,
            f"speedup={speedup:.2f}x")

    # -- 3. adaptive planning vs the best static scheme ------------------
    # Steady-state comparison: two warm passes let compilations land and
    # the online scales converge, then adaptation is frozen (alpha=0) so
    # the timed pass measures the *converged* plans for every config.
    static_n = max(8, n_queries // 2)
    mix = make_workload("mixed", num_queries=static_n, base_tuples=base,
                        seed=bench_seed(23))
    results = {}
    adaptive_plans = None

    def timed_mix(pl_kwargs):
        pl = QueryPlanner.calibrated(cp, n=cal_n, reps=1, delta=delta,
                                     **pl_kwargs)
        s = JoinQueryService(cp=cp, planner=pl, num_workers=2,
                             tracer=NULL_TRACER)
        s.run(mix)                    # adapt pass 1 (compiles, observes)
        s.run(mix)                    # adapt pass 2 (clean feedback)
        s.run(mix)                    # adapt pass 3 (noise averages out)
        s.planner.online.alpha = 0.0  # freeze: plans are now stable
        s.run(mix)                    # compile the frozen plans
        # Median-of-5: this host's wall clock is noisy (shared core), and
        # a descheduled or stray-compile pass would otherwise dominate.
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            s.run(mix)                # timed: converged + compiled
            times.append(time.perf_counter() - t0)
        st = s.planner.stats()
        s.close()
        return float(np.median(times)), {"plans": st["plan_counts"],
                                         "online": st["online"],
                                         "pass_times_s": times}

    for name, allowed in (("CPU_ONLY", ("CPU_ONLY",)),
                          ("GPU_ONLY", ("GPU_ONLY",)), ("DD", ("DD",))):
        results[name], _ = timed_mix({"allowed_schemes": allowed,
                                      "allow_phj": False})
    results["adaptive"], adaptive_plans = timed_mix({})
    statics = [v for k, v in results.items() if k != "adaptive"]
    best_static = min(statics)
    # Tolerance = this host's observed config-level noise band: identical
    # configs vary by ~±20-30% across invocations on the shared core (the
    # statics themselves swap ranking run to run), and ``best_static`` is
    # the min of three noisy draws, which biases the baseline low.
    out["scheme_comparison"] = {
        "elapsed_s": results,
        "best_static_s": best_static,
        "median_static_s": float(np.median(statics)),
        "adaptive_plans": adaptive_plans,
        "adaptive_vs_median_static": results["adaptive"]
        / float(np.median(statics)),
        "adaptive_no_worse": bool(results["adaptive"]
                                  <= best_static * 1.2)}
    for name, t in results.items():
        csv_row(f"engine/mix_{name}", t * 1e6,
                f"vs_best_static={t/best_static:.2f}x")
    report("engine_throughput", out)
    return out
