"""SLO serving benchmark: cost-priced admission vs count-only FIFO.

Replays one open-loop arrival schedule (bursty overload, three tenants,
optional hot-tenant skew) against ``JoinQueryService`` twice — once with
``admission_mode="cost"`` (the deadline-aware two-level scheduler) and
once with ``admission_mode="fifo"`` (count-only baseline: global arrival
order, no deadline decisions) — and reports, per mode and per tenant:

  * p50 / p99 end-to-end latency (queued + execution),
  * deadline hit rate over *all submitted* queries (a shed or rejected
    query counts as a miss — shedding is only a win when the saved
    capacity turns into on-time completions elsewhere),
  * shed rate, and whether every shed carried a structured
    ``Backpressure`` (reason + retry-after), never a timeout,
  * Jain's fairness index over per-tenant completion ratios.

Two resilience sections ride on the same measured schedule: a
preemption on-vs-off A/B over the bursty overload (FIFO admission, so
deadline-dead queued queries reach workers unless preemption drops
them — ``preemption.gold_hit_rate_on/off`` + the ``preemptions``
counter), and a chaos smoke replay under a seeded ``FaultInjector``
(``chaos.unstructured_failures`` / ``row_exact`` / ``hung_workers`` —
the recovery ladder must absorb every injected fault).

Deadlines and the arrival rate are derived from the measured per-query
service time on this host (a closed-loop warm pass), so the bench applies
the same relative overload everywhere it runs.  Smoke mode shrinks sizes
and counts for CI; its ``deadline_hit_rate``/``shed_rate`` figures are
regression-gated by ``check_regression``.
"""
from __future__ import annotations

import datetime
import json
import os
import time

import numpy as np

from .common import N_TUPLES, REPORT_DIR, bench_seed, csv_row, report

TENANTS = ("gold", "silver", "bronze")
# Deadline classes in multiples of the measured mean service time: gold is
# tight, bronze is lax — the spread the EDF level exists to exploit.  The
# smoke replay uses tighter multiples: its 24 queries finish too quickly
# for 6-24x deadlines to ever be at risk, and the alert gate needs real
# misses to burn against.
DEADLINE_X = {"gold": 6.0, "silver": 12.0, "bronze": 24.0}
DEADLINE_X_SMOKE = {"gold": 2.0, "silver": 4.0, "bronze": 8.0}


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p)) \
        if xs else 0.0


def _replay(svc, events):
    """Open-loop replay: submit each event at its scheduled offset
    (non-blocking — arrivals never wait on completions), then drain.
    Returns ``(done, malformed, preempted)`` — a wait that raises the
    structured ``Backpressure`` family is a mid-flight preemption
    (``preempt=True`` services), counted rather than propagated."""
    from repro.engine import Backpressure, QueueFull

    for ev in events:                 # reset admission-time mutations
        ev.query.deadline_at = None
        ev.query.degraded = False
    waiters, malformed = [], 0
    t0 = time.perf_counter()
    for ev in events:
        lag = ev.at_s - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        try:
            waiters.append((ev, svc.submit(ev.query, block=False)))
        except Backpressure:
            pass                      # structured record lands in metrics
        except Exception:
            malformed += 1            # a shed that was NOT structured
    done, preempted = [], 0
    for ev, w in waiters:
        try:
            done.append((ev, w()))
        except QueueFull:
            preempted += 1            # structured mid-flight preemption
    return done, malformed, preempted


def _metrics(events, done, malformed, admission_events):
    """Per-tenant rollup.  Shed accounting comes from the service's
    structured ``admission`` event records (the registry ring), not from
    re-deriving reasons out of caught ``Backpressure`` exceptions."""
    from repro.engine import jain_index

    total = len(events)
    sub = {t: 0 for t in TENANTS}
    for ev in events:
        sub[ev.tenant] += 1
    per = {t: {"submitted": sub[t], "completed": 0, "hits": 0,
               "shed": 0, "latencies": []} for t in TENANTS}
    for ev, out in done:
        p = per[ev.tenant]
        p["completed"] += 1
        p["latencies"].append(out.queued_s + out.wall_s)
        if out.deadline_hit:
            p["hits"] += 1
    sheds = [e for e in admission_events
             if e.get("action") in ("shed", "reject")]
    for e in sheds:
        if e.get("tenant") in per:
            per[e["tenant"]]["shed"] += 1
    structured = all(
        e.get("reason") in ("deadline", "queue_full")
        and float(e.get("retry_after_s") or 0.0) > 0.0 for e in sheds)
    tenants = {}
    for t, p in per.items():
        n = max(p["submitted"], 1)
        tenants[t] = {
            "submitted": p["submitted"], "completed": p["completed"],
            "shed": p["shed"], "hit_rate": p["hits"] / n,
            "completion_ratio": p["completed"] / n,
            "p50_s": _percentile(p["latencies"], 50),
            "p99_s": _percentile(p["latencies"], 99)}
    hits = sum(p["hits"] for p in per.values())
    return {
        "total": total,
        "deadline_hit_rate": hits / max(total, 1),
        "shed_rate": len(sheds) / max(total, 1),
        "sheds_structured": bool(structured and malformed == 0),
        "jain_completion": jain_index(
            [tenants[t]["completion_ratio"] for t in TENANTS]),
        "jain_hit_rate": jain_index(
            [tenants[t]["hit_rate"] for t in TENANTS]),
        "tenants": tenants}


def slo_bench(smoke: bool = False):
    from repro.core import CoProcessor
    from repro.engine import (JoinQueryService, QueryPlanner, Tenant,
                              open_loop)

    if smoke:
        base, n_queries, cal_n, delta = 4096, 24, 8192, 0.25
        # Arrival rate = overload / mean service time, shared across 2
        # workers whose device dispatch overlaps — effective capacity
        # runs well past 2/mean, so the overload must be decisive (not
        # marginal) for the 24-query replay to produce the storm the
        # burn-rate alert gate expects.
        overload, burst_factor = 4.0, 4.0
    else:
        base = min(max(N_TUPLES // 32, 16384), 1 << 19)
        n_queries, cal_n, delta = 120, 32768, 0.1
        overload, burst_factor = 3.0, 6.0

    cp = CoProcessor()
    planner = QueryPlanner.calibrated(cp, n=cal_n, reps=2, delta=delta)
    out: dict = {"smoke": smoke, "base_tuples": base,
                 "num_queries": n_queries}

    # -- closed-loop warm pass: compile executables, measure service time
    warm_events = open_loop(n_queries, rate_qps=1.0, mix="mixed",
                            tenant_mix=[(t, 1.0) for t in TENANTS],
                            base_tuples=base, seed=bench_seed(31))
    # Compile pass: eats the XLA compiles (preferred AND deadline-
    # degraded plan variants — the drift-priced admission margins degrade
    # queries mid-replay, and a first-use compile inside the replay would
    # charge one query seconds of wall clock the scheduler never priced).
    warm_svc = JoinQueryService(cp=cp, planner=planner, num_workers=0)
    for ev in warm_events:
        warm_svc.execute(ev.query)
    for ev in warm_events:
        ev.query.degraded = True
        warm_svc.execute(ev.query)
        ev.query.degraded = False
    warm_svc.close()
    # Timed pass on a FRESH service: compiled code is process-wide but
    # the build-table cache is per-service, so timing against a fresh
    # cache reproduces what each replay service will actually pay (first
    # touch of a relation builds, repeats hit) — a cache-hot mean would
    # overload the steady replay, a compile-laden mean would underload
    # the bursty one, and the alert gates need both calibrated.
    timed_svc = JoinQueryService(cp=cp, planner=planner, num_workers=0)
    times = []
    for ev in warm_events:
        t0 = time.perf_counter()
        timed_svc.execute(ev.query)
        times.append(time.perf_counter() - t0)
    timed_svc.close()
    # Robust mean: a stray first-use compile in the timed pass (a plan
    # variant the warm pass didn't reach, e.g. after an online-cost
    # replan) charges one sample ~50x the typical service time and the
    # arithmetic mean then under-loads every derived replay — rates and
    # deadlines would be calibrated to compile time, not service time.
    # Trim samples beyond 10x the median before averaging.
    arr = np.asarray(times)
    med = float(np.median(arr))
    mean_s = float(np.mean(arr[arr <= 10.0 * med])) if med > 0 \
        else float(np.mean(arr))
    planner.online.alpha = 0.0        # freeze adaptation: fair replays
    out["mean_service_s"] = mean_s

    # -- the measured schedule: bursty overload, hot tenant, per-class
    #    deadlines, all derived from the measured service time
    rate = overload / max(mean_s, 1e-6)
    deadline_x = DEADLINE_X_SMOKE if smoke else DEADLINE_X
    deadlines = {t: x * mean_s for t, x in deadline_x.items()}
    events = open_loop(
        n_queries, rate_qps=rate, mix="mixed", arrivals="burst",
        burst_factor=burst_factor, burst_fraction=0.3,
        tenant_mix=[(t, 1.0) for t in TENANTS],
        hot_tenant=None if smoke else "gold",
        hot_skew=0.0 if smoke else 0.2,
        deadlines=deadlines, base_tuples=base, seed=bench_seed(31))
    out["rate_qps"] = rate
    out["deadlines_s"] = deadlines

    tenants = [Tenant(t, weight=1.0, deadline_s=deadlines[t])
               for t in TENANTS]

    # -- steady-state control: well inside capacity (0.5x), Poisson
    #    arrivals.  The SLO monitor must stay silent here — regression-
    #    gated at zero alerts (alerts that fire at steady state are noise
    #    that trains operators to ignore the pager).
    steady_n = max(12, n_queries // 2)
    steady_events = open_loop(
        steady_n, rate_qps=0.5 / max(mean_s, 1e-6), mix="mixed",
        arrivals="poisson", tenant_mix=[(t, 1.0) for t in TENANTS],
        deadlines=deadlines, base_tuples=base, seed=bench_seed(33))
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                           max_queue=max(4 * steady_n, 256),
                           tenants=list(tenants), admission_mode="cost")
    _replay(svc, steady_events)
    svc.slo.evaluate(force=True)
    steady_snap = svc.stats()["metrics"]
    out["slo_alerts_steady"] = int(
        (steady_snap.get("slo") or {}).get("alerts_total", 0))
    out["slo_steady_active"] = (steady_snap.get("slo") or {}).get(
        "active", [])
    svc.close()
    csv_row("slo/steady", 1e6 * mean_s,
            f"alerts={out['slo_alerts_steady']}")

    results = {}
    for mode in ("cost", "fifo"):
        svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                               max_queue=max(4 * n_queries, 256),
                               tenants=list(tenants), admission_mode=mode)
        done, malformed, _ = _replay(svc, events)
        svc.slo.evaluate(force=True)
        st = svc.stats()
        results[mode] = _metrics(events, done, malformed,
                                 svc.metrics.events("admission"))
        results[mode]["service_stats"] = {
            k: st[k]
            for k in ("admitted", "rejected", "shed", "degraded",
                      "completed", "failed")}
        # Per-tenant predicted-vs-measured error (p50/p95 ratio) from the
        # cost-model audit trail — ROADMAP item 1's raw material.
        results[mode]["prediction_error"] = st["metrics"].get(
            "prediction_error")
        if mode == "cost":
            # The observability loop under overload, regression-gated:
            # burn-rate alerts must fire during the bursty replay, the
            # staleness gauge must exist and be finite, and the flight-
            # recorder dump must be schema-valid.
            snap = st["metrics"]
            out["slo_alerts_burst"] = int(
                (snap.get("slo") or {}).get("alerts_total", 0))
            out["slo_burst_active"] = (snap.get("slo") or {}).get(
                "active", [])
            out["cost_model_staleness"] = snap.get("cost_model_staleness")
            out["admission_margins"] = (snap.get("drift") or {}).get(
                "margins", {})
            stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
                "%Y%m%dT%H%M%SZ")
            os.makedirs(REPORT_DIR, exist_ok=True)
            dump_path = os.path.join(REPORT_DIR,
                                     f"FLIGHT_slo_{stamp}.json")
            svc.flight.write_dump(dump_path, reason="bursty_overload")
            from repro.obs import validate_dump
            with open(dump_path) as f:
                out["flight_dump_valid"] = bool(
                    validate_dump(json.load(f)))
            out["flight_dump"] = os.path.basename(dump_path)
        svc.close()
        csv_row(f"slo/{mode}", 1e6 * mean_s,
                f"hit_rate={results[mode]['deadline_hit_rate']:.2f};"
                f"shed_rate={results[mode]['shed_rate']:.2f};"
                f"jain={results[mode]['jain_completion']:.2f}")
    out["modes"] = results

    # -- resilience: preemption on-vs-off at equal offered load ----------
    # FIFO admission for the A/B: cost-mode sheds predicted misses up
    # front, which is exactly the capacity-saving mechanism preemption
    # provides *after* admission — measuring preemption's own value needs
    # the count-only baseline where dead queries otherwise reach workers.
    # Marginal overload (1.3x base, bursty), its own schedule: at the
    # alert-storm rate above every deadline is hopeless with or without
    # preemption, zeroing both sides.  With recovery headroom between
    # bursts the mechanism is visible: the preempting service discards
    # its dead backlog in O(1) per query and is current again when the
    # next reachable query arrives, while the baseline grinds through
    # stale work and misses from the first burst onward.
    # Relaxed deadline classes (3x the alert-storm multiples): the A/B
    # measures whether preemption keeps *reachable* deadlines reachable
    # under backlog — the alert-storm multiples are calibrated to be
    # hopeless (that section needs misses to burn).
    pre_n = max(n_queries, 48)
    pre_deadlines = {t: 3.0 * x * mean_s for t, x in deadline_x.items()}
    pre_events = open_loop(
        pre_n, rate_qps=1.3 / max(mean_s, 1e-6), mix="mixed",
        arrivals="burst", burst_factor=burst_factor, burst_fraction=0.3,
        tenant_mix=[(t, 1.0) for t in TENANTS],
        deadlines=pre_deadlines, base_tuples=base, seed=bench_seed(35))
    pre: dict = {}
    for flag in (False, True):
        svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                               max_queue=max(4 * n_queries, 256),
                               tenants=list(tenants),
                               admission_mode="fifo", preempt=flag)
        done, malformed, preempted = _replay(svc, pre_events)
        st = svc.stats()
        per = {t: {"submitted": 0, "hits": 0} for t in TENANTS}
        for ev in pre_events:
            per[ev.tenant]["submitted"] += 1
        for ev, o in done:
            if o.deadline_hit:
                per[ev.tenant]["hits"] += 1
        key = "on" if flag else "off"
        pre[key] = {
            # Preempted (and malformed) queries count as misses: hit
            # rate is over everything submitted, at equal offered load.
            "hit_rate": sum(p["hits"] for p in per.values())
                        / max(len(pre_events), 1),
            "gold_hit_rate": (per["gold"]["hits"]
                              / max(per["gold"]["submitted"], 1)),
            "preempted_waits": preempted,
            "preemptions": st["resilience"]["preemptions"],
            "malformed": malformed}
        svc.close()
        csv_row(f"slo/preempt_{key}", 1e6 * mean_s,
                f"gold_hit={pre[key]['gold_hit_rate']:.2f};"
                f"preemptions={pre[key]['preemptions']}")
    pre["gold_hit_rate_on"] = pre["on"]["gold_hit_rate"]
    pre["gold_hit_rate_off"] = pre["off"]["gold_hit_rate"]
    pre["hit_rate_on"] = pre["on"]["hit_rate"]
    pre["hit_rate_off"] = pre["off"]["hit_rate"]
    pre["preemptions"] = pre["on"]["preemptions"]
    # "Improves" is strict: at equal offered load preemption must raise
    # the gold-class hit rate, or the overall one — matching-but-equal
    # rates mean the preemption machinery isn't earning its keep.
    pre["preempt_improves"] = bool(
        pre["gold_hit_rate_on"] > pre["gold_hit_rate_off"]
        or pre["hit_rate_on"] > pre["hit_rate_off"])
    out["preemption"] = pre

    # -- chaos smoke: seeded faults under load; invariants, not timings --
    # No deadlines: every admitted query must complete (through the
    # retry/degrade/reference ladder if needed) and be row-exact against
    # the NumPy oracle; every failure must be structured Backpressure.
    from repro.engine import FaultInjector, FaultSpec, injected
    from repro.ops.join_variants import join_variant_oracle

    def _rows(result):
        cnt = int(result.count)
        rows = np.stack(
            [np.asarray(result.probe_rid)[:cnt].astype(np.int64),
             np.asarray(result.build_rid)[:cnt].astype(np.int64)], axis=1)
        return rows[np.lexsort((rows[:, 1], rows[:, 0]))]

    chaos_events = open_loop(
        min(n_queries, 16), rate_qps=rate, mix="mixed",
        arrivals="poisson", tenant_mix=[(t, 1.0) for t in TENANTS],
        base_tuples=base, seed=bench_seed(37))
    inj = FaultInjector(seed=bench_seed(41), sites={
        # at=4 guarantees the ladder fires at least once per run; the
        # Bernoulli term adds seed-deterministic spice on top.
        "kernel": FaultSpec(mode="raise", at=(4,), p=0.05, max_faults=6),
        "h2d": FaultSpec(mode="delay", p=0.15, delay_s=0.002),
        "worker": FaultSpec(mode="raise", at=(3,))})
    # Best-effort tenants (no deadline classes): the soak is about the
    # recovery ladder, so every admitted query should run to completion
    # and be row-exact — deadline behavior has its own section above.
    svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                           max_queue=max(4 * n_queries, 256),
                           admission_mode="cost", preempt=True)
    unstructured, completed, row_exact = 0, 0, True
    with injected(inj):
        from repro.engine import QueueFull
        waiters = []
        t0 = time.perf_counter()
        for ev in chaos_events:
            lag = ev.at_s - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            try:
                waiters.append((ev.query,
                                svc.submit(ev.query, block=False)))
            except QueueFull:
                pass                  # structured
            except Exception:
                unstructured += 1
        for q, w in waiters:
            try:
                o = w()
            except QueueFull:
                continue              # structured preemption
            except Exception:
                unstructured += 1
                continue
            completed += 1
            if not np.array_equal(
                    _rows(o.result),
                    join_variant_oracle(q.build, q.probe, q.kind)):
                row_exact = False
        workers = list(svc._workers)
        svc.close(drain=True)
    st = svc.stats()
    out["chaos"] = {
        "queries": len(chaos_events), "completed": completed,
        "unstructured_failures": unstructured,
        "row_exact": bool(row_exact and completed > 0),
        "hung_workers": int(sum(t.is_alive() for t in workers)),
        "queue_depth_after_close": len(svc._queue),
        "failed": st["failed"],
        "faults_fired": inj.stats()["fired"],
        "retries": st["resilience"]["retries"],
        "preemptions": st["resilience"]["preemptions"],
        "worker_restarts": st["resilience"]["worker_restarts"],
        "budget_throttles": st["resilience"]["budget_throttles"],
        "breakers": st["resilience"]["breakers"],
        "breaker_events": len(svc.metrics.events("breaker"))}
    csv_row("slo/chaos", 1e6 * mean_s,
            f"completed={completed};unstructured={unstructured};"
            f"row_exact={out['chaos']['row_exact']}")

    out["deadline_hit_rate"] = results["cost"]["deadline_hit_rate"]
    out["shed_rate"] = results["cost"]["shed_rate"]
    out["cost_beats_fifo"] = bool(
        results["cost"]["deadline_hit_rate"]
        >= results["fifo"]["deadline_hit_rate"])
    out["sheds_structured"] = bool(results["cost"]["sheds_structured"])
    out["prediction_error"] = results["cost"]["prediction_error"]
    report("slo_bench", out)
    return out
