"""SLO serving benchmark: cost-priced admission vs count-only FIFO.

Replays one open-loop arrival schedule (bursty overload, three tenants,
optional hot-tenant skew) against ``JoinQueryService`` twice — once with
``admission_mode="cost"`` (the deadline-aware two-level scheduler) and
once with ``admission_mode="fifo"`` (count-only baseline: global arrival
order, no deadline decisions) — and reports, per mode and per tenant:

  * p50 / p99 end-to-end latency (queued + execution),
  * deadline hit rate over *all submitted* queries (a shed or rejected
    query counts as a miss — shedding is only a win when the saved
    capacity turns into on-time completions elsewhere),
  * shed rate, and whether every shed carried a structured
    ``Backpressure`` (reason + retry-after), never a timeout,
  * Jain's fairness index over per-tenant completion ratios.

Deadlines and the arrival rate are derived from the measured per-query
service time on this host (a closed-loop warm pass), so the bench applies
the same relative overload everywhere it runs.  Smoke mode shrinks sizes
and counts for CI; its ``deadline_hit_rate``/``shed_rate`` figures are
regression-gated by ``check_regression``.
"""
from __future__ import annotations

import time

import numpy as np

from .common import N_TUPLES, bench_seed, csv_row, report

TENANTS = ("gold", "silver", "bronze")
# Deadline classes in multiples of the measured mean service time: gold is
# tight, bronze is lax — the spread the EDF level exists to exploit.
DEADLINE_X = {"gold": 6.0, "silver": 12.0, "bronze": 24.0}


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p)) \
        if xs else 0.0


def _replay(svc, events):
    """Open-loop replay: submit each event at its scheduled offset
    (non-blocking — arrivals never wait on completions), then drain."""
    from repro.engine import Backpressure

    for ev in events:                 # reset admission-time mutations
        ev.query.deadline_at = None
        ev.query.degraded = False
    waiters, malformed = [], 0
    t0 = time.perf_counter()
    for ev in events:
        lag = ev.at_s - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        try:
            waiters.append((ev, svc.submit(ev.query, block=False)))
        except Backpressure:
            pass                      # structured record lands in metrics
        except Exception:
            malformed += 1            # a shed that was NOT structured
    done = []
    for ev, w in waiters:
        done.append((ev, w()))
    return done, malformed


def _metrics(events, done, malformed, admission_events):
    """Per-tenant rollup.  Shed accounting comes from the service's
    structured ``admission`` event records (the registry ring), not from
    re-deriving reasons out of caught ``Backpressure`` exceptions."""
    from repro.engine import jain_index

    total = len(events)
    sub = {t: 0 for t in TENANTS}
    for ev in events:
        sub[ev.tenant] += 1
    per = {t: {"submitted": sub[t], "completed": 0, "hits": 0,
               "shed": 0, "latencies": []} for t in TENANTS}
    for ev, out in done:
        p = per[ev.tenant]
        p["completed"] += 1
        p["latencies"].append(out.queued_s + out.wall_s)
        if out.deadline_hit:
            p["hits"] += 1
    sheds = [e for e in admission_events
             if e.get("action") in ("shed", "reject")]
    for e in sheds:
        if e.get("tenant") in per:
            per[e["tenant"]]["shed"] += 1
    structured = all(
        e.get("reason") in ("deadline", "queue_full")
        and float(e.get("retry_after_s") or 0.0) > 0.0 for e in sheds)
    tenants = {}
    for t, p in per.items():
        n = max(p["submitted"], 1)
        tenants[t] = {
            "submitted": p["submitted"], "completed": p["completed"],
            "shed": p["shed"], "hit_rate": p["hits"] / n,
            "completion_ratio": p["completed"] / n,
            "p50_s": _percentile(p["latencies"], 50),
            "p99_s": _percentile(p["latencies"], 99)}
    hits = sum(p["hits"] for p in per.values())
    return {
        "total": total,
        "deadline_hit_rate": hits / max(total, 1),
        "shed_rate": len(sheds) / max(total, 1),
        "sheds_structured": bool(structured and malformed == 0),
        "jain_completion": jain_index(
            [tenants[t]["completion_ratio"] for t in TENANTS]),
        "jain_hit_rate": jain_index(
            [tenants[t]["hit_rate"] for t in TENANTS]),
        "tenants": tenants}


def slo_bench(smoke: bool = False):
    from repro.core import CoProcessor
    from repro.engine import (JoinQueryService, QueryPlanner, Tenant,
                              open_loop)

    if smoke:
        base, n_queries, cal_n, delta = 4096, 24, 8192, 0.25
        overload, burst_factor = 2.5, 4.0
    else:
        base = min(max(N_TUPLES // 32, 16384), 1 << 19)
        n_queries, cal_n, delta = 120, 32768, 0.1
        overload, burst_factor = 3.0, 6.0

    cp = CoProcessor()
    planner = QueryPlanner.calibrated(cp, n=cal_n, reps=2, delta=delta)
    out: dict = {"smoke": smoke, "base_tuples": base,
                 "num_queries": n_queries}

    # -- closed-loop warm pass: compile executables, measure service time
    warm_events = open_loop(n_queries, rate_qps=1.0, mix="mixed",
                            tenant_mix=[(t, 1.0) for t in TENANTS],
                            base_tuples=base, seed=bench_seed(31))
    warm_svc = JoinQueryService(cp=cp, planner=planner, num_workers=0)
    times = []
    for ev in warm_events:
        t0 = time.perf_counter()
        warm_svc.execute(ev.query)
        times.append(time.perf_counter() - t0)
    warm_svc.close()
    # Steady-state mean: drop the first half (compiles land there).
    mean_s = float(np.mean(times[len(times) // 2:]))
    planner.online.alpha = 0.0        # freeze adaptation: fair replays
    out["mean_service_s"] = mean_s

    # -- the measured schedule: bursty overload, hot tenant, per-class
    #    deadlines, all derived from the measured service time
    rate = overload / max(mean_s, 1e-6)
    deadlines = {t: x * mean_s for t, x in DEADLINE_X.items()}
    events = open_loop(
        n_queries, rate_qps=rate, mix="mixed", arrivals="burst",
        burst_factor=burst_factor, burst_fraction=0.3,
        tenant_mix=[(t, 1.0) for t in TENANTS],
        hot_tenant=None if smoke else "gold",
        hot_skew=0.0 if smoke else 0.2,
        deadlines=deadlines, base_tuples=base, seed=bench_seed(31))
    out["rate_qps"] = rate
    out["deadlines_s"] = deadlines

    tenants = [Tenant(t, weight=1.0, deadline_s=deadlines[t])
               for t in TENANTS]
    results = {}
    for mode in ("cost", "fifo"):
        svc = JoinQueryService(cp=cp, planner=planner, num_workers=2,
                               max_queue=max(4 * n_queries, 256),
                               tenants=list(tenants), admission_mode=mode)
        done, malformed = _replay(svc, events)
        st = svc.stats()
        results[mode] = _metrics(events, done, malformed,
                                 svc.metrics.events("admission"))
        results[mode]["service_stats"] = {
            k: st[k]
            for k in ("admitted", "rejected", "shed", "degraded",
                      "completed", "failed")}
        # Per-tenant predicted-vs-measured error (p50/p95 ratio) from the
        # cost-model audit trail — ROADMAP item 1's raw material.
        results[mode]["prediction_error"] = st["metrics"].get(
            "prediction_error")
        svc.close()
        csv_row(f"slo/{mode}", 1e6 * mean_s,
                f"hit_rate={results[mode]['deadline_hit_rate']:.2f};"
                f"shed_rate={results[mode]['shed_rate']:.2f};"
                f"jain={results[mode]['jain_completion']:.2f}")
    out["modes"] = results
    out["deadline_hit_rate"] = results["cost"]["deadline_hit_rate"]
    out["shed_rate"] = results["cost"]["shed_rate"]
    out["cost_beats_fifo"] = bool(
        results["cost"]["deadline_hit_rate"]
        >= results["fifo"]["deadline_hit_rate"])
    out["sheds_structured"] = bool(results["cost"]["sheds_structured"])
    out["prediction_error"] = results["cost"]["prediction_error"]
    report("slo_bench", out)
    return out
