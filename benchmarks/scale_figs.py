"""Figures 13/14 (end-to-end), 19 (large data), and the TPU projection."""
from __future__ import annotations

import numpy as np

from .common import N_TUPLES, csv_row, default_relations, report


def fig13_14_end_to_end(skew: str = "uniform"):
    """End-to-end SHJ/PHJ x {CPU-only, OL(=GPU-only), DD, PL}.

    Measured on the real host two-group executor (mechanism + overheads),
    plus the APU-calibrated cost-model projection (the paper's headline
    53/35/28 percentages live at APU throughput ratios, which one CPU core
    cannot reproduce physically — see EXPERIMENTS.md §Claims).
    """
    from repro.core import CoProcessor
    from repro.core.phj import partition_series
    from repro.core.shj import BUILD_SERIES, PROBE_SERIES
    from .paper_figs import _model_for

    cp = CoProcessor()
    b, s = default_relations(N_TUPLES // 4, skew=skew)
    nb = max(1024, b.size // 4)
    measured = {}
    plans = {
        "cpu_only": ([1.0] * 4, [1.0] * 4),
        "gpu_only_ol": ([0.0] * 4, [0.0] * 4),
        "dd": ([0.25] * 4, [0.42] * 4),
        "pl": ([0.0, 0.25, 0.5, 0.25], [0.0, 0.25, 0.5, 0.25]),
    }
    for name, (br, pr) in plans.items():
        _, t = cp.shj(b, s, num_buckets=nb, max_out=2 * b.size,
                      build_ratios=br, probe_ratios=pr, table_mode="shared")
        measured[name] = t.wall_s
        csv_row(f"fig13_14/{skew}/measured/{name}", t.wall_s * 1e6, "")

    # APU-model projection: optimal plan per scheme, summed over phases.
    model = {}
    for scheme in ("cpu_only", "gpu_only_ol", "dd", "pl"):
        total = 0.0
        for series in (BUILD_SERIES, PROBE_SERIES):
            m = _model_for(series, 16e6)
            if scheme == "cpu_only":
                total += float(m.estimate_batch(np.ones((1, 4)))[0])
            elif scheme == "gpu_only_ol":
                total += float(m.estimate_batch(np.zeros((1, 4)))[0])
            elif scheme == "dd":
                _, t = m.optimize_dd(delta=0.02)
                total += t
            else:
                _, t = m.optimize_pl(delta=0.02)
                total += t
        model[scheme] = total
        csv_row(f"fig13_14/{skew}/apu_model/{scheme}", total * 1e6, "")
    imp = {
        "pl_vs_cpu_pct": 100 * (1 - model["pl"] / model["cpu_only"]),
        "pl_vs_gpu_pct": 100 * (1 - model["pl"] / model["gpu_only_ol"]),
        "pl_vs_dd_pct": 100 * (1 - model["pl"] / model["dd"]),
    }
    out = {"measured_s": measured, "apu_model_s": model,
           "apu_model_improvements": imp,
           "paper_claims_pct": {"pl_vs_cpu": 53, "pl_vs_gpu": 35,
                                "pl_vs_conventional": 28}}
    csv_row(f"fig13_14/{skew}/claims", 0,
            f"pl_vs_cpu={imp['pl_vs_cpu_pct']:.0f}%;"
            f"pl_vs_gpu={imp['pl_vs_gpu_pct']:.0f}%;"
            f"pl_vs_dd={imp['pl_vs_dd_pct']:.0f}%")
    report(f"fig13_14_end_to_end_{skew}", out)
    return out


def fig19_large_data():
    """Fig. 19: data beyond the zero-copy buffer — partition to fit, then
    join partition pairs; copy/partition/join breakdown, scaling check."""
    import time
    from repro.core import phj_join
    base = N_TUPLES // 4
    rows = []
    for mult in (1, 2, 4):
        n = base * mult
        b, s = default_relations(n, seed=mult)
        t0 = time.perf_counter()
        # Planner-chosen pass schedule; buckets_per_part derives from the
        # planned radix width (phj_bucket_count).
        res = phj_join(b, s, max_out=2 * n)
        res.probe_rid.block_until_ready()
        dt = time.perf_counter() - t0
        rows.append({"tuples": n, "join_s": dt})
        csv_row(f"fig19/n={n}", dt * 1e6, f"{n/dt/1e6:.1f}Mtup/s")
    r1, r4 = rows[0], rows[-1]
    out = {"rows": rows,
           "scaling_ratio": (r4["join_s"] / r1["join_s"])
           / (r4["tuples"] / r1["tuples"])}
    report("fig19_large_data", out)
    return out


def tpu_pod_projection():
    """Beyond-paper: the same cost model instantiated with v5e pod groups
    (32-chip C-group vs 224-chip G-group over ICI; DCN for 'discrete') —
    the design-space transfer claimed in DESIGN.md §2."""
    from repro.core.shj import BUILD_SERIES, PROBE_SERIES
    from .paper_figs import _model_for
    out = {}
    for link, discrete in (("ici", False), ("dcn", True)):
        total = {}
        for scheme in ("dd", "pl"):
            tot = 0.0
            for series in (BUILD_SERIES, PROBE_SERIES):
                m = _model_for(series, 1e9, device_pair="tpu", link=link,
                               discrete=discrete)
                _, t = (m.optimize_dd(delta=0.02) if scheme == "dd"
                        else m.optimize_pl(delta=0.02))
                tot += t
            total[scheme] = tot
        out[link] = total
        csv_row(f"tpu_projection/{link}", total["pl"] * 1e6,
                f"dd={total['dd']*1e6:.0f}us")
    out["pl_gain_on_ici_pct"] = 100 * (1 - out["ici"]["pl"]
                                       / out["ici"]["dd"])
    out["pl_gain_on_dcn_pct"] = 100 * (1 - out["dcn"]["pl"]
                                       / out["dcn"]["dd"])
    report("tpu_pod_projection", out)
    return out
