"""Roofline analysis: 3-term model per (arch x shape x mesh) cell, from the
dry-run artifacts in reports/dryrun/ (deliverable g).

  compute term    = HLO_FLOPs_per_chip / peak_bf16
  memory term     = HLO_bytes_per_chip / HBM_bw    (upper bound: counts all
                    buffer traffic as HBM)
  collective term = per-chip ICI link bytes (ring-model, see
                    launch.dryrun.collective_link_bytes) / link_bw
                    ("pod"-axis DCN traffic priced at DCN bw on 2x16x16)

  MODEL_FLOPS     = 6*N_active*tokens (train) / 2*N_active*tokens (prefill,
                    decode) — the "useful" fraction of compiled compute.

  fraction_overlap = ideal_model_time / max(terms)   (perfect overlap)
  fraction_serial  = ideal_model_time / sum(terms)   (no overlap)

The §Perf score quotes fraction_overlap of the dominant-term cell.
"""
from __future__ import annotations

import glob
import json
import os

from .common import report

HW = {"peak": 197e12, "hbm": 819e9, "ici": 50e9, "dcn": 3.2e9}
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def analyze_cell(rep: dict) -> dict:
    dev = rep["devices"]
    flops = rep["flops_per_device"]
    mem_bytes = rep["bytes_accessed_per_device"]
    link_bytes = rep["collectives"]["per_chip_link_bytes"]
    compute_s = flops / HW["peak"]
    memory_s = mem_bytes / HW["hbm"]
    # 2x16x16: pod-axis traffic crosses DCN; approximate the DCN share by
    # the fraction of all-reduce bytes with group size == #pods.
    coll_s = link_bytes / HW["ici"]
    n_act = rep["active_params"]
    mult = 6.0 if rep["kind"] == "train" else 2.0
    model_flops_total = mult * n_act * rep["tokens"]
    ideal_s = model_flops_total / (dev * HW["peak"])
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "ideal_model_s": round(ideal_s, 6),
        "useful_flops_ratio": round(model_flops_total
                                    / max(flops * dev, 1e-9), 3),
        "fraction_overlap": round(ideal_s / max(bound, 1e-12), 4),
        "fraction_serial": round(ideal_s / max(total, 1e-12), 4),
    }


def _advice(rep: dict, r: dict) -> str:
    if r["dominant"] == "collective_s":
        return ("shrink SP/FSDP gathers (overlap with compute; "
                "bigger per-chip batch)")
    if r["dominant"] == "memory_s":
        return "fuse/remat less; Pallas kernels cut re-read traffic"
    if r["useful_flops_ratio"] < 0.5:
        return "kill FLOP waste (dispatch einsums / replicated heads)"
    return "compute-bound: raise MXU utilization (layout, fusion)"


def run(write_markdown: bool = True) -> dict:
    cells = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rep = json.load(open(path))
        key = f"{rep['arch']}__{rep['shape']}__{rep.get('mesh', 'skip')}"
        if rep["status"] == "skipped":
            cells[key] = {"status": "skipped", "why": rep["why"],
                          "arch": rep["arch"], "shape": rep["shape"]}
            continue
        r = analyze_cell(rep)
        r.update(status="ok", arch=rep["arch"], shape=rep["shape"],
                 mesh=rep["mesh"], advice=_advice(rep, r))
        cells[key] = r
        print(f"roofline/{key},{r['ideal_model_s']*1e6:.1f},"
              f"dom={r['dominant']};frac={r['fraction_overlap']:.3f};"
              f"useful={r['useful_flops_ratio']:.2f}")
    report("roofline", cells)
    if write_markdown:
        md = _markdown(cells)
        with open(os.path.join(DRYRUN_DIR, "..", "roofline.md"), "w") as f:
            f.write(md)
    return cells


def _markdown(cells: dict) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s |"
        " dominant | useful FLOPs | frac(overlap) | what moves it |",
        "|---|---|---|---|---|---|---|---|---|---|"[:-4] + "|",
    ]
    for key, r in sorted(cells.items()):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                         f" SKIP | — | — | {r['why'][:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
            f" {r['collective_s']:.4f} | {r['dominant'][:-2]} |"
            f" {r['useful_flops_ratio']:.2f} | {r['fraction_overlap']:.3f} |"
            f" {r['advice']} |")
    return "\n".join(lines) + "\n"


if __name__ == "__main__":
    run()
