"""Resilience layer: seeded fault injection, cooperative deadline
preemption with checkpoint/resume, runtime budget enforcement, the
retry -> degrade -> breaker -> reference recovery ladder, and drain-close.

Scheduling-sensitive tests run on fake clocks and seeded injectors —
fully deterministic; the chaos soak replays a seeded open-loop trace
under a seeded injector and checks *invariants* (structured failures
only, row-exact successes) rather than a specific interleaving."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (CoProcessor, Relation, join_oracle,
                        radix_partition_scheduled, uniform_relation,
                        unique_relation)
from repro.core.partition import (partition_pass,
                                  radix_partition_cooperative)
from repro.core.phj import default_shj_bits, schedule_prefixes
from repro.engine import (AdmissionController, Backpressure, BreakerBoard,
                          BudgetEnforcer, BudgetExceeded, Cancelled,
                          DeadlineExceeded, FaultInjected, FaultInjector,
                          FaultSpec, JoinQuery, JoinQueryService,
                          QueryContext, QueryPlanner, QueueFull,
                          RetryPolicy, Tenant, injected, open_loop)
from repro.engine.resilience import CLOSED, HALF_OPEN, OPEN
from repro.ops.join_variants import join_variant_oracle


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class StepClock:
    """Advances by ``dt`` on every read — time passes *because* the
    service looked at the clock, which makes pass-boundary deadline
    checks land deterministically."""

    def __init__(self, dt):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _rows(result):
    cnt = int(result.count)
    out = np.stack([np.asarray(result.probe_rid)[:cnt].astype(np.int64),
                    np.asarray(result.build_rid)[:cnt].astype(np.int64)],
                   axis=1)
    return out[np.lexsort((out[:, 1], out[:, 0]))]


def _tiny(qid=0, n=1024, seed=1, **kw):
    b = unique_relation(n, seed=seed)
    s = uniform_relation(n, key_range=n, seed=seed + 1)
    return JoinQuery(b, s, query_id=qid, max_out=4 * n + 1024, **kw)


# ---------------------------------------------------------------------------
# Fault injector: seed-deterministic schedules.
# ---------------------------------------------------------------------------
def test_injector_at_every_and_max_faults():
    inj = FaultInjector(seed=3, sites={
        "kernel": FaultSpec(mode="raise", at=(2,), every=5, max_faults=2)})
    fired = []
    for i in range(1, 16):
        try:
            inj.visit("kernel")
        except FaultInjected as e:
            assert e.site == "kernel" and e.nth == i
            assert e.transient          # the ladder only engages on these
            fired.append(i)
    # at=2, every=5 -> {2, 5, 10, 15}, capped at max_faults=2.
    assert fired == [2, 5]
    assert inj.stats() == {"calls": {"kernel": 15}, "fired": {"kernel": 2}}


def test_injector_bernoulli_is_seed_deterministic():
    def run(seed):
        inj = FaultInjector(seed=seed, sites={
            "h2d": FaultSpec(mode="raise", p=0.3)})
        hits = []
        for i in range(1, 41):
            try:
                inj.visit("h2d")
            except FaultInjected:
                hits.append(i)
        return hits

    assert run(11) == run(11)           # same seed, same calls fire
    assert run(11) != run(12)           # and the seed actually matters
    assert 1 <= len(run(11)) <= 39


def test_injected_contextmanager_installs_and_clears():
    from repro.engine.faults import active, maybe_fault
    assert not active()
    maybe_fault("kernel")               # uninstalled: a no-op
    with injected(FaultInjector(seed=0, sites={
            "kernel": FaultSpec(mode="raise", every=1)})):
        assert active()
        with pytest.raises(FaultInjected):
            maybe_fault("kernel")
    assert not active()
    maybe_fault("kernel")               # cleared again


# ---------------------------------------------------------------------------
# QueryContext / token buckets / retry policy / breakers (pure units).
# ---------------------------------------------------------------------------
def test_query_context_deadline_and_cancel_are_structured():
    clk = FakeClock()
    ctx = QueryContext(query_id=7, tenant="t", deadline_at=1.0, clock=clk)
    ctx.check("pass0")                  # t=0 <= 1.0
    clk.t = 1.5
    with pytest.raises(DeadlineExceeded) as ei:
        ctx.check("pass1")
    # Same structured family admission sheds with: callers that treat
    # QueueFull/Backpressure as "not a failure" cover preemption free.
    assert isinstance(ei.value, Backpressure)
    assert isinstance(ei.value, QueueFull)
    assert ei.value.reason == "deadline_exceeded"

    ctx2 = QueryContext(query_id=8, tenant="t", clock=clk)
    ctx2.cancel.set()
    with pytest.raises(Cancelled):
        ctx2.check()
    # note_partial keeps only real progress (0 completed passes is not a
    # checkpoint).
    ctx2.note_partial("R", object(), 0)
    assert ctx2.partials == {}


def test_budget_enforcer_throttle_then_preempt():
    clk = FakeClock()
    adm = AdmissionController([Tenant("t", c_budget=0.5)], num_workers=1)
    enf = BudgetEnforcer(adm, burst_s=1.0, preempt_debt_s=2.0,
                         max_throttle_s=0.05, clock=clk)
    assert enf.check("t") == ("ok", 0.0)
    # Charge 1.5 C-seconds against 1.0s of burst headroom: 0.5s of debt,
    # small enough to throttle (bounded by max_throttle_s).
    enf.on_record({"measured_s": 1.5, "tenant": "t", "scheme": "CPU_ONLY"})
    verdict, amount = enf.check("t")
    assert verdict == "throttle" and amount == pytest.approx(0.05)
    # Pile on past the preemption bound.
    enf.on_record({"measured_s": 3.0, "tenant": "t", "scheme": "CPU_ONLY"})
    verdict, debt = enf.check("t")
    assert verdict == "preempt" and debt >= 2.0
    # Refill at the tenant's budget rate works the debt off: after 10
    # wall seconds at 0.5 dev-s/s the bucket is solvent again.
    clk.t = 10.0
    assert enf.check("t") == ("ok", 0.0)
    # Other tenants are untouched.
    assert enf.check("other") == ("ok", 0.0)


def test_budget_split_schemes_charge_both_groups():
    clk = FakeClock()
    adm = AdmissionController([Tenant("t")], num_workers=1)
    enf = BudgetEnforcer(adm, burst_s=0.1, clock=clk)
    enf.on_record({"measured_s": 1.0, "tenant": "t", "scheme": "DD"})
    levels = enf.summary()
    assert set(levels) == {"t/C", "t/G"}
    assert levels["t/C"]["level"] == pytest.approx(0.1 - 0.5)
    assert levels["t/G"]["level"] == pytest.approx(0.1 - 0.5)


def test_retry_policy_transience_and_backoff_bounds():
    rp = RetryPolicy(max_retries=2, base_backoff_s=0.01, max_backoff_s=0.04,
                     seed=5)
    assert rp.is_transient(FaultInjected("kernel", 1))
    assert not rp.is_transient(ValueError("bad shape"))
    for attempt in (1, 2, 3, 8):
        d = rp.backoff_s(attempt)
        assert 0.0 < d <= 0.04 * 1.5    # jitter in [0.5, 1.5) x base


def test_breaker_full_cycle_with_halfopen_trial():
    clk = FakeClock()
    bb = BreakerBoard(threshold=3, cooldown_s=10.0, clock=clk)
    key = ("shj", "DD")
    assert bb.allow(key) and bb.state_of(key) == CLOSED
    assert not bb.record_failure(key)
    assert not bb.record_failure(key)
    assert bb.record_failure(key)       # third consecutive failure: opens
    assert bb.state_of(key) == OPEN
    assert not bb.allow(key)            # quarantined inside the cooldown
    clk.t = 11.0
    assert bb.allow(key)                # the half-open trial
    assert bb.state_of(key) == HALF_OPEN
    assert not bb.allow(key)            # exactly one trial in flight
    bb.record_failure(key)              # trial failed: re-open
    assert bb.state_of(key) == OPEN
    clk.t = 22.0
    assert bb.allow(key)
    bb.record_success(key)              # trial succeeded: closed, reset
    assert bb.state_of(key) == CLOSED
    assert bb.allow(key)
    assert bb.summary()["shj/DD"] == {"state": "closed", "fails": 0}


def test_breaker_release_frees_a_verdictless_trial():
    clk = FakeClock()
    bb = BreakerBoard(threshold=1, cooldown_s=1.0, clock=clk)
    key = ("phj", "DD")
    bb.record_failure(key)
    clk.t = 2.0
    assert bb.allow(key)                # half-open trial claimed
    bb.release(key)                     # preempted mid-trial: no verdict
    assert bb.allow(key)                # slot free for the next trial
    bb.record_success(key)
    assert bb.state_of(key) == CLOSED


# ---------------------------------------------------------------------------
# Cooperative partitioning: preemptible passes, resumable checkpoints.
# ---------------------------------------------------------------------------
def test_cooperative_partition_matches_fused():
    rel = uniform_relation(4096, seed=3)
    sched = (4, 3)
    fused = radix_partition_scheduled(rel, schedule=sched)
    coop = radix_partition_cooperative(rel, schedule=sched)
    assert np.array_equal(np.asarray(fused.rel.key), np.asarray(coop.rel.key))
    assert np.array_equal(np.asarray(fused.rel.rid), np.asarray(coop.rel.rid))
    assert np.array_equal(np.asarray(fused.part_start),
                          np.asarray(coop.part_start))
    assert np.array_equal(np.asarray(fused.part_count),
                          np.asarray(coop.part_count))


def test_cooperative_resume_from_checkpoint_is_exact():
    """A k-pass partial layout + start_pass=k reproduces the fused result
    exactly — each pass is a stable reorder on its own bit slice."""
    rel = uniform_relation(4096, seed=9)
    sched = (4, 4)
    fused = radix_partition_scheduled(rel, schedule=sched)
    ckpt = partition_pass(rel, shift=0, bits=sched[0])  # pass 0 only
    resumed = radix_partition_cooperative(ckpt, schedule=sched,
                                          start_pass=1)
    assert np.array_equal(np.asarray(fused.rel.key),
                          np.asarray(resumed.rel.key))
    assert np.array_equal(np.asarray(fused.rel.rid),
                          np.asarray(resumed.rel.rid))
    assert np.array_equal(np.asarray(fused.part_start),
                          np.asarray(resumed.part_start))


def test_cooperative_check_sees_every_pass_boundary():
    rel = uniform_relation(1024, seed=2)
    seen = []

    def chk(i):
        seen.append(i)
        if i == 1:
            raise RuntimeError("preempted")

    with pytest.raises(RuntimeError, match="preempted"):
        radix_partition_cooperative(rel, schedule=(3, 3, 2), check=chk)
    assert seen == [0, 1]


def test_schedule_prefixes_longest_first():
    assert schedule_prefixes((4, 3, 2)) == [(4, 3), (4,)]
    assert schedule_prefixes((5,)) == []


# ---------------------------------------------------------------------------
# Service-level preemption, checkpointing and resume.
# ---------------------------------------------------------------------------
class ForcePhjPlanner(QueryPlanner):
    """Planner pinned to a fixed-schedule PHJ plan — the checkpoint tests
    need a deterministic multi-pass partition phase, not a cost-model
    arbitration."""

    def __init__(self, schedule=(4, 4), **kw):
        super().__init__(**kw)
        self._sched = tuple(schedule)

    def choose(self, build_n, probe_n, *, max_out, **kw):
        plan = self._phj_candidate(build_n, probe_n)
        return dataclasses.replace(
            plan, schedule=self._sched,
            shj_bits=default_shj_bits(build_n, sum(self._sched)),
            max_out=int(max_out))


def test_preempt_drops_already_missed_deadline_in_o1(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, preempt=True,
                           clock=StepClock(0.3))
    q = _tiny(qid=1, deadline_s=0.1)    # dead before any kernel runs
    with pytest.raises(DeadlineExceeded):
        svc.execute(q)
    st = svc.stats()
    assert st["resilience"]["preemptions"] == 1
    assert st["failed"] == 0            # a decision, not a failure
    assert st["completed"] == 0


def test_phj_deadline_preemption_checkpoints_then_resumes(cp):
    """The tentpole end-to-end: a deadline blown at a pass boundary
    aborts with the completed pass checkpointed under its schedule-prefix
    key; the re-admitted query resumes at start_pass=1 and produces the
    exact oracle join."""
    # Clock reads in execute(): stamp (0.2) -> pre_execute (0.4) ->
    # R pass0 (0.6) -> R pass1 (0.8 > deadline 0.2+0.5): preempted with
    # exactly one completed pass.
    svc = JoinQueryService(cp=cp, planner=ForcePhjPlanner(schedule=(4, 4)),
                           num_workers=0, preempt=True,
                           clock=StepClock(0.2))
    b = unique_relation(2048, seed=21)
    s = uniform_relation(2048, key_range=2048, seed=22)
    q1 = JoinQuery(b, s, query_id=1, max_out=4 * 2048 + 1024,
                   deadline_s=0.5)
    with pytest.raises(DeadlineExceeded):
        svc.execute(q1)
    st = svc.stats()["resilience"]
    assert st["preemptions"] == 1
    assert st["checkpoints"] == 1       # R's 1-of-2-passes layout stored

    # Re-admitted without a deadline: the full-schedule layout misses,
    # the (4,) prefix checkpoint hits, partitioning resumes at pass 1.
    q2 = JoinQuery(b, s, query_id=2, max_out=4 * 2048 + 1024)
    out = svc.execute(q2)
    st = svc.stats()
    assert st["resilience"]["partition_resumes"] == 1
    assert out.timing.notes.get("R_resumed_at") == 1
    assert st["completed"] == 1 and st["failed"] == 0
    oracle = join_oracle(b, s)
    assert int(out.result.count) == len(oracle)
    assert np.array_equal(_rows(out.result), oracle)


def test_budget_preemption_through_the_service(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, enforce_budgets=True,
                           tenants=[Tenant("meter", c_budget=0.5)],
                           clock=FakeClock())
    # A tenant that already burned far past its budget: the next query
    # is preempted at its first pass boundary (here: pre_execute).
    svc.budget.on_record({"measured_s": 10.0, "tenant": "meter",
                          "scheme": "CPU_ONLY"})
    with pytest.raises(BudgetExceeded) as ei:
        svc.execute(_tiny(qid=3, tenant="meter"))
    assert ei.value.reason == "budget"
    st = svc.stats()
    assert st["resilience"]["preemptions"] == 1
    assert st["failed"] == 0
    # An unmetered tenant sails through on the same service.
    out = svc.execute(_tiny(qid=4, tenant="other"))
    assert int(out.result.count) > 0


# ---------------------------------------------------------------------------
# The recovery ladder: retry -> degrade -> breaker -> reference path.
# ---------------------------------------------------------------------------
def test_ladder_recovers_every_kernel_fault_row_exact(cp):
    q = _tiny(qid=5, n=2048, seed=31)
    oracle = join_variant_oracle(q.build, q.probe, q.kind)
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=1)
    with injected(FaultInjector(seed=7, sites={
            "kernel": FaultSpec(mode="raise", every=1)})):
        out = svc.submit(q)(timeout=120)
    st = svc.stats()
    # Every real-kernel attempt died; the ladder retried, degraded, fed
    # the breaker and landed on the reference path — never a failure.
    assert st["failed"] == 0 and st["completed"] == 1
    assert st["resilience"]["retries"] == svc.retry.max_retries
    assert out.timing.notes.get("reference_path") is True
    assert np.array_equal(_rows(out.result), oracle)
    assert any(b["state"] == "open"
               for b in st["resilience"]["breakers"].values())
    # The quarantined variant now short-circuits straight to the
    # reference path — no faults needed, still row-exact.
    q2 = _tiny(qid=6, n=2048, seed=31)
    out2 = svc.submit(q2)(timeout=120)
    st = svc.stats()
    assert st["resilience"]["breaker_short_circuits"] >= 1
    assert np.array_equal(_rows(out2.result), oracle)
    svc.close()


def test_deterministic_errors_still_fail_fast(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=1)
    bad = _tiny(qid=7)
    bad.build = None                    # not transient: no ladder
    h = svc.submit(bad)
    with pytest.raises(Exception):
        h()
    st = svc.stats()
    assert st["failed"] == 1
    assert st["resilience"]["retries"] == 0
    svc.close()


def test_cache_corruption_detected_and_insert_faults_absorbed(cp):
    b = unique_relation(2048, seed=41)
    s = uniform_relation(2048, key_range=2048, seed=42)
    oracle = join_oracle(b, s)

    def fresh():
        return JoinQueryService(cp=cp, planner=ForcePhjPlanner(),
                                num_workers=0)

    # corrupt-mode cache_insert: the stored layout is flipped, the
    # checksum (taken from the clean relation) exposes it at reuse —
    # a cache miss, never a wrong join.
    svc = fresh()
    with injected(FaultInjector(seed=1, sites={
            "cache_insert": FaultSpec(mode="corrupt", every=1)})):
        svc.execute(JoinQuery(b, s, query_id=1, max_out=4 * 2048 + 1024))
        out = svc.execute(JoinQuery(b, s, query_id=2,
                                    max_out=4 * 2048 + 1024))
    st = svc.stats()
    assert st["resilience"]["cache_validation_failures"] >= 2  # R and S
    assert not out.partition_cache_hit
    assert np.array_equal(_rows(out.result), oracle)

    # raise-mode cache_insert: the insert is skipped; the query that
    # computed the layout still completes.
    svc = fresh()
    with injected(FaultInjector(seed=2, sites={
            "cache_insert": FaultSpec(mode="raise", at=(1,))})):
        out = svc.execute(JoinQuery(b, s, query_id=3,
                                    max_out=4 * 2048 + 1024))
    st = svc.stats()
    assert st["resilience"]["cache_insert_failures"] == 1
    assert st["failed"] == 0
    assert np.array_equal(_rows(out.result), oracle)


# ---------------------------------------------------------------------------
# Worker hygiene and drain-close.
# ---------------------------------------------------------------------------
def test_worker_restart_preserves_capacity(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=1)
    with injected(FaultInjector(seed=0, sites={
            "worker": FaultSpec(mode="raise", at=(1,))})):
        out = svc.submit(_tiny(qid=8))(timeout=120)
    st = svc.stats()
    assert st["resilience"]["worker_restarts"] >= 1
    assert st["completed"] == 1 and st["failed"] == 0
    assert int(out.result.count) > 0
    svc.close()


def test_close_drains_then_rejects_submits(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=1)
    waits = [svc.submit(_tiny(qid=i, seed=51)) for i in range(3)]
    svc.close(drain=True)
    # Drained: every admitted query completed before the workers stopped.
    assert all(int(w(timeout=1).result.count) >= 0 for w in waits)
    st = svc.stats()
    assert st["completed"] == 3
    assert st["resilience"]["cancelled_on_close"] == 0
    # Submit-after-close: structured rejection, counted.
    with pytest.raises(Backpressure) as ei:
        svc.submit(_tiny(qid=99))
    assert ei.value.reason == "service_closing"
    assert svc.stats()["rejected"] == 1


def test_close_cancels_undrainable_queue_structured(cp):
    # No workers: queued items can never be served — close() must cancel
    # them with structured Backpressure, not leave waiters hanging.
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    w = svc.submit(_tiny(qid=9))
    svc.close()
    with pytest.raises(Backpressure) as ei:
        w(timeout=1)
    assert ei.value.reason == "service_closing"
    assert svc.stats()["resilience"]["cancelled_on_close"] == 1
    assert len(svc._queue) == 0


def test_resilience_counters_present_and_zero_by_default(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    svc.execute(_tiny(qid=10))
    res = svc.stats()["resilience"]
    for name in ("preemptions", "budget_throttles", "retries",
                 "worker_restarts", "checkpoints", "partition_resumes",
                 "breaker_short_circuits", "cancelled_on_close"):
        assert res[name] == 0
    assert res["breakers"] == {}


# ---------------------------------------------------------------------------
# Chaos soak: seeded open-loop traffic under seeded faults.
# ---------------------------------------------------------------------------
def test_chaos_soak_structured_failures_and_row_exact_results(cp):
    events = open_loop(
        12, rate_qps=500.0, mix="mixed", arrivals="poisson",
        tenant_mix=(("gold", 2.0), ("bronze", 1.0)),
        deadlines={"gold": 30.0}, base_tuples=512, seed=11)
    inj = FaultInjector(seed=5, sites={
        "kernel": FaultSpec(mode="raise", p=0.05, max_faults=4),
        "h2d": FaultSpec(mode="delay", p=0.2, delay_s=0.001),
    })
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2, preempt=True)
    unstructured = []
    results = []
    with injected(inj):
        waits = []
        for ev in events:
            try:
                waits.append((ev.query, svc.submit(ev.query)))
            except Backpressure:
                pass                    # structured shed: fine
        for q, w in waits:
            try:
                results.append((q, w(timeout=180)))
            except QueueFull:
                pass                    # structured preemption: fine
            except Exception as e:      # anything else breaks the soak
                unstructured.append(e)
        svc.close(drain=True)
    assert unstructured == []
    st = svc.stats()
    assert st["failed"] == 0            # injected faults all recovered
    assert inj.stats()["fired"].get("kernel", 0) >= 1  # soak saw faults
    # No hung workers, nothing stranded in the queue.
    assert svc._workers == [] and len(svc._queue) == 0
    assert results, "soak must complete some queries"
    # Every success is row-exact against the NumPy oracle — retried,
    # degraded or reference-path executions included.
    for q, out in results:
        oracle = join_variant_oracle(q.build, q.probe, q.kind)
        assert np.array_equal(_rows(out.result), oracle)
    # Breakers are either closed or opened *with* their state on record.
    for b in st["resilience"]["breakers"].values():
        assert b["state"] in ("closed", "open", "half_open")
