"""Radix-partition invariants (paper §3.1, Algorithm 2).

Property-style tests over a seeded input grid (deliberately hypothesis-free
so they execute even on minimal environments where the hypothesis-based
modules skip):
  (a) each pass is a STABLE permutation of its input;
  (b) histogram counts sum to n and match np.bincount;
  (c) composing the planned passes clusters identically to one
      full-``total_bits`` pass (multi-pass == single-pass radix sort).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Relation, radix_partition_scheduled,
                        radix_partition_unfused, uniform_relation)
from repro.core.relation import radix_of
from repro.kernels.partition_hist.ops import fused_partition_pass

CASES = [(1024, 3, 0, 0), (1024, 5, 2, 1), (4096, 4, 0, 2),
         (4096, 6, 4, 3), (3000, 4, 0, 4), (8192, 2, 7, 5)]


def _rel(n, seed):
    return uniform_relation(n, key_range=max(64, n // 2), seed=seed)


@pytest.mark.parametrize("n,bits,shift,seed", CASES)
def test_pass_is_stable_permutation(n, bits, shift, seed):
    rel = _rel(n, seed)
    out, starts, counts = fused_partition_pass(rel, shift=shift, bits=bits)
    in_pairs = np.stack([np.asarray(rel.rid), np.asarray(rel.key)], 1)
    out_pairs = np.stack([np.asarray(out.rid), np.asarray(out.key)], 1)
    # permutation: same multiset of tuples
    order_in = np.lexsort(in_pairs.T)
    order_out = np.lexsort(out_pairs.T)
    assert (in_pairs[order_in] == out_pairs[order_out]).all()
    # stability: within each partition, rids keep input order (rid == input
    # position for uniform_relation)
    pid_out = np.asarray(radix_of(out.key, shift=shift, bits=bits))
    for p in np.unique(pid_out):
        rids = np.asarray(out.rid)[pid_out == p]
        assert (np.diff(rids) > 0).all(), f"pass not stable in part {p}"
    # clustered: pid non-decreasing, consistent with starts
    assert (np.diff(pid_out) >= 0).all()
    st = np.asarray(starts)
    ct = np.asarray(counts)
    assert (st == np.cumsum(ct) - ct).all()


@pytest.mark.parametrize("n,bits,shift,seed", CASES)
def test_histogram_matches_bincount(n, bits, shift, seed):
    rel = _rel(n, seed)
    _, _, counts = fused_partition_pass(rel, shift=shift, bits=bits)
    pid = np.asarray(radix_of(rel.key, shift=shift, bits=bits))
    ct = np.asarray(counts)
    assert ct.sum() == n
    assert (ct == np.bincount(pid, minlength=1 << bits)).all()


@pytest.mark.parametrize("schedule", [(2, 2, 2), (3, 3), (1, 2, 3), (6,),
                                      (4, 2)])
@pytest.mark.parametrize("n,seed", [(2048, 0), (4096, 3)])
def test_multipass_equals_single_full_pass(schedule, n, seed):
    """LSD composition: passes of b_i bits == one sum(b_i)-bit pass."""
    rel = _rel(n, seed)
    total = sum(schedule)
    multi = radix_partition_scheduled(rel, schedule=schedule)
    single = radix_partition_scheduled(rel, schedule=(total,))
    assert (np.asarray(multi.rel.rid) == np.asarray(single.rel.rid)).all()
    assert (np.asarray(multi.rel.key) == np.asarray(single.rel.key)).all()
    assert (np.asarray(multi.part_start) == np.asarray(single.part_start)).all()
    assert (np.asarray(multi.part_count) == np.asarray(single.part_count)).all()


@pytest.mark.parametrize("bits,passes", [(3, 2), (2, 3), (4, 1)])
def test_fused_path_matches_seed_unfused_path(bits, passes):
    """The rewritten fused pipeline is bit-identical to the seed's
    materialized 3-step path."""
    rel = _rel(4096, seed=9)
    fused = radix_partition_scheduled(rel, schedule=(bits,) * passes)
    unfused = radix_partition_unfused(rel, bits_per_pass=bits,
                                      num_passes=passes)
    for a, b in ((fused.rel.rid, unfused.rel.rid),
                 (fused.rel.key, unfused.rel.key),
                 (fused.part_start, unfused.part_start),
                 (fused.part_count, unfused.part_count)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_negative_sentinel_keys_partition_cleanly():
    """Pad sentinels (-2/-3) flow through the fused pass like any key."""
    rid = jnp.arange(1024, dtype=jnp.int32)
    key = jnp.where(jnp.arange(1024) % 7 == 0, jnp.int32(-2),
                    jnp.arange(1024, dtype=jnp.int32))
    out, _, counts = fused_partition_pass(Relation(rid, key), shift=0,
                                          bits=4)
    assert int(np.asarray(counts).sum()) == 1024
    assert set(np.asarray(out.rid).tolist()) == set(range(1024))
