"""Layer-level unit tests: MoE dispatch equivalence, SSD chunk invariance,
sharding rule engine, compression, data pipeline determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import MoECfg, SSMCfg, all_configs, reduced


def _moe_cfg(impl, top_k=2, experts=8):
    cfg = reduced(all_configs()["granite_moe_3b"])
    return dataclasses.replace(
        cfg, moe_impl=impl,
        moe=MoECfg(num_experts=experts, top_k=top_k, d_ff=32,
                   capacity_factor=4.0, group_size=1 << 20))


def test_moe_dense_equals_sorted():
    """The GSPMD one-hot dispatch and the paper's radix-partition dispatch
    compute the same function (capacity high enough that neither drops)."""
    from repro.layers.moe import moe_specs, moe_dense, moe_sorted
    from repro.models.params import materialize
    cfg = _moe_cfg("dense")
    params = materialize(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y1, a1 = moe_dense(params, cfg, x)
    y2, a2 = moe_sorted(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_capacity_drops_consistently():
    from repro.layers.moe import moe_specs, moe_dense, moe_sorted
    from repro.models.params import materialize
    cfg = _moe_cfg("dense")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    params = materialize(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y1, _ = moe_dense(params, cfg, x)
    y2, _ = moe_sorted(params, cfg, x)
    # Same priority order (token-major within slot) => identical drops.
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([32, 64, 96, 128]),
       chunk=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 1000))
def test_ssd_chunk_invariance(l, chunk, seed):
    """SSD output must not depend on the chunk length (duality check)."""
    from repro.layers.ssd import ssd_chunked
    rng = np.random.default_rng(seed)
    b, h, p, n = 1, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, l, h)), jnp.float32)
    a = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.2), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    y1, h1 = ssd_chunked(x, dt, a, bb, cc, chunk)
    y2, h2 = ssd_chunked(x, dt, a, bb, cc, l)   # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-3)


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD == step-by-step recurrent decode (state-space duality)."""
    from repro.layers.ssd import ssd_chunked, ssd_decode_step
    rng = np.random.default_rng(3)
    b, l, h, p, n = 2, 24, 2, 4, 8
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, l, h)), jnp.float32)
    a = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.2), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    y_chunk, h_final = ssd_chunked(x, dt, a, bb, cc, 8)
    hs = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        y, hs = ssd_decode_step(x[:, t], dt[:, t], a, bb[:, t], cc[:, t], hs)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(hs),
                               atol=2e-3)


def test_sharding_rule_engine():
    from repro.distributed.sharding import TRAIN_RULES, axes_to_spec
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    # engine falls back to None when sizes don't divide
    spec = axes_to_spec(("batch", "heads"), (3, 5), TRAIN_RULES, mesh)
    assert spec == jax.sharding.PartitionSpec(None, None) or all(
        s is None or True for s in spec)

    import numpy as _np
    devs = _np.array(jax.devices()[:1]).reshape(1, 1)
    mesh16 = jax.sharding.Mesh(devs, ("data", "model"))
    # divisibility honored: heads=40 on a 16-wide model axis -> replicated
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = axes_to_spec(("batch", "heads", "head_dim"), (256, 40, 128),
                        TRAIN_RULES, FakeMesh())
    assert spec[1] is None                      # 40 % 16 != 0
    spec = axes_to_spec(("batch", "heads", "head_dim"), (256, 64, 128),
                        TRAIN_RULES, FakeMesh())
    assert spec[1] == "model"
    # one mesh axis never used twice in a tensor
    spec = axes_to_spec(("vocab", "mlp"), (160, 160), TRAIN_RULES,
                        FakeMesh())
    assert not (spec[0] == "model" and spec[1] == "model")


def test_grad_compression_roundtrip(rng):
    from repro.train.compress import ef_int8_allreduce_sim
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)) * 0.01,
                          jnp.float32)}
    d = ef_int8_allreduce_sim(g)
    err = np.abs(np.asarray(d["a"]) - np.asarray(g["a"])).max()
    assert err <= float(jnp.abs(g["a"]).max()) / 127 + 1e-8


def test_data_pipeline_deterministic_and_host_sharded():
    from repro.data.pipeline import SyntheticLM
    ds = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=8)
    a = ds.batch(3, host_index=0, host_count=2)
    b = ds.batch(3, host_index=0, host_count=2)
    assert (a["tokens"] == b["tokens"]).all()
    c = ds.batch(3, host_index=1, host_count=2)
    assert a["tokens"].shape == (4, 64)
    assert not (a["tokens"] == c["tokens"]).all()
    # labels are next-token shifted
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
