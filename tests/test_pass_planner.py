"""PassPlanner: the cost-model-guided choice of radix pass knobs."""
import itertools

import numpy as np
import pytest

from repro.core import (PassPlan, PassPlanner, default_planner,
                        even_schedule)
from repro.core.calibrate import APU_CPU, APU_GPU
from repro.core.phj import resolve_schedule


def test_even_schedule_partitions_bits():
    for total, p in itertools.product(range(1, 17), range(1, 9)):
        if p > total:
            continue
        s = even_schedule(total, p)
        assert len(s) == p and sum(s) == total
        assert max(s) - min(s) <= 1  # near-equal widths


@pytest.mark.parametrize("spec", [APU_CPU, APU_GPU])
def test_plan_minimizes_modeled_cost_on_grid(spec):
    """The chosen schedule attains the minimum of the planner's own model
    over the full calibration grid of pass counts."""
    planner = PassPlanner.from_device_spec(spec)
    for n, total_bits in [(1 << 14, 6), (1 << 20, 12), (1 << 22, 16)]:
        plan = planner.plan(n, total_bits=total_bits)
        grid = {p: planner.schedule_cost(n, even_schedule(total_bits, p))
                for p in range(1, total_bits + 1)}
        assert plan.est_s == pytest.approx(min(grid.values()))
        assert plan.total_bits == total_bits


def test_flat_hierarchy_prefers_one_wide_pass():
    """No scatter penalty -> every extra pass is pure overhead."""
    p = PassPlanner(1e-9, 1e-9, 3e-9, capacity_bits=32)
    assert p.plan(1 << 20, total_bits=12).schedule == (12,)


def test_steep_hierarchy_prefers_narrow_passes():
    """A scatter knee far below the fanout forces the multi-pass regime
    (the paper's 'tuned according to the memory hierarchy')."""
    p = PassPlanner(1e-9, 1e-9, 5e-9, capacity_bits=4, fanout_penalty=2.0)
    plan = p.plan(1 << 20, total_bits=16)
    assert plan.num_passes > 1
    assert plan.bits_per_pass <= 6


def test_choose_total_bits_tracks_relation_size():
    p = default_planner()
    bits = [p.choose_total_bits(n) for n in (1 << 12, 1 << 16, 1 << 20,
                                             1 << 24)]
    assert bits == sorted(bits)          # monotone in n
    assert all(1 <= b <= 16 for b in bits)
    # target partition size respected within a factor of two
    b20 = p.choose_total_bits(1 << 20)
    assert (1 << 20) / (1 << b20) == pytest.approx(p.part_tuples, rel=1.0)


def test_pass_model_prices_ratio_sweep():
    """The planner's per-pass SeriesCostModel supports the schemes'
    optimizers (extends, not forks, the paper's model)."""
    planner = PassPlanner.from_device_spec(APU_CPU)
    m = planner.pass_model(1 << 18, 6, device_g=APU_GPU)
    r, t = m.optimize_dd(delta=0.1)
    assert 0.0 <= r <= 1.0
    assert t <= m.estimate_batch(np.ones((1, 3)))[0] + 1e-12
    assert t <= m.estimate_batch(np.zeros((1, 3)))[0] + 1e-12


def test_resolve_schedule_priorities():
    assert resolve_schedule(4096, schedule=(2, 3)) == (2, 3)
    assert resolve_schedule(4096, bits_per_pass=4, num_passes=2) == (4, 4)
    planned = resolve_schedule(1 << 20)
    assert sum(planned) >= 1 and len(planned) >= 1


def test_plan_properties():
    plan = PassPlan((3, 3, 2), 1.0)
    assert plan.total_bits == 8
    assert plan.num_passes == 3
    assert plan.bits_per_pass == 3
