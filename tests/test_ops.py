"""Co-processed relational operators: group-by aggregation + join variants.

Kernel-vs-ref parity (interpret + compiled jnp path), operator-vs-NumPy-
oracle checks across the edge cases (empty groups, all-unmatched probes,
duplicate keys), planner pricing of the new operators, and declarative
``group_by`` / join-``kind`` queries verified row/value-exact against
``reference_execute`` — hypothesis-driven where available, a deterministic
sweep otherwise (test_queries.py conventions).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CoProcessor, join_oracle, uniform_relation,
                        unique_relation)
from repro.core.hash_table import build_hash_table, default_num_buckets
from repro.core.relation import Relation, probe_with_selectivity
from repro.engine import (GroupByQuery, JoinQuery, JoinQueryService,
                          QueryPlanner)
from repro.ops import (groupby_ref, join_variant_oracle,
                       probe_hash_table_variant, probe_table_variant)
from repro.ops.groupby import grouped_agg
from repro.queries import (Filter, Join, JoinOrderOptimizer,
                           PipelineExecutor, Query, Table, make_star_query,
                           reference_execute)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


@pytest.fixture(scope="module")
def planner():
    return QueryPlanner(delta=0.25)


def run_pipeline(query, physical=None, optimizer=None, num_workers=2):
    svc = JoinQueryService(planner=QueryPlanner(delta=0.25),
                           num_workers=num_workers)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        return ex.run(query, physical), svc


# ---------------------------------------------------------------------------
# Segmented-aggregation kernel: interpret-mode Pallas vs jnp oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,slots", [(1024, 16), (2048, 128), (8192, 1024)])
def test_seg_agg_kernel(n, slots, rng):
    from repro.kernels.agg.agg import seg_agg_pallas
    from repro.kernels.agg.ref import seg_agg_ref
    gid = jnp.asarray(rng.integers(-1, slots, n).astype(np.int32))
    val = jnp.asarray(rng.integers(-1000, 1000, n).astype(np.int32))
    got = seg_agg_pallas(gid, val, num_slots=slots, interpret=True)
    exp = seg_agg_ref(gid, val, num_slots=slots)
    for g, e, name in zip(got, exp, ("count", "sum", "min", "max")):
        assert (np.asarray(g) == np.asarray(e)).all(), name


def test_seg_agg_kernel_empty_slots(rng):
    """Slots no tuple maps to report the neutral elements."""
    from repro.kernels.agg.agg import INT32_MAX, INT32_MIN, seg_agg_pallas
    gid = jnp.asarray(np.zeros(1024, np.int32))          # everything slot 0
    val = jnp.asarray(rng.integers(0, 9, 1024).astype(np.int32))
    cnt, sm, mn, mx = seg_agg_pallas(gid, val, num_slots=8, interpret=True,
                                     wrap32=True)
    assert int(cnt[0]) == 1024 and (np.asarray(cnt[1:]) == 0).all()
    assert (np.asarray(mn[1:]) == INT32_MAX).all()
    assert (np.asarray(mx[1:]) == INT32_MIN).all()
    assert int(sm[0]) == int(np.asarray(val).sum())


def test_seg_agg_kernel_wide_sums(rng):
    """The default wide path is int64-exact where int32 would wrap."""
    from repro.kernels.agg.agg import seg_agg_pallas, wide_sums_to_int64
    gid = jnp.asarray((np.arange(2048) % 4).astype(np.int32))
    base = rng.integers(-2**31, 2**31, 2048).astype(np.int32)
    val = jnp.asarray(base)
    cnt, sm, mn, mx = seg_agg_pallas(gid, val, num_slots=8, interpret=True)
    assert sm.shape == (5, 8)
    got = wide_sums_to_int64(np.asarray(sm))
    exp = np.zeros(8, np.int64)
    np.add.at(exp, np.arange(2048) % 4, base.astype(np.int64))
    assert (got == exp).all()
    # ... and the wrap32 channel reproduces the old modular accumulator.
    _, sm32, _, _ = seg_agg_pallas(gid, val, num_slots=8, interpret=True,
                                   wrap32=True)
    wrapped = (got & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
    assert (np.asarray(sm32) == wrapped).all()


def _check_groupby(result, keys, values):
    ref = groupby_ref(keys, values)
    s = result.sorted()
    assert s.num_groups == ref.num_groups
    for a, b in ((s.keys, ref.keys), (s.counts, ref.counts),
                 (s.sums, ref.sums), (s.mins, ref.mins), (s.maxs, ref.maxs)):
        assert (a == b).all()


@pytest.mark.parametrize("n,krange", [(1024, 8), (4096, 256), (4096, 4096)])
def test_grouped_agg_matches_oracle(n, krange, rng):
    keys = rng.integers(0, krange, n).astype(np.int32)
    vals = rng.integers(-100, 100, n).astype(np.int32)
    rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
    uk, cnt, sm, mn, mx, ng = grouped_agg(rel, jnp.asarray(vals),
                                          num_slots=n)
    from repro.kernels.agg import wide_sums_to_int64
    ref = groupby_ref(keys, vals)
    ng = int(ng)
    assert ng == ref.num_groups
    o = np.argsort(np.asarray(uk[:ng]))
    assert (np.asarray(uk[:ng])[o] == ref.keys).all()
    assert (np.asarray(cnt[:ng])[o] == ref.counts).all()
    assert (wide_sums_to_int64(np.asarray(sm))[:ng][o] == ref.sums).all()
    assert (np.asarray(mn[:ng])[o] == ref.mins).all()
    assert (np.asarray(mx[:ng])[o] == ref.maxs).all()


# ---------------------------------------------------------------------------
# Co-processed group-by operator.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule,pr,ar", [((3, 2), 0.5, 0.5),
                                            ((4,), 1.0, 0.25),
                                            (None, 1.0, 1.0),
                                            (None, 0.0, 0.0)])
def test_coprocessed_groupby(cp, schedule, pr, ar, rng):
    n = 4096
    keys = rng.integers(0, 64, n).astype(np.int32)
    vals = rng.integers(0, 100, n).astype(np.int32)
    rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
    res, timing = cp.groupby(rel, vals, schedule=schedule,
                             partition_ratio=pr, agg_ratio=ar)
    _check_groupby(res, keys, vals)
    assert "agg" in timing.phase_s
    if schedule:
        assert timing.phase_s["partition"] > 0


def test_groupby_edge_cases(cp):
    # Empty input -> zero groups.
    empty = Relation(jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    res, _ = cp.groupby(empty, np.zeros(0, np.int32))
    assert res.num_groups == 0 and res.sorted().keys.shape == (0,)
    # One duplicate key -> one group carrying everything.
    n = 1024
    keys = np.full(n, 7, np.int32)
    vals = np.arange(n, dtype=np.int32)
    rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
    res, _ = cp.groupby(rel, vals, schedule=(2,), partition_ratio=0.5,
                        agg_ratio=0.5)
    assert res.num_groups == 1 and int(res.counts[0]) == n
    assert int(res.mins[0]) == 0 and int(res.maxs[0]) == n - 1
    _check_groupby(res, keys, vals)


def test_groupby_sum_width_modes(cp):
    # Values that overflow int32 by a wide margin: the default wide path
    # must be int64-exact, and wrap32=True must reproduce the legacy
    # modular accumulator exactly (oracle parity in both modes).
    n = 1024
    keys = np.zeros(n, np.int32)
    vals = np.full(n, 2**30, np.int32)       # overflows far past int32
    rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
    res, _ = cp.groupby(rel, vals)
    assert res.sums.dtype == np.int64
    assert int(res.sums[0]) == n * 2**30     # no silent wrap
    _check_groupby(res, keys, vals)
    res32, _ = cp.groupby(rel, vals, wrap32=True)
    assert res32.sums.dtype == np.int32
    ref32 = groupby_ref(keys, vals, wrap32=True)
    assert (res32.sorted().sums == ref32.sums).all()
    # The separate-partials DD merge keeps wide sums exact too.
    res_dd, _ = cp.groupby(rel, vals, agg_ratio=0.5)
    assert int(res_dd.sorted().sums[0]) == n * 2**30


# ---------------------------------------------------------------------------
# Join variants: kernel + co-processed probe vs oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["semi", "anti", "left_outer"])
@pytest.mark.parametrize("sel", [0.0, 0.5, 1.0])
def test_probe_variant_matches_oracle(cp, kind, sel):
    b = unique_relation(512, seed=41)
    p = probe_with_selectivity(b, 1024, selectivity=sel, seed=42)
    table = build_hash_table(b, default_num_buckets(512))
    exp = join_variant_oracle(b, p, kind)
    got = probe_hash_table_variant(p, table, 4096, kind).valid_pairs()
    assert got.shape == exp.shape and (got == exp).all()
    res, _ = probe_table_variant(cp, p, table, kind=kind, max_out=4096,
                                 ratios=(0.5,) * 4)
    gotc = res.valid_pairs()
    assert gotc.shape == exp.shape and (gotc == exp).all()


def test_probe_variant_duplicate_keys(cp):
    # Duplicate build keys: semi must not multiply rows, outer must.
    b = uniform_relation(512, key_range=64, seed=5)      # heavy duplicates
    p = uniform_relation(512, key_range=128, seed=6)
    table = build_hash_table(b, default_num_buckets(512))
    for kind in ("semi", "anti", "left_outer"):
        exp = join_variant_oracle(b, p, kind)
        got = probe_hash_table_variant(p, table, 16384, kind).valid_pairs()
        assert got.shape == exp.shape and (got == exp).all(), kind
    n_semi = join_variant_oracle(b, p, "semi").shape[0]
    n_anti = join_variant_oracle(b, p, "anti").shape[0]
    assert n_semi + n_anti == 512
    assert join_variant_oracle(b, p, "left_outer").shape[0] >= 512


# ---------------------------------------------------------------------------
# Planner: variant + group-by pricing.
# ---------------------------------------------------------------------------

def test_planner_semi_probe_cheaper_than_inner(planner):
    inner = planner.choose(65536, 65536, max_out=65536)
    semi = planner.choose(65536, 65536, max_out=65536, kind="semi")
    assert semi.kind == "semi" and semi.algorithm == "shj"
    # No p4 payload gather: the semi probe estimate must be cheaper.
    assert semi.est_probe_s < inner.est_probe_s


def test_planner_variant_never_phj(planner):
    big = planner.choose(1 << 24, 1 << 24, max_out=1024, kind="anti")
    assert big.algorithm == "shj"            # phj has no variant emission


def test_planner_groupby_schemes(planner):
    small = planner.choose_groupby(4096)
    assert small.algorithm == "groupby"
    assert small.scheme in ("CPU_ONLY", "GPU_ONLY", "DD")
    big = planner.choose_groupby(1 << 24)
    assert big.scheme == "DD" and big.schedule is not None
    assert big.est_s > 0 and sum(big.schedule) > 0


def test_planner_groupby_feedback():
    from repro.core import Timing
    pl = QueryPlanner(delta=0.25)
    plan = pl.choose_groupby(8192)
    before = plan.est_s
    t = Timing()
    t.phase_s = {"partition": 100.0 * max(plan.est_build_s, 1e-3),
                 "agg": 100.0 * max(plan.est_probe_s, 1e-3)}
    pl.observe(plan, t)
    after = pl.choose_groupby(8192).est_s
    assert after > before                    # scales moved the estimate


# ---------------------------------------------------------------------------
# Service: group-by queries + variant joins through the engine.
# ---------------------------------------------------------------------------

def test_service_groupby_query(cp, rng):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    n = 4096
    keys = rng.integers(0, 128, n).astype(np.int32)
    vals = rng.integers(0, 100, n).astype(np.int32)
    rel = Relation(jnp.arange(n, dtype=jnp.int32), jnp.asarray(keys))
    out = svc.execute(GroupByQuery(keys=rel, values=vals, query_id=1))
    assert out.plan.algorithm == "groupby"
    _check_groupby(out.result, keys, vals)
    d = out.to_dict()
    assert d["algorithm"] == "groupby" and d["matches"] == \
        out.result.num_groups


def test_service_variant_join_uses_table_cache(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    b = unique_relation(2048, seed=3)
    p = uniform_relation(4096, key_range=4096, seed=4)
    exp = join_variant_oracle(b, p, "semi")
    o1 = svc.execute(JoinQuery(build=b, probe=p, kind="semi",
                               max_out=8192, query_id=1))
    # Inner query against the same build side: the variant's table is
    # reusable (and vice versa) — same fingerprint, same CSR table.
    o2 = svc.execute(JoinQuery(build=b, probe=p, kind="inner",
                               max_out=16384, query_id=2))
    o3 = svc.execute(JoinQuery(build=b, probe=p, kind="semi",
                               max_out=8192, query_id=3))
    assert (o1.result.valid_pairs() == exp).all()
    assert (o3.result.valid_pairs() == exp).all()
    assert (o2.result.valid_pairs() == join_oracle(b, p)).all()
    assert o2.cache_hit and o3.cache_hit
    assert o1.plan.kind == "semi" and o1.to_dict()["kind"] == "semi"


def test_service_probe_partition_reuse(cp):
    # PHJ-forced planner: both sides' partition layouts are cached, so a
    # replayed (build, probe) pair skips every n1–n3 pass.
    pl = QueryPlanner(delta=0.25, cache_bytes=1 << 10, rand_penalty=8.0,
                      phj_overhead_s=0.0)
    assert pl.choose(4096, 4096, max_out=8192).algorithm == "phj"
    svc = JoinQueryService(cp=cp, planner=pl, num_workers=0)
    b = uniform_relation(4096, seed=3)
    s = uniform_relation(4096, key_range=4096, seed=4)
    exp = join_oracle(b, s)
    outs = [svc.execute(JoinQuery(build=b, probe=s, query_id=i,
                                  max_out=4 * 4096 + 1024))
            for i in range(2)]
    assert outs[0].plan.algorithm == "phj"
    assert not outs[0].probe_partition_cache_hit
    assert outs[1].probe_partition_cache_hit
    assert outs[1].partition_cache_hit
    assert outs[1].timing.notes.get("probe_parts_reused")
    for o in outs:
        assert (o.result.valid_pairs() == exp).all()
    st = svc.cache.stats()
    assert st["probe_partition_hits"] == 1
    assert st["probe_partition_misses"] == 1
    assert st["probe_partition_puts"] == 1


# ---------------------------------------------------------------------------
# Declarative layer: group_by + join kinds end-to-end vs the reference.
# ---------------------------------------------------------------------------

def test_query_groupby_validation():
    t = Table("t", {"id": np.arange(8)})
    with pytest.raises(ValueError, match="group_by"):
        Query(tables={"t": t}, joins=(), group_by=("t.nope",))
    with pytest.raises(ValueError, match="unknown aggregate"):
        Query(tables={"t": t}, joins=(), aggregate=("median", "t.id"))
    with pytest.raises(ValueError, match="avg over unknown column"):
        Query(tables={"t": t}, joins=(), aggregate=("avg", "t.nope"))
    # Semi filter tables are consumed: no reuse in other edges/group-by.
    u = Table("u", {"id": np.arange(8), "a": np.arange(8)})
    with pytest.raises(ValueError, match="no other join edge"):
        Query(tables={"t": t, "u": u},
              joins=(Join("t", "id", "u", "id", kind="semi"),
                     Join("t", "id", "u", "a")))
    with pytest.raises(ValueError, match="consumed"):
        Query(tables={"t": t, "u": u},
              joins=(Join("t", "id", "u", "id", kind="semi"),),
              group_by=("u.a",))
    with pytest.raises(ValueError, match="must be inner"):
        Query(tables={"t": t}, joins=(Join("t", "id", "t", "id",
                                           kind="semi"),))


def _run_vs_reference(q, optimizer=None, num_workers=2):
    ref_rows, ref_agg = reference_execute(q)
    res, _ = run_pipeline(q, optimizer=optimizer, num_workers=num_workers)
    assert res.aggregate == ref_agg
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()
    return res


def test_semi_anti_star_matches_reference():
    q = make_star_query(2048, [256, 128], selectivities=[0.5, 0.4], seed=5,
                        join_kinds=["semi", "anti"], aggregate=("count",))
    res = _run_vs_reference(q)
    assert res.aggregate > 0                 # non-degenerate


def test_left_outer_matches_reference():
    q = make_star_query(1024, [64, 128], selectivities=[0.05, None], seed=7,
                        join_kinds=["left_outer", "inner"])
    res = _run_vs_reference(q)
    assert res.rows >= 1024                  # every fact row preserved


def test_left_outer_empty_build_side():
    # The preserved side survives even when the filter empties the right
    # table: every probe row emits once, all build columns NULL.
    q = make_star_query(256, [64], seed=8, join_kinds=["left_outer"])
    q.tables["D0"] = q.tables["D0"].with_filters(Filter("a", 2000, 2001))
    ref_rows, ref_agg = reference_execute(q)
    res, _ = run_pipeline(q)
    assert res.rows == 256 == ref_agg == res.aggregate
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()


def test_join_on_null_padded_column_rejected():
    # A later join keyed on an outer join's NULL-padded columns would put
    # NULL_VALUE keys in front of the executor — rejected at construction.
    a = Table("a", {"id": np.arange(8, dtype=np.int32),
                    "b": np.arange(8, dtype=np.int32)})
    b = Table("b", {"id": np.arange(8, dtype=np.int32)})
    f = Table("f", {"k": np.arange(16, dtype=np.int32) % 8})
    with pytest.raises(ValueError, match="nullable"):
        Query(tables={"f": f, "a": a, "b": b},
              joins=(Join("f", "k", "a", "id", kind="left_outer"),
                     Join("a", "b", "b", "id")))
    # ...but an edge BEFORE the outer join sees the table pre-padding.
    Query(tables={"f": f, "a": a, "b": b},
          joins=(Join("a", "b", "b", "id"),
                 Join("f", "k", "a", "id", kind="left_outer")))


def test_left_outer_is_not_reordered(planner):
    opt = JoinOrderOptimizer(planner)
    q = make_star_query(512, [64, 64], seed=9,
                        join_kinds=["left_outer", "inner"])
    assert opt.enumerate_orders(q) == [q.joins]
    assert opt.optimize(q).order == q.joins


def test_groupby_query_through_service():
    q = make_star_query(4096, [256, 128], selectivities=[0.2, None],
                        seed=11, join_kinds=["inner", "semi"],
                        group_by=("F.g",), aggregate=("sum", "F.m"))
    res, svc = run_pipeline(q)
    ref_rows, _ = reference_execute(q)
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()
    # The sink ran through the service as its own engine query.
    assert len(res.outcomes) == len(q.joins) + 1
    assert res.outcomes[-1].plan.algorithm == "groupby"
    assert svc.stats()["completed"] == len(q.joins) + 1


@pytest.mark.parametrize("agg", [("count",), ("min", "F.m"),
                                 ("avg", "F.m")])
def test_groupby_aggregates_match_reference(agg):
    q = make_star_query(1024, [128], seed=13, group_by=("F.g",),
                        aggregate=agg)
    _run_vs_reference(q, num_workers=0)


def test_multi_column_groupby_matches_reference():
    q = make_star_query(2048, [64], seed=15, group_by=("F.g", "D0.a"),
                        aggregate=("avg", "F.m"))
    _run_vs_reference(q)


def test_empty_groupby_pipeline():
    q = make_star_query(512, [64, 64], seed=17, group_by=("F.g",))
    q.tables["D0"] = q.tables["D0"].with_filters(Filter("a", 2000, 2001))
    ref_rows, _ = reference_execute(q)
    res, _ = run_pipeline(q)
    assert res.rows == 0 and res.rows_array().shape == ref_rows.shape


def test_scan_fusion_skips_filtered_materialization():
    # Satellite: the executor must not materialize filtered base tables
    # on the host before their first join (Table.filtered() untouched).
    q = make_star_query(1024, [256], selectivities=[0.1], seed=19)
    ref_rows, ref_agg = reference_execute(
        make_star_query(1024, [256], selectivities=[0.1], seed=19))
    res, _ = run_pipeline(q)
    assert res.aggregate == ref_agg and (res.rows_array() == ref_rows).all()
    assert q.tables["D0"]._filtered is None


def test_groupby_sink_priced_into_plan(planner):
    opt = JoinOrderOptimizer(planner)
    plain = make_star_query(2048, [256], seed=21)
    grouped = make_star_query(2048, [256], seed=21, group_by=("F.g",))
    p0, p1 = opt.optimize(plain), opt.optimize(grouped)
    assert p1.agg_plan is not None and p0.agg_plan is None
    assert p1.est_total_s > p0.est_total_s
    assert "group by" in p1.describe()
    assert p1.to_dict()["agg_scheme"] == p1.agg_plan.scheme


# ---------------------------------------------------------------------------
# Property: any group_by query matches reference_execute (hypothesis when
# available, deterministic sweep otherwise).
# ---------------------------------------------------------------------------

def _check_groupby_property(fact, dims, sel, kind, agg, seed):
    kinds = [kind] + ["inner"] * (len(dims) - 1)
    q = make_star_query(fact, dims,
                        selectivities=[sel] + [None] * (len(dims) - 1),
                        seed=seed, join_kinds=kinds, group_by=("F.g",),
                        aggregate=agg)
    ref_rows, _ = reference_execute(q)
    res, _ = run_pipeline(q, num_workers=0)
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()


def test_property_groupby_matches_reference():
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for fact, dims, sel, kind, agg, seed in (
                (512, [64], None, "inner", ("count",), 0),
                (1024, [64, 128], 0.3, "semi", ("sum", "F.m"), 1),
                (2048, [256], 0.5, "anti", ("max", "F.m"), 2),
                (512, [64, 64], None, "left_outer", ("avg", "F.m"), 3),
                (1024, [256], 0.05, "semi", ("min", "F.m"), 4)):
            _check_groupby_property(fact, dims, sel, kind, agg, seed)
        return

    @settings(max_examples=10, deadline=None)
    @given(fact=st.sampled_from([512, 1024, 2048]),
           dims=st.lists(st.sampled_from([64, 128, 256]), min_size=1,
                         max_size=2),
           sel=st.sampled_from([None, 0.05, 0.5]),
           kind=st.sampled_from(["inner", "semi", "anti", "left_outer"]),
           agg=st.sampled_from([("count",), ("sum", "F.m"),
                                ("min", "F.m"), ("avg", "F.m")]),
           seed=st.integers(0, 99))
    def check(fact, dims, sel, kind, agg, seed):
        _check_groupby_property(fact, dims, sel, kind, agg, seed)

    check()


# ---------------------------------------------------------------------------
# Analytic workload mix.
# ---------------------------------------------------------------------------

def test_workload_analytic_queries():
    from repro.engine import WorkloadGenerator
    gen = WorkloadGenerator(1024, seed=31)
    qs = [gen.analytic() for _ in range(4)]
    kinds = {j.kind for q in qs for j in q.joins}
    assert kinds - {"inner"}                 # variants actually appear
    assert all(q.group_by == ("F.g",) for q in qs)
    aggs = {q.aggregate[0] for q in qs}
    assert len(aggs) > 1                     # the aggregate cycle cycles
    gen2 = WorkloadGenerator(1024, seed=31)
    assert [q.describe() for q in qs] == \
        [gen2.analytic().describe() for _ in range(4)]


def test_workload_analytic_executes_correctly():
    from repro.engine import WorkloadGenerator
    gen = WorkloadGenerator(512, seed=37)
    q = gen.analytic()
    _run_vs_reference(q)
