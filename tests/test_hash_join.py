"""Hash-join engine correctness: unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Relation, build_hash_table, default_num_buckets,
                        join_oracle, phj_join, probe_hash_table,
                        probe_with_selectivity, shj_join, skewed_relation,
                        uniform_relation, unique_relation)
from repro.core.hash_table import merge_hash_tables
from repro.core.partition import radix_partition, partition_ids
from repro.core.phj import phj_coarse_join


def _check_join(build, probe, num_buckets=None, max_out=None):
    exp = join_oracle(build, probe)
    nb = num_buckets or default_num_buckets(build.size)
    mo = max_out or max(64, 4 * (len(exp) + 8))
    res = shj_join(build, probe, num_buckets=nb, max_out=mo)
    got = res.valid_pairs()
    assert got.shape == exp.shape
    assert (got == exp).all()
    return exp


def test_shj_unique_keys():
    _check_join(unique_relation(1000, seed=1),
                uniform_relation(3000, key_range=1500, seed=2))


def test_shj_duplicate_build_keys():
    _check_join(uniform_relation(2000, key_range=300, seed=3),
                uniform_relation(1000, key_range=300, seed=4))


def test_shj_skewed():
    _check_join(skewed_relation(2000, s_percent=25, seed=5),
                skewed_relation(3000, s_percent=25, seed=6))


def test_shj_no_matches():
    b = Relation(jnp.arange(100), jnp.arange(100))
    p = Relation(jnp.arange(50), jnp.arange(50) + 1000)
    res = shj_join(b, p, num_buckets=32, max_out=64)
    assert int(res.count) == 0


def test_shj_selectivity():
    b = unique_relation(1000, seed=7)
    for sel in (0.125, 0.5, 1.0):
        p = probe_with_selectivity(b, 2000, selectivity=sel, seed=8)
        exp = _check_join(b, p)
        assert abs(len(exp) / 2000 - sel) < 0.05


def test_phj_matches_shj():
    b = uniform_relation(4096, key_range=1000, seed=9)
    p = uniform_relation(8192, key_range=1000, seed=10)
    exp = join_oracle(b, p)
    res = phj_join(b, p, bits_per_pass=3, num_passes=2, buckets_per_part=8,
                   max_out=4 * len(exp))
    assert (res.valid_pairs() == exp).all()


def test_phj_coarse_matches():
    bits = 4
    b = uniform_relation(2048, key_range=700, seed=11)
    p = uniform_relation(4096, key_range=700, seed=12)
    exp = join_oracle(b, p)
    pr = radix_partition(b, bits_per_pass=2, num_passes=2)
    ps = radix_partition(p, bits_per_pass=2, num_passes=2)
    cap = int(max(np.asarray(pr.part_count).max(),
                  np.asarray(ps.part_count).max())) + 8
    res = phj_coarse_join(pr, ps, num_parts=1 << bits, part_cap=cap,
                          buckets_per_part=16,
                          max_out_per_part=cap * 16)
    assert (res.valid_pairs() == exp).all()


def test_merge_partial_tables():
    b = uniform_relation(2048, key_range=512, seed=13)
    p = uniform_relation(2048, key_range=512, seed=14)
    nb = 256
    t1 = build_hash_table(b.take(0, 1024), nb)
    t2 = build_hash_table(b.take(1024, 2048), nb)
    merged = merge_hash_tables([t1, t2], nb)
    res = probe_hash_table(p, merged, 65536)
    assert (res.valid_pairs() == join_oracle(b, p)).all()


def test_output_capacity_truncation():
    b = uniform_relation(512, key_range=4, seed=15)   # heavy duplication
    p = uniform_relation(512, key_range=4, seed=16)
    res = shj_join(b, p, num_buckets=16, max_out=100)
    assert int(res.count) == 100   # truncated, reported honestly
    assert (np.asarray(res.probe_rid[:100]) >= 0).all()


# ---------------------------------------------------------------------------
# Property tests.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 400), np_=st.integers(1, 400),
    key_range=st.integers(1, 500), seed=st.integers(0, 2**31 - 1),
)
def test_property_join_equals_oracle(nb, np_, key_range, seed):
    rng = np.random.default_rng(seed)
    b = Relation(jnp.arange(nb, dtype=jnp.int32),
                 jnp.asarray(rng.integers(0, key_range, nb, dtype=np.int32)))
    p = Relation(jnp.arange(np_, dtype=jnp.int32),
                 jnp.asarray(rng.integers(0, key_range, np_,
                                          dtype=np.int32)))
    exp = join_oracle(b, p)
    res = shj_join(b, p, num_buckets=64, max_out=max(64, 4 * len(exp) + 8))
    assert (res.valid_pairs() == exp).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000), bits=st.integers(1, 6),
       passes=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_property_partition_complete_and_clustered(n, bits, passes, seed):
    """Radix partitioning is a permutation AND clusters by partition id."""
    rng = np.random.default_rng(seed)
    rel = Relation(jnp.arange(n, dtype=jnp.int32),
                   jnp.asarray(rng.integers(0, 1 << 30, n, dtype=np.int32)))
    parts = radix_partition(rel, bits_per_pass=bits, num_passes=passes)
    # permutation: same multiset of (rid, key)
    got = np.stack([np.asarray(parts.rel.rid), np.asarray(parts.rel.key)], 1)
    exp = np.stack([np.asarray(rel.rid), np.asarray(rel.key)], 1)
    assert (got[np.lexsort(got.T)] == exp[np.lexsort(exp.T)]).all()
    # clustered: pids non-decreasing; headers consistent
    pid = np.asarray(partition_ids(parts.rel, total_bits=bits * passes))
    assert (np.diff(pid) >= 0).all()
    counts = np.asarray(parts.part_count)
    assert counts.sum() == n
    assert (np.asarray(parts.part_start)
            == np.concatenate([[0], np.cumsum(counts)[:-1]])).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 512), dup=st.integers(1, 50),
       seed=st.integers(0, 2**31 - 1))
def test_property_build_table_invariants(n, dup, seed):
    """Bucket headers tile the key entries; rid lists cover every tuple."""
    rng = np.random.default_rng(seed)
    rel = Relation(jnp.arange(n, dtype=jnp.int32),
                   jnp.asarray(rng.integers(0, dup, n, dtype=np.int32)))
    nb = 32
    t = build_hash_table(rel, nb)
    nk = int(t.num_keys)
    assert nk == len(np.unique(np.asarray(rel.key)))
    bks = np.asarray(t.bucket_key_start)
    bkc = np.asarray(t.bucket_key_count)
    assert bkc.sum() == nk
    assert (np.asarray(t.key_rid_count)[:nk].sum()) == n
