"""Per-kernel interpret-mode sweeps vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import uniform_relation, unique_relation


@pytest.mark.parametrize("n,buckets", [(1024, 64), (4096, 256),
                                       (8192, 1024)])
def test_hash_kernel(n, buckets, rng):
    from repro.kernels.hash.hash import hash_bucket_pallas
    from repro.kernels.hash.ref import hash_bucket_ref
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, n, dtype=np.int32))
    got = hash_bucket_pallas(keys, num_buckets=buckets, interpret=True)
    assert (np.asarray(got) == np.asarray(
        hash_bucket_ref(keys, num_buckets=buckets))).all()


@pytest.mark.parametrize("n,parts", [(1024, 16), (4096, 64), (4096, 256)])
def test_hist_kernel(n, parts, rng):
    from repro.kernels.partition_hist.partition_hist import radix_hist_pallas
    from repro.kernels.partition_hist.ref import radix_hist_ref
    pid = jnp.asarray(rng.integers(0, parts, n, dtype=np.int32))
    got = radix_hist_pallas(pid, num_parts=parts, interpret=True)
    assert (np.asarray(got) == np.asarray(
        radix_hist_ref(pid, num_parts=parts))).all()


@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
@pytest.mark.parametrize("n,bits,shift", [(1024, 4, 0), (4096, 6, 0),
                                          (4096, 3, 6), (8192, 8, 2)])
def test_fused_partition_hist_kernel(n, bits, shift, dtype, rng):
    """Fused n1+n2: pid AND histogram from one VMEM pass == oracle."""
    from repro.kernels.partition_hist.fused import partition_hist_fused_pallas
    from repro.kernels.partition_hist.ref import partition_hist_fused_ref
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, n).astype(dtype))
    pid, hist = partition_hist_fused_pallas(keys, shift=shift, bits=bits,
                                            interpret=True)
    epid, ehist = partition_hist_fused_ref(keys, shift=shift, bits=bits)
    assert (np.asarray(pid) == np.asarray(epid)).all()
    assert (np.asarray(hist) == np.asarray(ehist)).all()


@pytest.mark.parametrize("n,parts", [(1024, 8), (2048, 64), (4096, 16),
                                     (8192, 128)])
def test_radix_scatter_kernel(n, parts, rng):
    """Fused n3 scan+scatter == stable-sort oracle, bit-exact."""
    from repro.kernels.partition_hist.ref import radix_scatter_ref
    from repro.kernels.partition_hist.reorder import radix_scatter_pallas
    pid = jnp.asarray(rng.integers(0, parts, n, dtype=np.int32))
    rid = jnp.asarray(rng.permutation(n).astype(np.int32))
    key = jnp.asarray(rng.integers(-3, 2**31 - 1, n, dtype=np.int32))
    counts = np.bincount(np.asarray(pid), minlength=parts).astype(np.int32)
    starts = jnp.asarray(np.cumsum(counts) - counts, dtype=jnp.int32)
    orid, okey = radix_scatter_pallas(rid, key, pid, starts,
                                      num_parts=parts, interpret=True)
    erid, ekey = radix_scatter_ref(rid, key, pid)
    assert (np.asarray(orid) == np.asarray(erid)).all()
    assert (np.asarray(okey) == np.asarray(ekey)).all()


@pytest.mark.parametrize("n,bits", [(1024, 4), (4096, 5)])
def test_fused_pass_interpret_matches_jnp_path(n, bits, rng):
    """Whole fused pass: Pallas (interpret) vs the fused jnp path."""
    from repro.core import Relation
    from repro.kernels.partition_hist.ops import fused_partition_pass
    rel = Relation(jnp.arange(n, dtype=jnp.int32),
                   jnp.asarray(rng.integers(0, n, n, dtype=np.int32)))
    got, gs, gc = fused_partition_pass(rel, shift=0, bits=bits,
                                       interpret=True)
    exp, es, ec = fused_partition_pass(rel, shift=0, bits=bits,
                                       use_pallas=False)
    assert (np.asarray(got.rid) == np.asarray(exp.rid)).all()
    assert (np.asarray(got.key) == np.asarray(exp.key)).all()
    assert (np.asarray(gs) == np.asarray(es)).all()
    assert (np.asarray(gc) == np.asarray(ec)).all()


@pytest.mark.parametrize("nb,np_,bits", [(512, 1024, 2), (2048, 4096, 3)])
def test_probe_kernel(nb, np_, bits):
    from repro.kernels.probe.ops import build_partitioned_table
    from repro.kernels.probe.probe import probe_pallas
    from repro.kernels.probe.ref import probe_ref
    b = unique_relation(nb, seed=nb)
    p = uniform_relation(np_, key_range=nb * 2, seed=np_)
    tk, tr, qk, _ = build_partitioned_table(b, p, total_bits=bits)
    got = probe_pallas(tk, tr, qk, interpret=True)
    exp = probe_ref(tk, tr, qk)
    assert (np.asarray(got) == np.asarray(exp)).all()


@pytest.mark.parametrize(
    "b,sq,sk,h,kv,d,causal,dtype",
    [(2, 256, 256, 4, 2, 64, True, jnp.float32),
     (1, 128, 384, 8, 8, 128, False, jnp.float32),
     (2, 256, 256, 4, 4, 32, True, jnp.float32),
     (1, 256, 256, 8, 2, 64, True, jnp.bfloat16)])
def test_flash_attention_kernel(b, sq, sk, h, kv, d, causal, dtype, rng):
    from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
    from repro.kernels.flash_attn.ref import flash_attention_ref
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, sk, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, sk, kv, d)), dtype)
    got = flash_attention_pallas(q, k, v, num_kv_heads=kv, causal=causal,
                                 interpret=True)
    exp = flash_attention_ref(q, k, v, num_kv_heads=kv, causal=causal)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(got, np.float32), np.asarray(exp, np.float32),
                    rtol=tol, atol=tol)


@pytest.mark.parametrize("bs,nc,q,h,p,n,dtype",
                         [(2, 3, 64, 4, 32, 16, jnp.float32),
                          (1, 2, 128, 8, 64, 64, jnp.float32),
                          (1, 2, 128, 4, 64, 128, jnp.bfloat16)])
def test_ssd_kernel(bs, nc, q, h, p, n, dtype, rng):
    from repro.kernels.ssd.ref import ssd_intra_chunk_ref
    from repro.kernels.ssd.ssd import ssd_intra_chunk_pallas
    x = jnp.asarray(rng.standard_normal((bs, nc, q, h, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bs, nc, q, h)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((bs, nc, q, n)), dtype)
    cc = jnp.asarray(rng.standard_normal((bs, nc, q, n)), dtype)
    a = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.3), jnp.float32)
    got = ssd_intra_chunk_pallas(x, dt, bb, cc, a, interpret=True)
    exp = ssd_intra_chunk_ref(x, dt, bb, cc, a)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    assert_allclose(np.asarray(got, np.float32), np.asarray(exp, np.float32),
                    rtol=tol, atol=tol)


def test_kernel_end_to_end_join_with_pallas_probe():
    """Partition with the paper's pipeline, probe with the Pallas kernel,
    and match the full-join oracle on the unique-match subset."""
    from repro.core import join_oracle
    from repro.kernels.probe.ops import build_partitioned_table, probe
    b = unique_relation(4096, seed=42)
    p = uniform_relation(8192, key_range=6000, seed=43)
    tk, tr, qk, qr = build_partitioned_table(b, p, total_bits=4)
    rid = probe(tk, tr, qk, interpret=True)
    got = np.stack([np.asarray(qr).ravel(), np.asarray(rid).ravel()], 1)
    got = got[got[:, 1] >= 0]
    got = got[np.lexsort((got[:, 1], got[:, 0]))]
    exp = join_oracle(b, p)
    assert (got == exp).all()
