"""Checkpoint/restore: roundtrip, atomic commit, resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": {"a": jax.random.normal(k, (16, 8)),
                  "b": jnp.arange(10, dtype=jnp.int32)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    like = jax.tree.map(jnp.zeros_like, t)
    r = restore_checkpoint(str(tmp_path), 5, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_latest_pointer_and_prune(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_every=1)
    for s in (1, 2, 3, 4):
        assert mgr.maybe_save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_crash_mid_write_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated torn write
    assert latest_step(str(tmp_path)) == 1


def test_restore_latest_resumes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    t = _tree(3)
    mgr.maybe_save(3, t)
    like = jax.tree.map(jnp.zeros_like, t)
    restored, step = mgr.restore_latest(like)
    assert step == 3
    assert (np.asarray(restored["w"]["a"]) == np.asarray(t["w"]["a"])).all()


def test_elastic_restore_to_new_sharding(tmp_path):
    """Save unsharded, restore under a different (host) mesh sharding —
    the any-topology restore path (DESIGN.md §5)."""
    t = _tree()
    save_checkpoint(str(tmp_path), 9, t)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    shardings = {"w": {"a": sh, "b": sh},
                 "step": jax.sharding.NamedSharding(
                     mesh, jax.sharding.PartitionSpec())}
    r = restore_checkpoint(str(tmp_path), 9, t, shardings_tree=shardings)
    assert r["w"]["a"].sharding.is_equivalent_to(sh, 2)


def test_train_resume_equivalence(tmp_path):
    """Training 2 steps == training 1, checkpointing, restoring, 1 more."""
    from repro.configs import ShapeSpec, all_configs, reduced
    from repro.data.pipeline import make_batch
    from repro.distributed.sharding import TRAIN_RULES
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tfm
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import make_train_step

    cfg = reduced(all_configs()["phi3_mini_3_8b"])
    shape = ShapeSpec("t", 32, 2, "train")
    opt = AdamWConfig(lr=1e-3)
    mesh = make_host_mesh()
    step = jax.jit(make_train_step(cfg, mesh, TRAIN_RULES, opt))
    p0 = tfm.init_params(cfg, jax.random.PRNGKey(0))
    s0 = adamw_init(p0, opt)

    pa, sa = p0, s0
    for i in range(2):
        pa, sa, _ = step(pa, sa, make_batch(cfg, shape, step=i))

    pb, sb = p0, s0
    pb, sb, _ = step(pb, sb, make_batch(cfg, shape, step=0))
    save_checkpoint(str(tmp_path), 1, {"params": pb, "opt": sb})
    like = {"params": jax.tree.map(jnp.zeros_like, pb),
            "opt": jax.tree.map(jnp.zeros_like, sb)}
    rest = restore_checkpoint(str(tmp_path), 1, like)
    pc, sc, _ = step(rest["params"], rest["opt"],
                     make_batch(cfg, shape, step=1))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
