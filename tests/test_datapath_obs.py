"""Data-path observability: cardinality audit, transfer ledger, adaptive
mid-pipeline re-optimization, structural fingerprints, tenant cache budgets.

The skewed-star workload here is the PR's end-to-end story: a fact table
whose first FK column is half junk makes the System-R estimate for the
first join wrong by ~16x; the adaptive executor observes the exact device
cardinality, re-prices the remaining tail, and flips the stage order —
while reproducing the NumPy reference rows exactly (the same
permutation-invariance contract every static plan already honors).
"""
import numpy as np
import pytest

from repro.engine import BuildTableCache, JoinQueryService, QueryPlanner
from repro.obs import (CAUSES, CardinalityAudit, INTERMEDIATE_CAUSES,
                       MetricsRegistry, TransferLedger, q_error)
from repro.queries import (Join, JoinOrderOptimizer, PipelineExecutor,
                           Query, Table, make_star_query, reference_execute)


def make_service(**kw):
    return JoinQueryService(planner=QueryPlanner(delta=0.25),
                            num_workers=kw.pop("num_workers", 2), **kw)


def skewed_star_query(seed: int = 7) -> Query:
    """Seed-deterministic 3-join star built to fool the estimator.

    ``fact.fk0`` is ~50% matching / ~50% junk keys drawn from a wide
    range: the uniform-ndv estimate prices the first join at ~250 rows
    where ~4096 actually survive.  ``d2`` has 40 distinct ids over 400
    rows against a [0, 4000) FK — a x0.1 *shrink* at the true
    intermediate size that the estimate (capped by the ~250-row
    component's ndv) prices as x1.6 *growth*, so the static plan
    schedules it last while the observed cardinality says run it first.
    """
    rng = np.random.default_rng(seed)
    n = 8192
    fk0 = np.where(rng.random(n) < 0.5,
                   rng.integers(0, 128, n),
                   rng.integers(100_000, 200_000, n)).astype(np.int32)
    fact = Table("fact", {
        "fk0": fk0,
        "fk1": rng.integers(0, 144, n).astype(np.int32),
        "fk2": rng.integers(0, 4000, n).astype(np.int32),
        "v": rng.integers(0, 100, n).astype(np.int32)})
    d0 = Table("d0", {"id": np.arange(128, dtype=np.int32),
                      "a": rng.integers(0, 10, 128).astype(np.int32)})
    d1 = Table("d1", {"id": np.arange(144, dtype=np.int32),
                      "b": rng.integers(0, 10, 144).astype(np.int32)})
    d2 = Table("d2", {"id": np.repeat(np.arange(40, dtype=np.int32), 10),
                      "c": rng.integers(0, 10, 400).astype(np.int32)})
    return Query(tables={"fact": fact, "d0": d0, "d1": d1, "d2": d2},
                 joins=(Join("fact", "fk0", "d0", "id"),
                        Join("fact", "fk1", "d1", "id"),
                        Join("fact", "fk2", "d2", "id")),
                 aggregate=("count",))


# ---------------------------------------------------------------------------
# Units: q-error, cardinality audit, transfer ledger.
# ---------------------------------------------------------------------------

def test_q_error_symmetric_and_clamped():
    assert q_error(100, 100) == 1.0
    assert q_error(100, 400) == pytest.approx(4.0)
    assert q_error(400, 100) == pytest.approx(4.0)
    assert q_error(0.3, 0) == 1.0          # both clamp to >= 1: perfect
    assert q_error(0, 8) == pytest.approx(8.0)


def test_cardinality_audit_summary():
    audit = CardinalityAudit(max_records=4)
    for est, obs in ((100, 100), (100, 200), (50, 400)):
        audit.record(stage_type="inner", est_rows=est, observed_rows=obs,
                     depth=1, tenant="t0")
    audit.record(stage_type="semi", est_rows=10, observed_rows=10, depth=2)
    s = audit.summary()
    assert s["count"] == 4
    assert set(s["stage_types"]) == {"inner", "semi"}
    inner = s["stage_types"]["inner"]
    assert inner["count"] == 3 and inner["max"] == pytest.approx(8.0)
    assert np.isfinite(inner["p50"]) and np.isfinite(inner["p95"])
    assert set(s["depths"]) == {"1", "2"}
    assert s["tenants"]["t0"]["count"] == 3
    # Bounded ring: a 5th record drops the oldest.
    audit.record(stage_type="anti", est_rows=1, observed_rows=1)
    assert audit.summary()["count"] == 4


def test_ledger_records_and_sums():
    metrics = MetricsRegistry()
    led = TransferLedger(metrics)
    led.record(100, cause="handoff", stage="stage0", direction="d2h")
    led.record(50, cause="handoff", stage="stage0", direction="d2h")
    led.record(30, cause="fingerprint", stage="adhoc", column="build.key")
    led.record(70, cause="multicol_pack", stage="groupby-sink",
               direction="h2d")
    led.record(999, cause="result", stage="result", column="*")
    led.record(0, cause="handoff")          # no-ops, not recorded
    led.record(-5, cause="handoff")
    by_cause = led.by_cause()
    assert by_cause == {"fingerprint": 30, "multicol_pack": 70,
                        "handoff": 150, "result": 999}
    # The flat counter is a sum view over the intermediate causes only.
    assert led.total() == 250
    assert led.total(intermediate_only=False) == 1249
    snap = metrics.snapshot()
    assert snap["host_bytes_moved"] == 250
    assert snap["host_transfer_bytes{cause=handoff,direction=d2h}"] == 150
    assert snap["host_transfer_bytes{cause=result,direction=d2h}"] == 999
    s = led.summary()
    assert s["intermediate_bytes"] == 250 and s["total_bytes"] == 1249
    assert s["crossings"] == 5
    assert s["by_stage"]["stage0"]["handoff"] == 150
    assert s["by_direction"]["h2d"] == 70
    with pytest.raises(ValueError):
        led.record(1, cause="mystery")
    with pytest.raises(ValueError):
        led.record(1, cause="handoff", direction="sideways")


# ---------------------------------------------------------------------------
# Ledger exactness over the pipeline paths.
# ---------------------------------------------------------------------------

def test_ledger_fused_path_attributed_and_quiet():
    """Fused path: zero intermediate bytes, all causes known, handoff == 0,
    and the ledger sum equals the flat counter exactly."""
    query = make_star_query(4096, [256, 128], seed=3, aggregate=None)
    svc = make_service()
    with PipelineExecutor(service=svc) as ex:
        res = ex.run(query)
        assert res.host_bytes_moved == 0
        summ = svc.ledger.summary()
        assert set(summ["by_cause"]) == set(CAUSES)
        assert summ["by_cause"]["handoff"] == 0
        assert summ["intermediate_bytes"] == \
            svc.stats()["host_bytes_moved"] == 0
        # Result delivery is attributed under ``result`` without ever
        # touching the intermediate counter.
        rows = res.rows_array()
        assert rows.shape[0] == res.rows
        assert svc.ledger.by_cause()["result"] > 0
        assert svc.stats()["host_bytes_moved"] == 0


def test_ledger_host_path_sum_matches_counter():
    """Host-materialize path: every byte the pipeline reports is in the
    ledger — sum over intermediate causes == host_bytes_moved, exactly."""
    query = make_star_query(4096, [256, 128], seed=3, aggregate=("count",))
    svc = make_service()
    opt = JoinOrderOptimizer(svc.planner, handoff="host")
    with PipelineExecutor(service=svc, optimizer=opt,
                          handoff="host") as ex:
        res = ex.run(query)
        assert res.host_bytes_moved > 0
        st = svc.stats()
        summ = st["host_transfer_ledger"]
        assert summ["intermediate_bytes"] == st["host_bytes_moved"] \
            == res.host_bytes_moved
        assert summ["by_cause"]["handoff"] == res.host_bytes_moved
        assert sum(summ["by_cause"][c] for c in INTERMEDIATE_CAUSES) \
            == st["host_bytes_moved"]


def test_ledger_multicol_groupby_cause_split():
    """Multi-column group-by on the fused path: the host pack shows up as
    ``multicol_pack`` (attributed!), never as ``handoff``."""
    query = make_star_query(4096, [128, 64], seed=5, aggregate=("count",),
                            group_by=("D0.a", "D1.a"))
    svc = make_service()
    with PipelineExecutor(service=svc) as ex:
        res = ex.run(query)
        by_cause = svc.ledger.by_cause()
        assert by_cause["handoff"] == 0
        assert by_cause["multicol_pack"] > 0
        assert by_cause["multicol_pack"] == res.host_bytes_moved
        assert svc.stats()["host_bytes_moved"] == res.host_bytes_moved


def test_cardinality_recorded_for_every_stage():
    query = make_star_query(4096, [256, 128], seed=3)
    svc = make_service()
    with PipelineExecutor(service=svc) as ex:
        ex.run(query)
        st = svc.stats()["cardinality_error"]
        assert st["count"] == 2                      # one per join stage
        assert "inner" in st["stage_types"]
        t = st["stage_types"]["inner"]
        assert t["count"] == 2
        assert np.isfinite(t["p50"]) and np.isfinite(t["p95"])
        assert all(r["observed_rows"] >= 0
                   for r in svc.cardinality.records())


# ---------------------------------------------------------------------------
# Structural fingerprints: the fused path stops pulling key columns.
# ---------------------------------------------------------------------------

def test_fused_fingerprints_no_pull_and_cache_hits():
    """Repeating a fused pipeline hits the build cache via structural
    fingerprints — zero ``fingerprint``-cause bytes on either run."""
    query = make_star_query(4096, [256, 128], seed=11)
    svc = make_service()
    with PipelineExecutor(service=svc) as ex:
        first = ex.run(query)
        hits_before = svc.cache.stats()["hits"]
        again = ex.run(query)
        assert again.aggregate == first.aggregate
        assert svc.cache.stats()["hits"] > hits_before
        assert svc.ledger.by_cause()["fingerprint"] == 0
        assert svc.stats()["host_bytes_moved"] == 0


def test_host_path_fingerprints_hash_before_upload():
    """The host path fingerprints from the host copy pre-upload: no
    fingerprint pulls there either, and repeats still hit the cache."""
    query = make_star_query(4096, [256], seed=11)
    svc = make_service()
    opt = JoinOrderOptimizer(svc.planner, handoff="host")
    with PipelineExecutor(service=svc, optimizer=opt,
                          handoff="host") as ex:
        ex.run(query)
        hits_before = svc.cache.stats()["hits"]
        ex.run(query)
        assert svc.cache.stats()["hits"] > hits_before
        assert svc.ledger.by_cause()["fingerprint"] == 0


# ---------------------------------------------------------------------------
# Adaptive mid-pipeline re-optimization.
# ---------------------------------------------------------------------------

def test_adaptive_replan_flips_stage_order():
    query = skewed_star_query()
    ref_rows, ref_agg = reference_execute(query)

    svc_static = make_service()
    with PipelineExecutor(service=svc_static) as ex:
        static_res = ex.run(query)
    static_order = [str(s.join) for s in static_res.physical.stages]

    svc = make_service()
    with PipelineExecutor(service=svc, adaptive=True) as ex:
        res = ex.run(query)
        adaptive_order = [str(s.join) for s in res.physical.stages]
        # The replan happened, flipped the executed order, and left a
        # structured record + counter behind.
        assert len(res.replans) >= 1
        assert adaptive_order != static_order
        rec = res.replans[0]
        assert rec["worst_q_error"] >= 2.0
        assert rec["old_tail"] != rec["new_tail"]
        assert rec["after_stages"] >= 1
        assert svc.metrics.snapshot()["pipeline_replans"] >= 1
        assert svc.metrics.events("replan")
        # Row-exactness survives the mid-flight re-order, fused-quiet.
        assert res.aggregate == static_res.aggregate == ref_agg
        assert np.array_equal(res.rows_array(), ref_rows)
        assert res.host_bytes_moved == 0
        # to_dict carries the replans for bench payloads.
        assert res.to_dict()["replans"] == res.replans


def test_adaptive_noop_on_accurate_estimates():
    """Uniform star: estimates are good, so no replan fires and results
    match the static run exactly."""
    query = make_star_query(4096, [256, 128, 64], seed=3)
    ref_rows, ref_agg = reference_execute(query)
    svc = make_service()
    with PipelineExecutor(service=svc, adaptive=True) as ex:
        res = ex.run(query)
        assert res.replans == []
        assert res.aggregate == ref_agg
        assert np.array_equal(res.rows_array(), ref_rows)
        assert svc.metrics.snapshot().get("pipeline_replans", 0) == 0


def test_adaptive_group_by_and_variants_still_exact():
    query = make_star_query(4096, [256, 128], seed=9, aggregate=("count",),
                            group_by=("D0.a",), join_kinds=("inner", "semi"))
    ref_rows, _ = reference_execute(query)
    with PipelineExecutor(service=make_service(), adaptive=True) as ex:
        res = ex.run(query)
        assert np.array_equal(res.rows_array(), ref_rows)


def test_reprice_remaining_guards():
    opt = JoinOrderOptimizer(QueryPlanner(delta=0.25))
    query = skewed_star_query()
    j0, j1, j2 = query.joins
    observed = {id(j0): 4096}
    # A single-edge tail cannot be re-ordered.
    assert opt.reprice_remaining(query, [j0, j1], [j2], observed) is None
    # Outer queries pin textual order: never re-ordered.
    rng = np.random.default_rng(0)
    t0 = Table("t0", {"id": np.arange(256, dtype=np.int32),
                      "fka": rng.integers(0, 64, 256).astype(np.int32),
                      "fkb": rng.integers(0, 64, 256).astype(np.int32)})
    ta = Table("ta", {"id": np.arange(64, dtype=np.int32)})
    tb = Table("tb", {"id": np.arange(64, dtype=np.int32)})
    outer = Query(tables={"t0": t0, "ta": ta, "tb": tb},
                  joins=(Join("t0", "fka", "ta", "id", kind="left_outer"),
                         Join("t0", "fkb", "tb", "id"),
                         Join("t0", "id", "t0", "id")))
    o0 = outer.joins[0]
    assert opt.reprice_remaining(
        outer, [o0], list(outer.joins[1:]), {id(o0): 256}) is None


def test_replan_margin_hysteresis():
    pl = QueryPlanner(delta=0.25, replan_margin=0.8)
    assert pl.replan_beats(0.7, 1.0)
    assert not pl.replan_beats(0.9, 1.0)     # near-tie: incumbent stays
    assert not pl.replan_beats(0.8, 1.0)     # margin is strict


# ---------------------------------------------------------------------------
# Per-tenant cache byte budgets.
# ---------------------------------------------------------------------------

def _filler(nbytes: int) -> np.ndarray:
    return np.zeros(nbytes // 4, dtype=np.int32)


def test_tenant_budget_evicts_own_entries_first():
    reg = MetricsRegistry()
    cache = BuildTableCache(budget_bytes=1 << 20,
                            tenant_budget_bytes=1024)
    cache.register_metrics(reg)
    assert cache.put("hot:a", _filler(512), tenant="hot")
    assert cache.put("cold:a", _filler(512), tenant="cold")
    assert cache.put("hot:b", _filler(512), tenant="hot")
    # Third hot entry pushes the tenant over its cap: its own LRU entry
    # goes, the cold tenant's survives.
    assert cache.put("hot:c", _filler(512), tenant="hot")
    assert cache.peek("hot:a") is None
    assert cache.peek("cold:a") is not None
    assert cache.peek("hot:b") is not None
    st = cache.stats()
    assert st["budget_evictions"] == 1 and st["evictions"] == 1
    assert st["tenant_bytes"]["hot"] == 1024
    snap = reg.snapshot()
    assert snap["cache_budget_evictions{kind=table,tenant=hot}"] == 1
    assert snap["cache_evictions{kind=table,tenant=hot}"] == 1
    ev = reg.events("cache_eviction")
    assert ev and ev[-1]["reason"] == "tenant_budget"
    assert ev[-1]["victim"] == "hot"


def test_tenant_budget_rejects_oversized_entry():
    cache = BuildTableCache(budget_bytes=1 << 20,
                            tenant_budget_bytes={"small": 256})
    assert not cache.put("small:big", _filler(512), tenant="small")
    assert len(cache) == 0
    # Unlisted tenants are uncapped under a dict budget.
    assert cache.put("other:big", _filler(512), tenant="other")


def test_shared_capacity_sweep_unchanged():
    reg = MetricsRegistry()
    cache = BuildTableCache(budget_bytes=1024)
    cache.register_metrics(reg)
    assert cache.put("a", _filler(512), tenant="t0")
    assert cache.put("b", _filler(512), tenant="t1")
    assert cache.put("c", _filler(512), tenant="t2")   # evicts "a"
    assert cache.peek("a") is None
    st = cache.stats()
    assert st["evictions"] == 1 and st["budget_evictions"] == 0
    ev = reg.events("cache_eviction")
    assert ev[-1]["reason"] == "capacity"


def test_service_accepts_tenant_cache_budget():
    svc = make_service(tenant_cache_budget_bytes=64 << 10)
    try:
        assert svc.cache.tenant_budget_bytes == 64 << 10
    finally:
        svc.close()
