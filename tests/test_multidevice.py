"""Integration tests that need multiple XLA host devices — run in
subprocesses so the main pytest process keeps its single device."""
import json
import subprocess
import sys

import pytest

CODE_COPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.core import (CoProcessor, PCIE_LINK, join_oracle,
                        uniform_relation, unique_relation)
b = unique_relation(4096, seed=1)
p = uniform_relation(8192, key_range=6000, seed=2)
exp = join_oracle(b, p)
out = {}
cp = CoProcessor()
assert cp.c.size == 2 and cp.g.size == 6
for mode in ("shared", "separate"):
    res, t = cp.shj(b, p, num_buckets=1024, max_out=65536,
                    build_ratios=[0.25]*4, probe_ratios=[0.5]*4,
                    table_mode=mode)
    got = res.valid_pairs()
    out[mode] = bool(got.shape == exp.shape and (got == exp).all())
cpd = CoProcessor(link=PCIE_LINK, discrete=True)
res, t = cpd.shj(b, p, num_buckets=1024, max_out=65536,
                 build_ratios=[0.25]*4, probe_ratios=[0.5]*4,
                 table_mode="separate")
out["discrete"] = bool((res.valid_pairs() == exp).all())
out["discrete_transfer_bytes"] = int(t.transfer_bytes)
print(json.dumps(out))
"""

CODE_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses, jax, numpy as np
from repro.configs import all_configs, reduced, SHAPES, ShapeSpec
from repro.launch import dryrun as dr
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
cfg = reduced(all_configs()["qwen3_8b"])
cfg = dataclasses.replace(cfg, d_model=64, num_heads=8, num_kv_heads=4,
                          head_dim=16, d_ff=128)
shape = ShapeSpec("t", 64, 8, "train")
dr.SHAPES["t"] = shape
lowered = dr._build_lowered(cfg, shape, mesh, None, "float32")
compiled = lowered.compile()
cost = dr.cost_analysis_dict(compiled)
colls = dr.parse_collectives(compiled.as_text())
print(json.dumps({"flops": cost.get("flops", 0.0),
                  "collectives": len(colls),
                  "ok": True}))
"""


def _run(code: str) -> dict:
    # JAX_PLATFORMS=cpu: these tests are about forced HOST devices; without
    # it, a machine with libtpu installed but no TPU blocks in backend init.
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_coprocessor_real_two_groups():
    out = _run(CODE_COPROC)
    assert out["shared"] and out["separate"] and out["discrete"]
    assert out["discrete_transfer_bytes"] > 0


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    out = _run(CODE_DRYRUN)
    assert out["ok"] and out["flops"] > 0 and out["collectives"] > 0
