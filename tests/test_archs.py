"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, ShapeSpec, all_configs, reduced, runnable
from repro.data.pipeline import make_batch
from repro.distributed.sharding import TRAIN_RULES
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step

SMOKE = ShapeSpec("smoke", 64, 2, "train")
ARCHS = list(all_configs())


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = reduced(all_configs()[arch])
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE)
    logits, aux = tfm.forward_train(params, cfg, batch["tokens"],
                                    batch.get("enc_frames"))
    assert logits.shape == (SMOKE.global_batch, SMOKE.seq_len,
                            cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, mesh):
    cfg = reduced(all_configs()[arch])
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    opt = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params, opt)
    step = jax.jit(make_train_step(cfg, mesh, TRAIN_RULES, opt))
    batch = make_batch(cfg, SMOKE)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    delta = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = reduced(all_configs()[arch])
    params = tfm.init_params(cfg, jax.random.PRNGKey(2))
    shape = ShapeSpec("t", 32, 2, "train")
    batch = make_batch(cfg, shape)
    toks, enc = batch["tokens"], batch.get("enc_frames")
    logits_full, _ = tfm.forward_train(params, cfg, toks, enc)
    logits_pre, cache = tfm.prefill(params, cfg, toks[:, :-1], enc)
    from jax.tree_util import tree_map_with_path

    def grow(path, x):
        names = [str(getattr(p, "key", "")) for p in path]
        if any(n in ("k", "v") for n in names):
            pad = [(0, 0)] * x.ndim
            pad[x.ndim - 3] = (0, 8)
            return jnp.pad(x, pad)
        return x

    cache = tree_map_with_path(grow, cache)
    logits_dec, _ = tfm.decode_step(params, cfg, toks[:, -1:], cache,
                                    jnp.int32(31))
    a = np.asarray(logits_full[:, -1].astype(jnp.float32))
    b = np.asarray(logits_dec.astype(jnp.float32))
    rel = np.max(np.abs(a - b)) / max(1e-6, np.max(np.abs(a)))
    assert rel < 0.06, rel
    c = np.asarray(logits_full[:, -2].astype(jnp.float32))
    d = np.asarray(logits_pre.astype(jnp.float32))
    assert np.max(np.abs(c - d)) / max(1e-6, np.max(np.abs(c))) < 0.06


def test_assigned_cells_marked():
    """Exactly the 8 full-attention long_500k cells are skipped."""
    skipped = [(a, s.name) for a, c in all_configs().items()
               for s in SHAPES.values() if not runnable(c, s)[0]]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert {"mamba2_2_7b", "zamba2_1_2b"}.isdisjoint({a for a, _ in skipped})


def test_loss_decreases_on_structured_data():
    """A few steps on the synthetic structured stream reduce the loss."""
    cfg = reduced(all_configs()["qwen3_8b"])
    params = tfm.init_params(cfg, jax.random.PRNGKey(3))
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    opt_state = adamw_init(params, opt)
    mesh = make_host_mesh()
    step = jax.jit(make_train_step(cfg, mesh, TRAIN_RULES, opt))
    losses = []
    for i in range(16):
        batch = make_batch(cfg, ShapeSpec("t", 128, 4, "train"), step=i)
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    # Per-step losses are noisy on 4-sequence batches; compare window means.
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.2, losses
