"""Cost model (paper Eqs. 1–5): structure, special cases, optimizers."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.calibrate import APU_CPU, APU_GPU
from repro.core.cost_model import (DCN_LINK, ICI_LINK, PCIE_LINK,
                                   SeriesCostModel, ZEROCOPY_LINK,
                                   series_model_from_costs)
from repro.core.shj import BUILD_SERIES, PROBE_SERIES


def _model(link=ICI_LINK, discrete=False, n_items=1e6):
    return series_model_from_costs(PROBE_SERIES.steps, [n_items] * 4,
                                   APU_CPU, APU_GPU, link,
                                   discrete=discrete)


def test_cpu_only_vs_gpu_only():
    m = _model()
    t_cpu = m.estimate_batch(np.ones((1, 4)))[0]
    t_gpu = m.estimate_batch(np.zeros((1, 4)))[0]
    # APU: GPU wins hash steps by >15x, so GPU-only beats CPU-only overall.
    assert t_gpu < t_cpu


def test_pl_no_worse_than_dd_and_ol():
    m = _model()
    _, tpl = m.optimize_pl(delta=0.05)
    _, tdd = m.optimize_dd(delta=0.05)
    _, tol = m.optimize_ol()
    assert tpl <= tdd + 1e-12
    assert tpl <= tol + 1e-12


def test_dd_is_pl_special_case():
    m = _model()
    r, tdd = m.optimize_dd(delta=0.1)
    assert abs(m.estimate_batch(np.full((1, 4), r))[0] - tdd) < 1e-12


def test_equal_ratio_no_pipeline_delay():
    m = _model()
    bd = m.estimate([0.3, 0.3, 0.3, 0.3])
    assert np.allclose(bd.delay_c, 0.0)
    assert np.allclose(bd.delay_g, 0.0)
    assert np.allclose(bd.link, 0.0)


def test_discrete_adds_bus_cost():
    coupled = _model(ZEROCOPY_LINK, discrete=False)
    discrete = _model(PCIE_LINK, discrete=True)
    r = np.array([[0.3, 0.3, 0.3, 0.3]])
    assert discrete.estimate_batch(r)[0] > coupled.estimate_batch(r)[0]


def test_pl_ratio_mismatch_penalized_on_discrete():
    """The paper's central claim: fine-grained PL collapses on discrete
    (PCIe-priced intermediates) but stays cheap on coupled."""
    varied = np.array([[0.0, 0.2, 0.8, 0.1]])
    flat = np.array([[0.3, 0.3, 0.3, 0.3]])
    disc = _model(PCIE_LINK, discrete=True)
    coup = _model(ICI_LINK, discrete=False)
    penalty_disc = disc.estimate_batch(varied)[0] - disc.estimate_batch(flat)[0]
    penalty_coup = coup.estimate_batch(varied)[0] - coup.estimate_batch(flat)[0]
    assert penalty_disc > penalty_coup


def test_monte_carlo_never_beats_optimum_much():
    m = _model()
    _, tpl = m.optimize_pl(delta=0.02)
    _, times = m.monte_carlo(500, seed=1)
    assert times.min() >= tpl - 0.05 * tpl


@settings(max_examples=30, deadline=None)
@given(r=st.lists(st.floats(0, 1), min_size=4, max_size=4),
       x=st.floats(1e3, 1e8))
def test_property_estimate_positive_and_max(r, x):
    m = _model(n_items=x)
    bd = m.estimate(np.array(r))
    assert bd.total >= 0
    assert bd.total == pytest.approx(max(bd.t_c, bd.t_g))
    batch = m.estimate_batch(np.array([r]))[0]
    assert batch == pytest.approx(bd.total, rel=1e-9)


def test_build_series_model_works():
    m = series_model_from_costs(BUILD_SERIES.steps, [1e6] * 4, APU_CPU,
                                APU_GPU, DCN_LINK, discrete=True)
    r, t = m.optimize_pl(delta=0.1)
    assert np.isfinite(t) and t > 0
