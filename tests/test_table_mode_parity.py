"""Property-based parity: shared vs separate build tables (paper §3.3).

The two build-table modes differ in mechanism (bucket-range ownership vs
partial tables + merge) but must be semantically identical for every ratio
assignment.  Hypothesis drives the ratio grid, relation sizes, and key
skew; both modes must produce the oracle's exact pair set.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (CoProcessor, join_oracle, skewed_relation,
                        uniform_relation, unique_relation)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


ratio = st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0])


@settings(max_examples=12, deadline=None)
@given(
    build_ratios=st.tuples(ratio, ratio, ratio, ratio),
    probe_ratios=st.tuples(ratio, ratio, ratio, ratio),
    n_build=st.sampled_from([257, 512, 1000]),
    n_probe=st.sampled_from([333, 1024]),
    skew=st.sampled_from(["uniform", "unique", "high"]),
)
def test_shared_vs_separate_modes_agree(cp, build_ratios, probe_ratios,
                                        n_build, n_probe, skew):
    if skew == "uniform":
        b = uniform_relation(n_build, seed=1)
    elif skew == "unique":
        b = unique_relation(n_build, seed=1)
    else:
        b = skewed_relation(n_build, s_percent=25, seed=1)
    p = uniform_relation(n_probe, key_range=n_build, seed=2)
    exp = join_oracle(b, p)
    max_out = exp.shape[0] + n_probe + 64
    got = {}
    for mode in ("shared", "separate"):
        res, t = cp.shj(b, p, num_buckets=128, max_out=max_out,
                        build_ratios=list(build_ratios),
                        probe_ratios=list(probe_ratios), table_mode=mode)
        got[mode] = res.valid_pairs()
        assert got[mode].shape == exp.shape, (mode, build_ratios)
        assert (got[mode] == exp).all(), (mode, build_ratios, probe_ratios)
    assert (got["shared"] == got["separate"]).all()
