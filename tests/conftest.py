"""Shared fixtures.  NOTE: device count stays 1 here (the 512-device flag
belongs ONLY to launch/dryrun.py); multi-device executor tests spawn
subprocesses or run in degraded single-device mode."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
