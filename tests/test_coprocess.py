"""Two-group co-processing executor: every scheme produces the oracle join
(single-device degraded mode here; the real 8-device run is exercised by
the benchmark harness and by test_multidevice.py's subprocess)."""
import numpy as np
import pytest

from repro.core import (CoProcessor, PCIE_LINK, join_oracle,
                        uniform_relation, unique_relation)


@pytest.fixture(scope="module")
def data():
    b = unique_relation(2048, seed=1)
    p = uniform_relation(4096, key_range=3000, seed=2)
    return b, p, join_oracle(b, p)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


SCHEMES = {
    "cpu_only": ([1.0] * 4, [1.0] * 4),
    "gpu_only": ([0.0] * 4, [0.0] * 4),
    "dd": ([0.25] * 4, [0.5] * 4),
    "pl": ([0.0, 0.25, 0.5, 0.25], [0.0, 0.25, 0.75, 0.25]),
}


@pytest.mark.parametrize("mode", ["shared", "separate"])
@pytest.mark.parametrize("scheme", list(SCHEMES))
def test_shj_schemes(data, cp, mode, scheme):
    b, p, exp = data
    br, pr = SCHEMES[scheme]
    res, t = cp.shj(b, p, num_buckets=512, max_out=32768,
                    build_ratios=br, probe_ratios=pr, table_mode=mode)
    got = res.valid_pairs()
    assert got.shape == exp.shape
    assert (got == exp).all()
    assert t.wall_s > 0


def test_shj_discrete_emulation(data):
    b, p, exp = data
    cp = CoProcessor(link=PCIE_LINK, discrete=True)
    res, t = cp.shj(b, p, num_buckets=512, max_out=32768,
                    build_ratios=[0.25] * 4, probe_ratios=[0.5] * 4,
                    table_mode="separate")
    assert (res.valid_pairs() == exp).all()
    assert t.transfer_bytes > 0


def test_phj_coprocess(data, cp):
    b, p, exp = data
    res, t = cp.phj(b, p, bits_per_pass=3, num_passes=2, shj_bits=2,
                    max_out=32768, partition_ratio=0.25, join_ratio=0.5)
    assert (res.valid_pairs() == exp).all()
    assert set(t.phase_s) == {"partition", "join"}


def test_basic_unit(data, cp):
    b, p, exp = data
    res, t, ratios = cp.basic_unit_shj(b, p, num_buckets=512,
                                       max_out=32768, chunk=512)
    assert (res.valid_pairs() == exp).all()
    assert 0.0 <= ratios["build"] <= 1.0
    assert 0.0 <= ratios["probe"] <= 1.0


def test_divergence_grouping_roundtrip(rng):
    import jax.numpy as jnp
    from repro.core import (divergence_order, inverse_permutation,
                            tile_divergence_waste)
    w = jnp.asarray(rng.zipf(1.5, 4096).clip(0, 1000).astype(np.int32))
    order = divergence_order(w, num_groups=64)
    inv = inverse_permutation(order)
    assert (np.asarray(order[inv]) == np.arange(4096)).all()
    before = float(tile_divergence_waste(w, tile=256))
    after = float(tile_divergence_waste(w[order], tile=256))
    assert after <= before  # grouping only helps


def test_scan_allocator(rng):
    import jax.numpy as jnp
    from repro.core import alloc_stats, basic_alloc_units, scan_alloc
    sizes = jnp.asarray(rng.integers(0, 9, 4096, dtype=np.int32))
    offs, total = scan_alloc(sizes, tile=256, block_items=256)
    offs = np.asarray(offs)
    sz = np.asarray(sizes)
    # Non-overlapping extents.  Zero-size requests legitimately share an
    # offset with the next live extent, so only positive extents are
    # checked (argsort orders equal offsets arbitrarily).
    pos = sz > 0
    order = np.argsort(offs[pos])
    ends = offs[pos][order] + sz[pos][order]
    assert (offs[pos][order][1:] >= ends[:-1]).all()
    assert int(total) >= sz.sum()
    st = alloc_stats(sizes, tile=256, block_items=256)
    assert st.global_units == 4096 // 256           # one claim per tile
    assert basic_alloc_units(sizes) == int((sz > 0).sum())
